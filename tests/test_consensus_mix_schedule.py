"""Kernel-vs-dense parity for the schedule-aware consensus_mix path.

The fused kernel (interpret mode) must match the dense einsum runtime —
``consensus_lib.mix_stacked`` plus the masked d-bias — on static topologies
AND on every round of a time-varying schedule, where rounds of differing
degree share one padded shape and churned-out peers have degree 0.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as consensus_lib
from repro.core import graph as gl
from repro.core import protocols
from repro.kernels.consensus_mix import ops as cm_ops

K = 8
T = 10  # local steps


def _tree(rng, k=K):
    return {
        "w": jnp.asarray(rng.normal(size=(k, 33)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(k, 5, 7)), jnp.float32),
    }


def _dense_reference(w_mat, beta_mat, tree, local_steps=T):
    """mix_stacked + the d-bias with the isolated-peer (all-zero beta row) mask."""
    wj = jnp.asarray(w_mat, jnp.float32)
    bj = jnp.asarray(beta_mat, jnp.float32)
    mixed = consensus_lib.mix_stacked(wj, tree)
    nbr_avg = consensus_lib.mix_stacked(bj, tree)
    has_nbrs = np.asarray(beta_mat).sum(axis=1) > 0
    d = jax.tree.map(
        lambda avg, x: np.where(
            has_nbrs.reshape((-1,) + (1,) * (x.ndim - 1)),
            (np.asarray(avg, np.float32) - np.asarray(x, np.float32)) / local_steps,
            0.0,
        ),
        nbr_avg,
        tree,
    )
    return mixed, d


def _assert_parity(got, want, atol=1e-5):
    for key in want:
        np.testing.assert_allclose(
            np.asarray(got[key]), np.asarray(want[key]), atol=atol, err_msg=key
        )


@pytest.mark.parametrize("topo", ["ring", "star", "erdos_renyi"])
def test_static_parity(topo, rng):
    g = gl.build_graph(topo, K)
    sizes = rng.integers(1, 50, K)
    w = gl.mixing_matrix(g, "data_weighted", data_sizes=sizes)
    beta = gl.affinity_matrix(g, data_sizes=sizes)
    tree = _tree(rng)
    self_w, nbr_idx, nbr_w, beta_p = cm_ops.sparse_from_matrices(w, beta)
    got_m, got_d = cm_ops.consensus_mix_stacked(
        tree, self_w, nbr_idx, nbr_w, beta_p, T
    )
    want_m, want_d = _dense_reference(w, beta, tree)
    _assert_parity(got_m, want_m)
    _assert_parity(got_d, want_d)


def _schedule(name, rounds=6, seed=0):
    base = gl.build_graph("ring", K)
    if name == "link_dropout":
        return gl.link_dropout_schedule(base, 0.6, rounds, seed=seed)
    if name == "random_matching":
        return gl.random_matching_schedule(K, rounds, seed=seed)
    return gl.peer_churn_schedule(base, 0.5, rounds, seed=seed)


@pytest.mark.parametrize("name", ["link_dropout", "random_matching", "peer_churn"])
def test_schedule_parity_every_round(name, rng):
    """One padded shape serves all rounds; each round matches the dense path."""
    sched = _schedule(name)
    sizes = rng.integers(1, 50, K)
    w_stack, beta_stack = gl.schedule_matrices(sched, "data_weighted", data_sizes=sizes)
    self_w, nbr_idx, nbr_w, beta_p = cm_ops.sparse_from_schedule(w_stack, beta_stack)
    assert self_w.shape == (sched.period, K)
    assert nbr_idx.shape[-1] == max(sched.max_degree(), 1)
    tree = _tree(rng)
    for r in range(sched.period):
        got_m, got_d = cm_ops.consensus_mix_stacked(
            tree, self_w[r], nbr_idx[r], nbr_w[r], beta_p[r], T
        )
        want_m, want_d = _dense_reference(w_stack[r], beta_stack[r], tree)
        _assert_parity(got_m, want_m)
        _assert_parity(got_d, want_d)


def test_degree0_churned_out_peer(rng):
    """Offline peers keep their params exactly and get a zero d bias."""
    sched = _schedule("peer_churn", rounds=8, seed=3)
    degs = np.stack([g.degree() for g in sched.graphs])
    assert (degs == 0).any(), "fixture must contain a churned-out peer"
    w_stack, beta_stack = gl.schedule_matrices(sched, "data_weighted")
    self_w, nbr_idx, nbr_w, beta_p = cm_ops.sparse_from_schedule(w_stack, beta_stack)
    tree = _tree(rng)
    for r in range(sched.period):
        off = np.nonzero(degs[r] == 0)[0]
        if not len(off):
            continue
        got_m, got_d = cm_ops.consensus_mix_stacked(
            tree, self_w[r], nbr_idx[r], nbr_w[r], beta_p[r], T
        )
        for key in tree:
            np.testing.assert_allclose(
                np.asarray(got_m[key])[off], np.asarray(tree[key])[off], atol=1e-6
            )
            np.testing.assert_allclose(np.asarray(got_d[key])[off], 0.0, atol=0.0)


def test_consensus_mix_schedule_traced_round_idx(rng):
    """The jitted wrapper selects the round inside the traced program."""
    sched = _schedule("link_dropout")
    w_stack, beta_stack = gl.schedule_matrices(sched, "metropolis")
    sparse = cm_ops.sparse_from_schedule(w_stack, beta_stack)
    tree = _tree(rng)

    @jax.jit
    def step(tree, round_idx):
        return cm_ops.consensus_mix_schedule(tree, round_idx, *sparse, T)

    for r in [0, 3, sched.period, 2 * sched.period + 1]:
        got_m, got_d = step(tree, jnp.asarray(r, jnp.int32))
        want_m, want_d = _dense_reference(
            w_stack[r % sched.period], beta_stack[r % sched.period], tree
        )
        _assert_parity(got_m, want_m)
        _assert_parity(got_d, want_d)


# ---------------------------------------------------------------------------
# Push-sum: the kernel path carries the appended mass scalar
# ---------------------------------------------------------------------------


def _directed_schedule(name, rounds=5, seed=2):
    if name == "one_way_matching":
        return gl.one_way_matching_schedule(K, rounds, seed=seed)
    if name == "directed_dropout":
        return gl.link_dropout_schedule(
            gl.build_graph("directed_ring", K), 0.6, rounds, seed=seed
        )
    return gl.static_schedule(gl.build_graph("directed_ring", K))


@pytest.mark.parametrize("name", ["directed_ring", "one_way_matching", "directed_dropout"])
def test_push_sum_kernel_parity_every_round(name, rng):
    """consensus_mix_push_sum_* == the dense PushSumProtocol.mix + the d bias
    of the de-biased params, on every round of a directed schedule, while
    conserving sum_k y_k == K."""
    sched = _directed_schedule(name)
    sizes = rng.integers(1, 50, K)
    proto = protocols.get_protocol("push_sum")
    consts_np = proto.constants(sched, "data_weighted", data_sizes=sizes)
    sparse = cm_ops.sparse_from_schedule(consts_np.w, consts_np.beta)
    tree = _tree(rng)
    mass = proto.init_state(tree, sizes).mass
    for r in range(sched.period):
        consts = protocols.round_constants(
            protocols.ProtocolConstants(
                jnp.asarray(consts_np.w, jnp.float32),
                jnp.asarray(consts_np.beta, jnp.float32),
            ),
            r,
        )
        want_state, want_m = proto.mix(protocols.PushSumState(mass=mass), tree, consts)
        _, want_d = _dense_reference(consts_np.w[r], consts_np.beta[r], tree)
        got_m, got_d, got_mass = cm_ops.consensus_mix_push_sum_schedule(
            tree, mass, jnp.asarray(r, jnp.int32), *sparse, T
        )
        _assert_parity(got_m, want_m)
        _assert_parity(got_d, want_d)
        np.testing.assert_allclose(
            np.asarray(got_mass), np.asarray(want_state.mass), atol=1e-5
        )
        np.testing.assert_allclose(float(got_mass.sum()), K, rtol=1e-5)
        tree, mass = got_m, got_mass
