"""Optimization variants must be numerically equivalent to their baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import graph as gl
from repro.launch import steps as steps_lib
from repro.sharding import specs


def test_psum_consensus_equals_einsum_uniform_complete(rng):
    for k in (2, 4):
        g = gl.build_graph("complete", k)
        w = gl.mixing_matrix(g, "data_weighted", data_sizes=np.ones(k))
        beta = gl.affinity_matrix(g)
        tree = {"a": jnp.asarray(rng.normal(size=(k, 6, 5)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(k, 3)), jnp.float32)}
        d0 = jax.tree.map(jnp.zeros_like, tree)
        f_ein = steps_lib.make_consensus_step(w, beta, local_steps=10, use_affinity=True)
        f_psum = steps_lib.make_consensus_step_psum(
            k, self_weight=float(w[0, 0]), peer_weight=float(w[0, 1]),
            local_steps=10, use_affinity=True,
        )
        m1, d1 = f_ein(tree, d0)
        m2, d2 = f_psum(tree, d0)
        for key in tree:
            np.testing.assert_allclose(np.asarray(m1[key]), np.asarray(m2[key]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(d1[key]), np.asarray(d2[key]), atol=1e-5)


def test_cache_layout_specs():
    names = ["main", "k"]
    heads = specs.cache_leaf_spec(names, 4, layout="heads")
    seq = specs.cache_leaf_spec(names, 4, layout="seq")
    assert heads == P("data", None, "model", None)
    assert seq == P("data", "model", None, None)
    # MLA latent cache
    assert specs.cache_leaf_spec(["c_kv"], 3, layout="seq") == P("data", "model", None)


def test_mla_absorb_equals_expanded_decode(rng):
    """mla_absorb=True decode logits == the expanded path (same params)."""
    from repro.configs import get_config, reduced
    from repro.models import build_model

    cfg = reduced(get_config("deepseek-v2-236b"))
    cfg_abs = cfg.replace(attention=dataclasses.replace(cfg.attention, mla_absorb=True))
    m = build_model(cfg)
    m_abs = build_model(cfg_abs)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(jax.random.PRNGKey(1), 2, 8)
    cache = m.init_cache(2, 12)
    _, cache = m.prefill(params, batch, cache)
    cache2 = jax.tree.map(lambda x: x, cache)
    tok = jnp.asarray([3, 5], jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    logits1, _ = m.decode_step(params, tok, pos, cache)
    logits2, _ = m_abs.decode_step(params, tok, pos, cache2)
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2), atol=2e-3, rtol=2e-3)


def test_sliding_window_ring_buffer_decode(rng):
    """Window-cache decode == full-cache decode when history fits the window,
    and stays finite/correct beyond it."""
    from repro.configs import get_config, reduced
    from repro.models import build_model

    base = reduced(get_config("minitron-8b"))
    win = base.replace(attention=dataclasses.replace(base.attention, sliding_window=8))
    m_full, m_win = build_model(base), build_model(win)
    params = m_full.init(jax.random.PRNGKey(0))
    batch = m_full.make_batch(jax.random.PRNGKey(1), 1, 6)

    cache_f = m_full.init_cache(1, 32)
    cache_w = m_win.init_cache(1, 32)
    assert jax.tree.leaves(cache_w)[0].shape[2] == 8  # ring buffer = window
    lf, cache_f = m_full.prefill(params, batch, cache_f)
    lw, cache_w = m_win.prefill(params, batch, cache_w)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lw), atol=2e-3, rtol=2e-3)

    tok = jnp.asarray([1], jnp.int32)
    for i in range(12):  # run decode past the window size
        pos = jnp.full((1,), 6 + i, jnp.int32)
        lgf, cache_f = m_full.decode_step(params, tok, pos, cache_f)
        lgw, cache_w = m_win.decode_step(params, tok, pos, cache_w)
        assert np.isfinite(np.asarray(lgw)).all()
        if 6 + i < 8:  # history still inside the window: exact match
            np.testing.assert_allclose(np.asarray(lgf), np.asarray(lgw), atol=2e-3, rtol=2e-3)
        tok = jnp.argmax(lgw[:, -1], -1).astype(jnp.int32)


def test_int8_kv_cache_close_to_fp(rng):
    """int8 cache decode logits ~= fp cache decode logits."""
    from repro.configs import get_config, reduced
    from repro.models import build_model

    base = reduced(get_config("minitron-8b"))
    q8 = base.replace(attention=dataclasses.replace(base.attention, cache_quant="int8"))
    m_fp, m_q8 = build_model(base), build_model(q8)
    params = m_fp.init(jax.random.PRNGKey(0))
    batch = m_fp.make_batch(jax.random.PRNGKey(1), 2, 8)
    c_fp = m_fp.init_cache(2, 12)
    c_q8 = m_q8.init_cache(2, 12)
    assert jax.tree.leaves({"k": c_q8["main"]["k"]})[0].dtype == jnp.int8
    l_fp, c_fp = m_fp.prefill(params, batch, c_fp)
    l_q8, c_q8 = m_q8.prefill(params, batch, c_q8)
    np.testing.assert_allclose(np.asarray(l_fp), np.asarray(l_q8), atol=0.05, rtol=0.05)
    tok = jnp.asarray([1, 2], jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    d_fp, _ = m_fp.decode_step(params, tok, pos, c_fp)
    d_q8, _ = m_q8.decode_step(params, tok, pos, c_q8)
    np.testing.assert_allclose(np.asarray(d_fp), np.asarray(d_q8), atol=0.05, rtol=0.05)
    # halved cache bytes
    bytes_fp = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_fp))
    bytes_q8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c_q8))
    assert bytes_q8 < 0.65 * bytes_fp
