"""Regression guards for the paper's headline claim (Figs. 3 & 6).

Small deterministic runs on synthetic non-IID MNIST, K=2, fixed seeds:
P2PL-with-Affinity damps the consensus sawtooth relative to local DSGD.
Kept fast (~12 rounds, reduced data) so it rides in tier-1, not `slow`.
"""
import dataclasses

import numpy as np

from repro.configs.p2pl_mnist import noniid_k2, timevarying_k2
from repro.core import p2p
from repro.launch.train import run_paper_experiment

ROUNDS = 12


def _run(exp, data):
    return run_paper_experiment(exp, rounds=ROUNDS, data=data, seed=0)


def test_affinity_damps_oscillation_below_local_dsgd(mnist_small):
    # Fig. 6 configuration: the 10-class split (5 classes per device), where
    # the sawtooth is largest and the affinity damping is unambiguous at
    # reduced scale.  eta_d=0.5: stable for K=2 full averaging
    # (EXPERIMENTS.md observation O1).
    def fig6_exp(algo, eta_d):
        exp = noniid_k2(algorithm=algo, local_steps=10)
        return dataclasses.replace(
            exp,
            peer_classes=((0, 1, 2, 3, 4), (5, 6, 7, 8, 9)),
            samples_per_class=100,
            p2p=dataclasses.replace(exp.p2p, eta_d=eta_d),
        )

    log_plain = _run(fig6_exp("local_dsgd", 0.0), mnist_small)
    log_aff = _run(fig6_exp("p2pl_affinity", 0.5), mnist_small)

    # device A's accuracy on its unseen classes, both phase boundaries
    def osc(log):
        a = np.stack(log.after_local["peer1_seen"])[:, 0]
        c = np.stack(log.after_consensus["peer1_seen"])[:, 0]
        return float(p2p.oscillation_amplitude(a, c).mean())

    assert osc(log_aff) < osc(log_plain), (
        f"affinity oscillation {osc(log_aff):.4f} must be strictly below "
        f"local DSGD {osc(log_plain):.4f}"
    )
    # sanity: local DSGD on disjoint classes genuinely oscillates
    assert osc(log_plain) > 0.02


def test_timevarying_run_completes_and_measures(mnist_small):
    """A link_dropout schedule runs end-to-end through run_paper_experiment
    (single jitted round fn) and still produces the paper's instruments."""
    exp = timevarying_k2(schedule="link_dropout", algorithm="local_dsgd",
                         local_steps=10,
                         schedule_rounds=8, link_survival_prob=0.6)
    log = _run(exp, mnist_small)
    assert len(log.after_consensus["all"]) == ROUNDS
    assert np.isfinite(log.train_loss).all()
    assert 0.0 <= log.final_accuracy("all") <= 1.0
    # dropped-link rounds skip consensus: oscillation can't exceed static's
    # round count and the series stays well-formed
    assert log.oscillation("peer1_seen").shape == (ROUNDS,)
