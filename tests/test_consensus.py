"""Consensus operators: stacked einsum vs sparse gather vs mesh collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as cl
from repro.core import graph as gl


def _tree(rng, k):
    return {
        "w": jnp.asarray(rng.normal(size=(k, 5, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(k, 7)), jnp.float32),
    }


def test_mix_stacked_matches_numpy(rng):
    k = 6
    g = gl.build_graph("ring", k)
    w = gl.mixing_matrix(g, "metropolis")
    tree = _tree(rng, k)
    out = cl.mix_stacked(jnp.asarray(w, jnp.float32), tree)
    for key in tree:
        want = np.einsum("kj,j...->k...", w, np.asarray(tree[key]))
        np.testing.assert_allclose(out[key], want, atol=1e-5)


@pytest.mark.parametrize("topo", ["ring", "star", "complete", "erdos_renyi"])
def test_mix_sparse_equals_dense(rng, topo):
    k = 8
    g = gl.build_graph(topo, k)
    w = gl.mixing_matrix(g, "data_weighted", data_sizes=rng.integers(1, 50, k))
    tree = _tree(rng, k)
    self_w, idx, nbr_w = cl.sparse_mixing(w)
    dense = cl.mix_stacked(jnp.asarray(w, jnp.float32), tree)
    sparse = cl.mix_sparse(jnp.asarray(self_w), jnp.asarray(idx), jnp.asarray(nbr_w), tree)
    for key in tree:
        np.testing.assert_allclose(sparse[key], dense[key], atol=1e-5)


def test_mix_psum_under_vmap_axis(rng):
    """Complete-graph psum form == dense mixing (peer axis via vmap axis_name)."""
    k = 4
    g = gl.build_graph("complete", k)
    w = gl.mixing_matrix(g, "uniform_neighbor")
    self_w, peer_w = w[0, 0], w[0, 1]
    tree = _tree(rng, k)

    def per_peer(x):
        return cl.mix_psum(x, "peer", self_weight=self_w, peer_weight=peer_w)

    out = jax.vmap(per_peer, axis_name="peer")(tree)
    want = cl.mix_stacked(jnp.asarray(w, jnp.float32), tree)
    for key in tree:
        np.testing.assert_allclose(out[key], want[key], atol=1e-5)


def test_mix_ring_under_vmap_axis(rng):
    k = 5
    g = gl.build_graph("ring", k)
    w = gl.mixing_matrix(g, "uniform_neighbor")
    tree = _tree(rng, k)

    def per_peer(x):
        return cl.mix_ring(
            x, "peer",
            self_weight=w[0, 0], left_weight=w[0, k - 1], right_weight=w[0, 1],
        )

    out = jax.vmap(per_peer, axis_name="peer")(tree)
    want = cl.mix_stacked(jnp.asarray(w, jnp.float32), tree)
    for key in tree:
        np.testing.assert_allclose(out[key], want[key], atol=1e-5)


@pytest.mark.mesh
@pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
def test_mix_psum_under_real_mesh(rng):
    """Complete-graph psum == dense mixing with the peer axis on a REAL mesh
    (shard_map), not a vmap-faked axis_name."""
    from jax.sharding import PartitionSpec as P

    from repro.core.p2p import _shard_map_fn
    from repro.launch.mesh import make_peer_mesh

    k = 4
    g = gl.build_graph("complete", k)
    w = gl.mixing_matrix(g, "uniform_neighbor")
    tree = _tree(rng, k)
    mesh = make_peer_mesh(k)
    shard_map = _shard_map_fn()

    fn = jax.jit(
        shard_map(
            lambda x: cl.mix_psum(x, "pod", self_weight=w[0, 0], peer_weight=w[0, 1]),
            mesh=mesh,
            in_specs=({"w": P("pod", None, None), "b": P("pod", None)},),
            out_specs={"w": P("pod", None, None), "b": P("pod", None)},
        )
    )
    out = fn(tree)
    want = cl.mix_stacked(jnp.asarray(w, jnp.float32), tree)
    for key in tree:
        np.testing.assert_allclose(np.asarray(out[key]), np.asarray(want[key]), atol=1e-5)


@pytest.mark.mesh
@pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
def test_mix_ring_under_real_mesh(rng):
    """Ring gossip's two collective-permutes == dense mixing on a real mesh."""
    from jax.sharding import PartitionSpec as P

    from repro.core.p2p import _shard_map_fn
    from repro.launch.mesh import make_peer_mesh

    k = 4
    g = gl.build_graph("ring", k)
    w = gl.mixing_matrix(g, "uniform_neighbor")
    tree = _tree(rng, k)
    mesh = make_peer_mesh(k)
    shard_map = _shard_map_fn()

    fn = jax.jit(
        shard_map(
            lambda x: cl.mix_ring(
                x, "pod",
                self_weight=w[0, 0], left_weight=w[0, k - 1], right_weight=w[0, 1],
            ),
            mesh=mesh,
            in_specs=({"w": P("pod", None, None), "b": P("pod", None)},),
            out_specs={"w": P("pod", None, None), "b": P("pod", None)},
        )
    )
    out = fn(tree)
    want = cl.mix_stacked(jnp.asarray(w, jnp.float32), tree)
    for key in tree:
        np.testing.assert_allclose(np.asarray(out[key]), np.asarray(want[key]), atol=1e-5)


@pytest.mark.mesh
@pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
@pytest.mark.parametrize("topo", ["ring", "star", "erdos_renyi", "directed_ring"])
def test_gather_peer_rows_under_real_mesh(rng, topo):
    """Lane-gathered neighbor rows match the stacked array on edge positions
    and are zero elsewhere — on a real mesh, for every lane decomposition."""
    from jax.sharding import PartitionSpec as P

    from repro.core.p2p import _shard_map_fn
    from repro.launch.mesh import make_peer_mesh

    k = 4
    g = gl.build_graph(topo, k)
    lanes = gl.edge_color_lanes(g.adjacency)
    x = jnp.asarray(rng.normal(size=(k, 3)), jnp.float32)
    mesh = make_peer_mesh(k)
    shard_map = _shard_map_fn()

    fn = jax.jit(
        shard_map(
            lambda v: cl.gather_peer_rows(v, "pod", lanes, k)[None],
            mesh=mesh,
            in_specs=(P("pod", None),),
            out_specs=P("pod", None, None),
        )
    )
    full = np.asarray(fn(x))  # (K, K, 3): per-peer reconstruction
    for dst in range(k):
        want = np.zeros((k, 3), np.float32)
        srcs = list(g.in_neighbors(dst)) + [dst]
        want[srcs] = np.asarray(x)[srcs]
        np.testing.assert_array_equal(full[dst], want)


def test_max_norm_sync_picks_largest(rng):
    k = 4
    tree = {"w": jnp.asarray(rng.normal(size=(k, 6)), jnp.float32)}
    tree["w"] = tree["w"].at[2].mul(10.0)  # peer 2 has the largest norm
    out = cl.max_norm_sync(tree)
    for i in range(k):
        np.testing.assert_allclose(out["w"][i], tree["w"][2])


def test_consensus_error_and_drift(rng):
    k = 3
    same = {"w": jnp.ones((k, 4), jnp.float32)}
    assert float(cl.consensus_error(same)) < 1e-6
    assert float(cl.pairwise_drift(same)) < 1e-3
    tree = _tree(rng, k)
    assert float(cl.consensus_error(tree)) > 0.1
    # mixing with a complete graph reduces drift
    g = gl.build_graph("complete", k)
    w = jnp.asarray(gl.mixing_matrix(g, "uniform_neighbor"), jnp.float32)
    mixed = cl.mix_stacked(w, tree)
    assert float(cl.pairwise_drift(mixed)) < float(cl.pairwise_drift(tree))


def test_repeated_mixing_converges_to_average(rng):
    k = 8
    g = gl.build_graph("ring", k)
    w = jnp.asarray(gl.mixing_matrix(g, "metropolis"), jnp.float32)
    tree = _tree(rng, k)
    avg = {key: np.asarray(tree[key]).mean(0) for key in tree}
    x = tree
    for _ in range(500):
        x = cl.mix_stacked(w, x)
    for key in tree:
        np.testing.assert_allclose(
            np.asarray(x[key]), np.broadcast_to(avg[key], x[key].shape), atol=1e-3
        )
