"""The scanned multi-round driver (``p2p.make_scan_driver``).

Contract under test (the acceptance criteria of the fused round loop):

* **Parity** — leaf-for-leaf fp32 BIT-identity (``np.array_equal``) with the
  python-loop driver for both protocols on static + round_robin schedules:
  final state, last after-local state, and the stacked per-round losses.
* **One compile** — a chunked run of many rounds traces the loss exactly once
  (value+grad share the trace), however many chunks are driven.
* **Donation** — ``donate_argnums`` consumes the input ``P2PState``: its
  buffers are deleted after the call (reused in place for the output state).

The vmap-runtime cases run everywhere (tier-1); the pod-runtime parity lives
in tests/test_mesh_runtime.py under the ``mesh`` marker.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import p2p

K = 4
T = 3
CHUNK = 3


def _init_fn(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (6, 16)),
        "b1": jnp.zeros((16,)),
        "w2": jax.random.normal(k2, (16, 4)),
    }


def _mlp_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return jnp.mean(jnp.sum(jnp.square(h @ p["w2"] - y), axis=-1))


def _cfg(protocol: str, schedule: str) -> p2p.P2PConfig:
    extra = {}
    if schedule == "round_robin":
        extra["round_robin_topologies"] = ("ring", "star")
    return p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=T,
        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
        topology="ring", protocol=protocol, schedule=schedule,
        schedule_rounds=2, **extra,
    )


def _chunk_batches(rng, chunks: int):
    x = jnp.asarray(rng.normal(size=(chunks, CHUNK, T, K, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(chunks, CHUNK, T, K, 10, 4)), jnp.float32)
    return x, y


def _assert_trees_equal(want, got, context: str):
    want_leaves = jax.tree_util.tree_leaves_with_path(want)
    got_leaves = jax.tree_util.tree_leaves_with_path(got)
    assert len(want_leaves) == len(got_leaves)
    for (path, w), (_, g) in zip(want_leaves, got_leaves):
        assert np.array_equal(np.asarray(w), np.asarray(g)), (
            f"{context} leaf {jax.tree_util.keystr(path)} diverged: "
            f"max |diff| = "
            f"{np.abs(np.asarray(w, np.float64) - np.asarray(g, np.float64)).max():.3e}"
        )


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("schedule", ["static", "round_robin"])
def test_scan_driver_bit_identical_to_python_loop(protocol, schedule):
    """Two scan chunks (crossing the schedule period) == 2*CHUNK python-loop
    rounds, bit for bit on every leaf, losses included."""
    cfg = _cfg(protocol, schedule)
    sizes = np.arange(1, K + 1)
    state0 = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    round_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    drive_fn = p2p.make_scan_driver(_mlp_loss, cfg, data_sizes=sizes, donate=False)

    x, y = _chunk_batches(np.random.default_rng(0), chunks=2)
    s_py, losses_py, al_py = state0, [], None
    for c in range(2):
        for r in range(CHUNK):
            al_py, s_py, loss_r = round_fn(s_py, (x[c, r], y[c, r]))
            losses_py.append(np.asarray(loss_r))

    s_scan, al_scan, losses_scan = state0, None, []
    for c in range(2):
        al_scan, s_scan, loss_c = drive_fn(s_scan, (x[c], y[c]))
        losses_scan.append(np.asarray(loss_c))

    _assert_trees_equal(s_py, s_scan, f"{protocol}/{schedule} final state")
    _assert_trees_equal(al_py, al_scan, f"{protocol}/{schedule} after_local")
    assert np.array_equal(np.stack(losses_py), np.concatenate(losses_scan))
    assert int(s_scan.round_idx) == 2 * CHUNK


def test_scan_driver_compiles_once():
    """Many chunks of a time-varying schedule: the loss traces once (value and
    grad share one forward), i.e. ONE compile covers the whole run."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = _cfg("gossip", "round_robin")
    state = p2p.init_state(jax.random.PRNGKey(1), _init_fn, cfg)
    drive_fn = p2p.make_scan_driver(counting_loss, cfg)
    x, y = _chunk_batches(np.random.default_rng(1), chunks=4)
    for c in range(4):
        _, state, losses = drive_fn(state, (x[c], y[c]))
    assert int(state.round_idx) == 4 * CHUNK
    assert np.isfinite(np.asarray(losses)).all()
    assert traces[0] <= 2  # value + grad trace of the single compile
    # the jit cache agrees: ONE entry serves the whole run
    assert drive_fn._cache_size() == 1


def test_scan_driver_donates_input_state():
    """donate_argnums on the input P2PState: the caller's buffers are consumed
    (reused in place), so touching the donated input must fail."""
    cfg = _cfg("push_sum", "static")
    state = p2p.init_state(jax.random.PRNGKey(2), _init_fn, cfg)
    drive_fn = p2p.make_scan_driver(_mlp_loss, cfg)
    x, y = _chunk_batches(np.random.default_rng(2), chunks=1)
    _, final, _ = drive_fn(state, (x[0], y[0]))
    deleted = [leaf.is_deleted() for leaf in jax.tree.leaves(state)]
    assert all(deleted), (
        f"{deleted.count(False)}/{len(deleted)} input-state buffers survived "
        "the donated call"
    )
    # ... and the returned state is usable in the donated slot's place
    _, final2, _ = drive_fn(final, (x[0], y[0]))
    assert int(final2.round_idx) == 2 * CHUNK


def test_scan_driver_donation_opt_out():
    """donate=False keeps the input alive (the parity tests rely on it)."""
    cfg = _cfg("gossip", "static")
    state = p2p.init_state(jax.random.PRNGKey(3), _init_fn, cfg)
    drive_fn = p2p.make_scan_driver(_mlp_loss, cfg, donate=False)
    x, y = _chunk_batches(np.random.default_rng(3), chunks=1)
    drive_fn(state, (x[0], y[0]))
    assert not any(leaf.is_deleted() for leaf in jax.tree.leaves(state))


def test_scan_driver_losses_shape_and_metrics():
    """The stacked (C, T) losses are the driver's per-round metric surface:
    one device_get per chunk replaces two per round."""
    cfg = _cfg("gossip", "static")
    state = p2p.init_state(jax.random.PRNGKey(4), _init_fn, cfg)
    drive_fn = p2p.make_scan_driver(_mlp_loss, cfg)
    x, y = _chunk_batches(np.random.default_rng(4), chunks=1)
    after_local, final, losses = drive_fn(state, (x[0], y[0]))
    assert losses.shape == (CHUNK, T)
    # after_local is the LAST round's post-local-phase state: one local phase
    # ahead of the final (post-consensus) state's round counter
    assert int(final.round_idx) - int(after_local.round_idx) == 1
