"""Per-architecture smoke tests (REDUCED variants of the same family):
one forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, reduced
from repro.models import build_model

ARCHS = sorted(ARCHITECTURES)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(get_config(name))
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    batch = model.make_batch(jax.random.PRNGKey(1), 2, 16)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch} bad grads"
    # SGD step changes params and keeps loss finite
    new = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2 = model.loss_fn(new, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, built):
    """Prefill(S) last-token logits == prefill(S-1) + decode_step(token S-1)."""
    cfg, model, params = built(arch)
    s = 8
    rng = jax.random.PRNGKey(2)
    batch = model.make_batch(rng, 2, s + 1)

    full_cache = model.init_cache(2, s + 1)
    logits_full, _ = model.prefill(params, batch, full_cache)

    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :-1]
    short["labels"] = batch["labels"][:, :-1]
    cache = model.init_cache(2, s + 1)
    _, cache = model.prefill(params, short, cache)
    last_tok = batch["tokens"][:, -1]
    # absolute decode position = decoder-side length so far (incl. vlm prefix)
    dec_len = short["tokens"].shape[1]
    if "patches" in batch:
        dec_len += batch["patches"].shape[1]
    pos = jnp.full((2,), dec_len, jnp.int32)
    logits_step, _ = model.decode_step(params, last_tok, pos, cache)

    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1]), np.asarray(logits_step[:, -1]),
        atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("arch", ["minitron-8b", "deepseek-v2-236b", "rwkv6-7b", "zamba2-2.7b"])
def test_multi_token_decode_consistency(arch, built):
    """Greedy decode token-by-token == teacher-forced prefill logits argmax."""
    cfg, model, params = built(arch)
    s = 8
    batch = model.make_batch(jax.random.PRNGKey(3), 1, s)
    cache = model.init_cache(1, s + 4)
    _, cache = model.prefill(params, batch, cache)
    dec_len = batch["tokens"].shape[1]
    if "patches" in batch:
        dec_len += batch["patches"].shape[1]
    tok = jnp.zeros((1,), jnp.int32)
    for i in range(3):
        logits, cache = model.decode_step(
            params, tok, jnp.full((1,), dec_len + i, jnp.int32), cache
        )
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)


def test_full_configs_param_counts():
    """Analytic param counts are in the advertised ballpark."""
    expect = {
        "rwkv6-7b": (6e9, 9e9),
        "minitron-8b": (7e9, 10e9),
        "deepseek-v2-236b": (180e9, 260e9),
        "phi4-mini-3.8b": (3e9, 5e9),
        "zamba2-2.7b": (2e9, 3.5e9),
        "qwen1.5-32b": (28e9, 36e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "internvl2-2b": (1.5e9, 2.6e9),
        "smollm-135m": (0.1e9, 0.18e9),
        "seamless-m4t-medium": (0.5e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < total * 0.2  # ~22B active of ~235B
    assert 15e9 <= active <= 30e9


def test_sliding_window_variant_for_long_ctx():
    from repro.configs import INPUT_SHAPES, for_shape

    cfg = for_shape(get_config("minitron-8b"), INPUT_SHAPES["long_500k"])
    assert cfg.attention.sliding_window == 4096
    cfg2 = for_shape(get_config("rwkv6-7b"), INPUT_SHAPES["long_500k"])
    assert cfg2.ssm is not None  # native, unchanged
    cfg3 = for_shape(get_config("minitron-8b"), INPUT_SHAPES["train_4k"])
    assert cfg3.attention.sliding_window is None
