"""Compressed gossip subsystem (``repro.compression`` + the runtimes).

Contract under test (the acceptance criteria of the compression PR):

* **Registry + config** — the three built-in compressors resolve by name,
  unknown names / out-of-range ``topk_frac`` fail loudly at config time.
* **Compressor semantics** — top-k keeps exactly ``keep(n)`` largest-|.|
  coordinates bit for bit (frac=1.0 is lossless), qint8's per-coordinate
  error is bounded by ``scale / 2``, zero inputs are safe.
* **Error feedback** — estimate tracking converges the public estimate onto
  a static target; the warm start makes the first payload exactly zero
  drift.
* **Runtimes** — ``compressor="none"`` takes the EXACT uncompressed code
  path (structural bypass, not numerical luck); compressed rounds stay
  finite and contract consensus error across protocol x schedule (adaptive
  included); push-sum mass conservation is exact under compression; the
  scan driver is bit-identical to the python loop and compiles once.
* **Guards** — the hierarchical (peers_per_device > 1) runtime and the CLI
  reject compressed / adaptive combinations with actionable errors.
* **Kernel** — the fused dequantize-and-mix Pallas kernel is allclose to
  its dense oracle, honors the no-neighbor guard, and the schedule entry
  compiles once.

The vmap-runtime cases run everywhere (tier-1); the pod-vs-vmap compressed
parity needs one device per peer and carries the ``mesh`` marker.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compression as compression_lib
from repro.core import consensus as cl
from repro.core import p2p
from repro.kernels.consensus_mix import dequant
from repro.kernels.consensus_mix import ops as cm_ops
from repro.kernels.consensus_mix import ref as cm_ref

K = 4
T = 3


def _init_fn(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (6, 16)),
        "b1": jnp.zeros((16,)),
        "w2": jax.random.normal(k2, (16, 4)),
    }


def _mlp_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return jnp.mean(jnp.sum(jnp.square(h @ p["w2"] - y), axis=-1))


def _cfg(compressor="none", protocol="gossip", schedule="static",
         num_peers=K, topk_frac=0.25):
    extra = {}
    if schedule == "round_robin":
        extra["round_robin_topologies"] = ("ring", "star")
    return p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=num_peers, local_steps=T,
        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
        topology="ring", protocol=protocol, schedule=schedule,
        schedule_rounds=2, compressor=compressor, topk_frac=topk_frac,
        **extra,
    )


def _round_batches(rng, t, k=K):
    x = jnp.asarray(rng.normal(size=(t, k, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(t, k, 10, 4)), jnp.float32)
    return (x, y)


def _assert_trees_equal(want, got, context):
    want_leaves = jax.tree_util.tree_leaves_with_path(want)
    got_leaves = jax.tree_util.tree_leaves_with_path(got)
    assert len(want_leaves) == len(got_leaves)
    for (path, w), (_, g) in zip(want_leaves, got_leaves):
        assert np.array_equal(np.asarray(w), np.asarray(g)), (
            f"{context} leaf {jax.tree_util.keystr(path)} diverged"
        )


# ---------------------------------------------------------------------------
# registry + config validation
# ---------------------------------------------------------------------------


def test_registry_has_builtins():
    assert set(compression_lib.compressor_names()) >= {"none", "topk", "qint8"}


def test_get_unknown_compressor_raises():
    with pytest.raises(ValueError, match="unknown compressor"):
        compression_lib.get_compressor("gzip")


def test_register_duplicate_raises():
    with pytest.raises(ValueError, match="already registered"):
        compression_lib.register_compressor(compression_lib.TopKCompressor)


@pytest.mark.parametrize("frac", [0.0, -0.1, 1.5])
def test_topk_frac_out_of_range(frac):
    with pytest.raises(ValueError, match="frac"):
        compression_lib.TopKCompressor(frac)
    with pytest.raises(ValueError, match="topk_frac"):
        _cfg(compressor="topk", topk_frac=frac)


def test_config_rejects_unknown_compressor():
    with pytest.raises(ValueError, match="compressor"):
        _cfg(compressor="gzip")


def test_from_config_resolves_frac():
    comp = compression_lib.from_config(_cfg(compressor="topk", topk_frac=0.5))
    assert isinstance(comp, compression_lib.TopKCompressor)
    assert comp.frac == 0.5
    assert not comp.identity
    assert compression_lib.from_config(_cfg()).identity


# ---------------------------------------------------------------------------
# compressor semantics
# ---------------------------------------------------------------------------


def test_topk_keeps_exact_count_and_largest(rng):
    comp = compression_lib.TopKCompressor(0.25)
    leaf = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
    payload = comp.compress(leaf)
    assert payload.values.shape == (2, 4)  # keep(16) = 4
    flat = np.asarray(leaf)
    for row in range(2):
        kept = set(np.asarray(payload.indices)[row].tolist())
        order = np.argsort(-np.abs(flat[row]))
        assert kept == set(order[:4].tolist())
        # kept coordinates round-trip bit for bit
        dec = np.asarray(comp.decompress(payload, leaf))
        for i in kept:
            assert dec[row, i] == flat[row, i]


def test_topk_frac_one_is_lossless(rng):
    comp = compression_lib.TopKCompressor(1.0)
    leaf = jnp.asarray(rng.normal(size=(3, 4, 5)), jnp.float32)
    out = comp.decompress(comp.compress(leaf), leaf)
    assert np.array_equal(np.asarray(out), np.asarray(leaf))


def test_topk_keep_floor_is_one():
    assert compression_lib.TopKCompressor(0.01).keep(3) == 1


def test_qint8_error_bounded_by_half_scale(rng):
    comp = compression_lib.QInt8Compressor()
    leaf = jnp.asarray(rng.normal(size=(3, 64)) * 10.0, jnp.float32)
    payload = comp.compress(leaf)
    out = np.asarray(comp.decompress(payload, leaf)).reshape(3, -1)
    err = np.abs(out - np.asarray(leaf).reshape(3, -1))
    bound = np.asarray(payload.scale) / 2.0 + 1e-7
    assert (err <= bound).all()


def test_qint8_zero_leaf_safe():
    comp = compression_lib.QInt8Compressor()
    leaf = jnp.zeros((2, 8), jnp.float32)
    payload = comp.compress(leaf)
    assert np.asarray(payload.scale).max() == 0.0
    out = np.asarray(comp.decompress(payload, leaf))
    assert np.array_equal(out, np.zeros_like(out))


def test_estimate_warm_starts_at_params(key):
    params = jax.vmap(_init_fn)(jax.random.split(key, K))
    est = compression_lib.TopKCompressor(0.25).init_estimate(params)
    _assert_trees_equal(params, est, "warm-start estimate")
    assert compression_lib.NoneCompressor().init_estimate(params) == ()


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["topk", "qint8"])
def test_ef_estimate_converges_on_static_target(name, rng):
    """Iterating C(x - x̂) shrinks ||x - x̂|| toward 0: the dropped signal
    re-enters every step (EF conservation)."""
    comp = compression_lib.get_compressor(name, topk_frac=0.2)
    x = jnp.asarray(rng.normal(size=(2, 40)), jnp.float32)
    est = jnp.zeros_like(x)
    errs = []
    for _ in range(60):
        _, est = compression_lib.ef_compress_leaf(comp, x, est)
        errs.append(float(jnp.max(jnp.abs(x - est))))
    assert errs[-1] < 1e-3 * errs[0]
    assert errs[-1] <= errs[0]


def test_ef_first_payload_is_zero_after_warm_start(key):
    """Warm start => the first difference x - x̂ is exactly zero; top-k ships
    zero values and the estimate does not move."""
    params = jax.vmap(_init_fn)(jax.random.split(key, K))
    comp = compression_lib.TopKCompressor(0.1)
    est = comp.init_estimate(params)
    payloads, est2 = compression_lib.ef_compress_tree(comp, params, est)
    for p in payloads:
        assert np.asarray(p.values).max() == 0.0
    _assert_trees_equal(est, est2, "estimate after zero payload")


# ---------------------------------------------------------------------------
# vmap runtime
# ---------------------------------------------------------------------------


def test_none_takes_uncompressed_code_path(monkeypatch):
    """compressor='none' is a STRUCTURAL bypass: the runtimes never touch the
    compression machinery, so fp32 bit-parity with the pre-compression
    runtime holds by construction.  A round with every compressor entry point
    booby-trapped must still run."""
    def boom(*a, **k):  # pragma: no cover - must never run
        raise AssertionError("compression machinery entered on the none path")

    monkeypatch.setattr(compression_lib.NoneCompressor, "compress", boom)
    monkeypatch.setattr(compression_lib.NoneCompressor, "decompress", boom)
    monkeypatch.setattr(compression_lib, "ef_compress_tree", boom)
    monkeypatch.setattr(
        compression_lib.compressors, "ef_compress_tree", boom, raising=False
    )
    cfg = _cfg()
    state = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg)
    assert state.compression == ()
    fn = p2p.make_round_fn(_mlp_loss, cfg)
    x, y = _round_batches(np.random.default_rng(0), T)
    _, state, losses = fn(state, (x, y))
    assert np.isfinite(np.asarray(losses)).all()


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("compressor", ["topk", "qint8"])
@pytest.mark.parametrize("schedule", ["static", "round_robin", "adaptive"])
def test_compressed_rounds_finite_and_contracting(protocol, compressor, schedule):
    """Compressed rounds run on every protocol x schedule (adaptive included),
    stay finite, and actually advance the carried estimate stack."""
    if schedule == "adaptive":
        cfg = p2p.P2PConfig(
            algorithm="p2pl_affinity", num_peers=K, local_steps=T,
            consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
            schedule="adaptive", protocol=protocol,
            compressor=compressor, topk_frac=0.25,
        )
    else:
        cfg = _cfg(compressor=compressor, protocol=protocol, schedule=schedule)
    sizes = np.arange(1, K + 1)
    state = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    est0 = jax.tree.map(np.asarray, state.compression)
    fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    rng = np.random.default_rng(1)
    for _ in range(3):
        x, y = _round_batches(rng, T)
        _, state, losses = fn(state, (x, y))
        assert np.isfinite(np.asarray(losses)).all()
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()
    moved = [
        not np.array_equal(a, np.asarray(b))
        for a, b in zip(jax.tree.leaves(est0), jax.tree.leaves(state.compression))
    ]
    assert any(moved), "estimate stack never advanced"


def test_compressed_consensus_error_contracts():
    """Gossiping with a compressed wire still pulls non-IID peers together:
    consensus error after compressed-only mixing (lr=0) shrinks."""
    cfg = dataclasses.replace(
        _cfg(compressor="topk", topk_frac=0.5), lr=0.0, momentum=0.0,
        consensus_steps=4, eta_d=0.0, eta_b=0.0, algorithm="p2pl",
    )
    state = p2p.init_state(jax.random.PRNGKey(2), _init_fn, cfg)
    # common-seed init starts at consensus: spread the peers apart first,
    # warm-starting the estimate stack on the spread values
    params = jax.vmap(_init_fn)(jax.random.split(jax.random.PRNGKey(22), K))
    comp = compression_lib.from_config(cfg)
    state = state._replace(params=params, compression=comp.init_estimate(params))
    err0 = float(cl.consensus_error(state.params))
    assert err0 > 0.0
    fn = p2p.make_round_fn(_mlp_loss, cfg)
    rng = np.random.default_rng(2)
    for _ in range(4):
        x, y = _round_batches(rng, T)
        _, state, _ = fn(state, (x, y))
    assert float(cl.consensus_error(state.params)) < 0.5 * err0


def test_push_sum_mass_conserved_under_compression():
    """The mass lane rides uncompressed: sum(y) == K exactly, any compressor."""
    for compressor in ("topk", "qint8"):
        cfg = _cfg(compressor=compressor, protocol="push_sum",
                   schedule="round_robin")
        state = p2p.init_state(jax.random.PRNGKey(3), _init_fn, cfg)
        fn = p2p.make_round_fn(_mlp_loss, cfg)
        rng = np.random.default_rng(3)
        for _ in range(3):
            x, y = _round_batches(rng, T)
            _, state, _ = fn(state, (x, y))
        np.testing.assert_allclose(
            float(jnp.sum(state.protocol.mass)), float(K), rtol=1e-6
        )


@pytest.mark.parametrize("compressor", ["topk", "qint8"])
def test_scan_driver_bit_identical_compressed(compressor):
    """The fused scan driver and the python round loop agree bit for bit on
    every state leaf — estimate stack included — under compression."""
    cfg = _cfg(compressor=compressor, protocol="gossip", schedule="round_robin")
    sizes = np.arange(1, K + 1)
    state0 = p2p.init_state(jax.random.PRNGKey(4), _init_fn, cfg, data_sizes=sizes)
    round_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    drive_fn = p2p.make_scan_driver(_mlp_loss, cfg, data_sizes=sizes, donate=False)

    chunk = 3
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(chunk, T, K, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(chunk, T, K, 10, 4)), jnp.float32)

    s_py = state0
    for r in range(chunk):
        _, s_py, _ = round_fn(s_py, (x[r], y[r]))
    _, s_scan, _ = drive_fn(state0, (x, y))
    _assert_trees_equal(s_py, s_scan, f"{compressor} scan vs python")


def test_compressed_one_compile():
    """A time-varying compressed run traces the loss once: compression keeps
    the one-compile contract of the round closure."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = _cfg(compressor="topk", schedule="round_robin")
    state = p2p.init_state(jax.random.PRNGKey(5), _init_fn, cfg)
    fn = p2p.make_round_fn(counting_loss, cfg)
    rng = np.random.default_rng(5)
    for _ in range(4):
        x, y = _round_batches(rng, T)
        _, state, _ = fn(state, (x, y))
    assert traces[0] <= 2  # value + grad trace of the single compile


# ---------------------------------------------------------------------------
# guards: hierarchical runtime + launcher (satellite: adaptive x ppd > 1)
# ---------------------------------------------------------------------------


def test_hier_runtime_rejects_compression():
    cfg = _cfg(compressor="topk", num_peers=8)
    with pytest.raises(ValueError, match="compressor.*not supported"):
        p2p._make_hier_round_step(
            _mlp_loss, cfg, mesh=None, axis_name="pod", peers_per_device=2
        )


def test_hier_runtime_rejects_adaptive():
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=8, local_steps=T,
        schedule="adaptive",
    )
    with pytest.raises(ValueError, match="adaptive.*not supported"):
        p2p._make_hier_round_step(
            _mlp_loss, cfg, mesh=None, axis_name="pod", peers_per_device=2
        )


def test_launcher_rejects_adaptive_with_peers_per_device():
    from repro.configs.p2pl_mnist import timevarying_k8
    from repro.launch import train

    exp = timevarying_k8(schedule="adaptive", algorithm="p2pl_affinity",
                         local_steps=10)
    with pytest.raises(ValueError, match="adaptive.*peers_per_device"):
        train.run_paper_experiment(
            exp, rounds=1, peer_axis="pod", peers_per_device=2
        )


def test_launcher_rejects_compressor_with_peers_per_device():
    from repro.configs.p2pl_mnist import timevarying_k8
    from repro.launch import train

    exp = timevarying_k8(
        schedule="round_robin", algorithm="p2pl_affinity", local_steps=10,
        compressor="qint8",
    )
    with pytest.raises(ValueError, match="compressor.*peers_per_device"):
        train.run_paper_experiment(
            exp, rounds=1, peer_axis="pod", peers_per_device=2
        )


@pytest.mark.parametrize("argv,msg", [
    (["--experiment", "timevarying_k8", "--schedule", "adaptive",
      "--peer-axis", "pod", "--peers-per-device", "2"], "adaptive"),
    (["--experiment", "timevarying_k8", "--compressor", "topk",
      "--peer-axis", "pod", "--peers-per-device", "2"], "compressor"),
    (["--experiment", "timevarying_k8", "--topk-frac", "1.5"], "topk-frac"),
    (["--experiment", "timevarying_k8", "--topk-frac", "0"], "topk-frac"),
])
def test_cli_rejects_bad_combinations(argv, msg, capsys):
    from repro.launch import train

    with pytest.raises(SystemExit) as ex:
        train.main(argv)
    assert ex.value.code == 2  # argparse usage error, before any training
    assert msg in capsys.readouterr().err


# ---------------------------------------------------------------------------
# fused dequantize-and-mix kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 257, 1000])
@pytest.mark.parametrize("d", [1, 3, 5])
def test_dequant_mix_matches_oracle(n, d, rng):
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    self_est = jnp.asarray(rng.normal(size=n), jnp.float32)
    nbrs_est = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    nbrs_q = jnp.asarray(rng.integers(-127, 128, size=(d, n)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.0, 0.1, size=d), jnp.float32)
    w_nbr = jnp.asarray(rng.dirichlet(np.ones(d + 1))[:d], jnp.float32)
    w_self = jnp.asarray(1.0 - w_nbr.sum())
    beta = jnp.asarray(rng.dirichlet(np.ones(d)), jnp.float32)
    got_m, got_d = dequant.dequant_mix_flat(
        x, self_est, nbrs_est, nbrs_q, scale, w_self, w_nbr, beta, 10
    )
    want_m, want_d = cm_ref.dequant_mix_ref(
        x, self_est, nbrs_est, nbrs_q, scale, w_self, w_nbr, beta, 10
    )
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               atol=5e-5, rtol=1e-4)


def test_dequant_mix_zero_beta_keeps_zero_d(rng):
    """The no-neighbor guard reads the RAW beta sum: d is exactly zero even
    when payload scales are nonzero."""
    n, d = 256, 3
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    self_est = jnp.asarray(rng.normal(size=n), jnp.float32)
    nbrs_est = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    nbrs_q = jnp.asarray(rng.integers(-127, 128, size=(d, n)), jnp.int8)
    scale = jnp.full((d,), 0.05, jnp.float32)
    _, got_d = dequant.dequant_mix_flat(
        x, self_est, nbrs_est, nbrs_q, scale, jnp.asarray(1.0),
        jnp.zeros((d,), jnp.float32), jnp.zeros((d,), jnp.float32), 10
    )
    assert np.array_equal(np.asarray(got_d), np.zeros(n, np.float32))


def test_dequant_mix_zero_scale_ignores_payload(rng):
    """scale = 0 (an all-zero difference) folds the payload away entirely:
    the mix runs on the bare estimates."""
    n, d = 128, 2
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    self_est = jnp.asarray(rng.normal(size=n), jnp.float32)
    nbrs_est = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    nbrs_q = jnp.asarray(rng.integers(-127, 128, size=(d, n)), jnp.int8)
    w_nbr = jnp.full((d,), 0.3, jnp.float32)
    beta = jnp.full((d,), 0.5, jnp.float32)
    got_m, got_d = dequant.dequant_mix_flat(
        x, self_est, nbrs_est, nbrs_q, jnp.zeros((d,), jnp.float32),
        jnp.asarray(0.4), w_nbr, beta, 10
    )
    want_m, want_d = cm_ref.dequant_mix_ref(
        x, self_est, nbrs_est, jnp.zeros_like(nbrs_q),
        jnp.zeros((d,), jnp.float32), jnp.asarray(0.4), w_nbr, beta, 10
    )
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               atol=5e-5, rtol=1e-4)


def _sparse_round(k):
    from repro.core import protocols as protocols_lib

    cfg = p2p.P2PConfig(num_peers=k, topology="ring", schedule="round_robin",
                        round_robin_topologies=("ring", "star"),
                        schedule_rounds=2, protocol="gossip")
    consts = protocols_lib.get_protocol("gossip").constants(
        p2p.build_schedule(cfg), cfg.mixing,
        data_sizes=np.arange(1, k + 1),
    )
    return cm_ops.sparse_from_schedule(np.asarray(consts.w), np.asarray(consts.beta))


def test_dequant_stacked_matches_per_peer_oracle(rng):
    k = 8
    params = jax.vmap(_init_fn)(jax.random.split(jax.random.PRNGKey(6), k))
    flat, _ = cm_ops.flatten_pytree(params)
    est = jnp.asarray(flat + 0.01 * rng.normal(size=flat.shape), jnp.float32)
    q, scale = dequant.quantize_int8(flat - est)
    self_w_s, nbr_idx_s, nbr_w_s, beta_s = _sparse_round(k)
    r = 0
    mixed, d = dequant.dequant_consensus_mix_stacked(
        params, est, q, scale,
        self_w_s[r], nbr_idx_s[r], nbr_w_s[r], beta_s[r], T,
    )
    mixed_f, _ = cm_ops.flatten_pytree(mixed)
    d_f, _ = cm_ops.flatten_pytree(d)
    for peer in range(k):
        idx = np.asarray(nbr_idx_s[r][peer])
        want_m, want_d = cm_ref.dequant_mix_ref(
            flat[peer], est[peer], est[idx], q[idx], scale[idx],
            self_w_s[r][peer], nbr_w_s[r][peer], beta_s[r][peer], T,
        )
        np.testing.assert_allclose(np.asarray(mixed_f[peer]),
                                   np.asarray(want_m), atol=5e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(d_f[peer]),
                                   np.asarray(want_d), atol=5e-5, rtol=1e-4)


def test_dequant_schedule_compiles_once(rng):
    k = 8
    params = jax.vmap(_init_fn)(jax.random.split(jax.random.PRNGKey(7), k))
    flat, _ = cm_ops.flatten_pytree(params)
    est = jnp.asarray(flat + 0.01 * rng.normal(size=flat.shape), jnp.float32)
    q, scale = dequant.quantize_int8(flat - est)
    operands = _sparse_round(k)
    before = dequant.dequant_consensus_mix_schedule._cache_size()
    outs = []
    for r in range(4):
        m, _ = dequant.dequant_consensus_mix_schedule(
            params, est, q, scale, *operands, jnp.asarray(r), T,
        )
        outs.append(m)
    after = dequant.dequant_consensus_mix_schedule._cache_size()
    assert after - before == 1  # round selected inside the one trace
    # rounds actually differ (ring vs star rows)
    a, _ = cm_ops.flatten_pytree(outs[0])
    b, _ = cm_ops.flatten_pytree(outs[1])
    assert not np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# pod runtime parity (mesh marker: one device per peer)
# ---------------------------------------------------------------------------

K8 = 8

needs_mesh = pytest.mark.skipif(
    jax.device_count() < K8,
    reason=f"needs >= {K8} devices "
           f"(XLA_FLAGS=--xla_force_host_platform_device_count={K8})",
)


@needs_mesh
@pytest.mark.mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("compressor", ["topk", "qint8"])
def test_pod_matches_vmap_compressed(protocol, compressor):
    """Compressed pod runtime (payloads on the wire, replicated estimate
    stack) is allclose to the vmap runtime on every leaf, every round."""
    from repro.launch import mesh as mesh_lib
    from repro.sharding import specs as specs_lib

    cfg = _cfg(compressor=compressor, protocol=protocol,
               schedule="round_robin", num_peers=K8)
    sizes = np.arange(1, K8 + 1)
    state0 = p2p.init_state(jax.random.PRNGKey(8), _init_fn, cfg, data_sizes=sizes)
    vmap_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    mesh = mesh_lib.make_peer_mesh(K8)
    pod_fn = p2p.make_sharded_round_fn(_mlp_loss, cfg, mesh, data_sizes=sizes)

    s_vmap = state0
    s_pod = specs_lib.shard_peer_tree(state0, mesh)
    rng = np.random.default_rng(8)
    for rnd in range(3):
        x, y = _round_batches(rng, T, k=K8)
        _, s_vmap, loss_v = vmap_fn(s_vmap, (x, y))
        _, s_pod, loss_p = pod_fn(s_pod, (x, y))
        np.testing.assert_allclose(np.asarray(loss_v), np.asarray(loss_p),
                                   atol=1e-4, rtol=1e-4)
    # tolerance note: the two runtimes mix with different reduction orders
    # (stacked diag/off-diag einsum vs per-row arithmetic); a one-ULP
    # difference in x - x̂ can flip a qint8 rounding / top-k selection
    # boundary, bounded by the per-step quantization error (~scale / 2),
    # which error feedback re-injects the following step
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_leaves_with_path(s_vmap),
        jax.tree_util.tree_leaves_with_path(s_pod),
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            atol=5e-3, rtol=1e-3,
            err_msg=f"{protocol}/{compressor} leaf {jax.tree_util.keystr(path)}",
        )
