"""Data pipeline, optimizers, checkpointing, sharding specs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import checkpoint, optim
from repro.data import partition, pipeline, synthetic
from repro.sharding import specs


# -- data -------------------------------------------------------------------


def test_mnist_like_determinism():
    a = synthetic.mnist_like(100, 50, seed=7)
    b = synthetic.mnist_like(100, 50, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = synthetic.mnist_like(100, 50, seed=8)
    assert not np.allclose(a[0], c[0])


def test_iid_partition_balanced(mnist_small):
    x, y, *_ = mnist_small
    parts = partition.iid_partition(x, y, 10)
    sizes = partition.data_sizes(parts)
    assert (sizes == len(x) // 10).all()
    # IID: every peer sees (almost) all classes
    for px, py in parts:
        assert len(np.unique(py)) >= 9


def test_pathological_partition(mnist_small):
    x, y, *_ = mnist_small
    parts = partition.pathological_partition(x, y, [(0, 1), (7, 8)], samples_per_class=50)
    assert sorted(np.unique(parts[0][1])) == [0, 1]
    assert sorted(np.unique(parts[1][1])) == [7, 8]
    assert len(parts[0][0]) == 100


def test_dirichlet_partition_covers_data(mnist_small):
    x, y, *_ = mnist_small
    parts = partition.dirichlet_partition(x, y, 5, alpha=0.5)
    assert sum(len(p[0]) for p in parts) == len(x)


def test_peer_batcher_epoch_cycling(mnist_small):
    x, y, *_ = mnist_small
    parts = partition.pathological_partition(x, y, [(0,), (1,)], samples_per_class=20)
    b = pipeline.PeerBatcher(parts, 10)
    bx, by = b.round_batches(4)  # 40 draws from 20 samples: 2 epochs
    assert bx.shape == (4, 2, 10, 784)
    assert set(np.unique(by[:, 0])) == {0}
    assert set(np.unique(by[:, 1])) == {1}


# -- optim ------------------------------------------------------------------


def test_sgd_momentum_matches_pytorch_formula():
    opt = optim.sgd(0.1, momentum=0.5)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([1.0, 1.0])}
    st = opt.init(p)
    p1, st = opt.update(g, st, p, jnp.asarray(0))
    np.testing.assert_allclose(p1["w"], [0.9, 1.9])  # buf=g, w -= .1*g
    p2, st = opt.update(g, st, p1, jnp.asarray(1))
    np.testing.assert_allclose(p2["w"], [0.75, 1.75])  # buf=.5+1=1.5, -=.15


def test_adamw_decreases_quadratic():
    opt = optim.adamw(0.05)
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = opt.init(p)
    for i in range(200):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        p, st = opt.update(g, st, p, jnp.asarray(i))
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_cosine_schedule_shape():
    fn = optim.cosine_schedule(1.0, warmup=10, total=100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)


def test_grad_clipping():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(clipped["a"], [0.6, 0.8], rtol=1e-5)


# -- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "scale": jnp.asarray(2.5),
    }
    path = os.path.join(tmp_path, "ckpt")
    checkpoint.save(path, tree, step=42, extra={"note": "hi"})
    restored = checkpoint.restore(path, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(restored["layers"]["w"], tree["layers"]["w"])
    meta = checkpoint.load_metadata(path)
    assert meta["step"] == 42


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.ones((2, 2))}
    path = os.path.join(tmp_path, "c2")
    checkpoint.save(path, tree)
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.ones((3, 3))})


# -- sharding specs -----------------------------------------------------------


def test_param_leaf_specs():
    s = specs.param_leaf_spec(["layers", "attn", "w_q"], 3, fsdp="data")
    assert s == P("data", "model", None)
    s = specs.param_leaf_spec(["layers", "moe", "w_up"], 3, fsdp=None)
    assert s == P("model", None, None)
    s = specs.param_leaf_spec(["layers", "mlp", "w_up"], 2, fsdp=None)
    assert s == P(None, "model")
    s = specs.param_leaf_spec(["embed"], 2, fsdp="data")
    assert s == P("model", "data")
    s = specs.param_leaf_spec(["ln1", "scale"], 1)
    assert s == P(None)


def test_stacked_layer_prefix():
    tree = {"layers": {"w_o": jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)},
            "embed": jax.ShapeDtypeStruct((32, 16), jnp.float32)}
    out = specs.param_pspecs(tree, fsdp=False)
    assert out["layers"]["w_o"] == P(None, "model", None)
    assert out["embed"] == P("model", None)
    out2 = specs.param_pspecs(tree, fsdp=False, peer_axis="pod")
    assert out2["layers"]["w_o"] == P("pod", None, "model", None)


def test_sanitize_divisibility():
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((1,), ("model",))
    # fake a 16-wide axis via explicit dict; use real mesh of size 1 => all pass
    t = jax.ShapeDtypeStruct((3, 4), jnp.float32)
    out = specs.sanitize_pspecs(P("model", None), t, mesh)
    assert out == P("model", None)  # 3 % 1 == 0


def test_param_count_vs_eval_shape():
    """Analytic param_count matches actual init within 2% for all archs."""
    from repro.configs import ARCHITECTURES, get_config

    from repro.models import build_model

    for name in ("smollm-135m", "qwen1.5-32b", "qwen3-moe-235b-a22b", "rwkv6-7b",
                  "zamba2-2.7b", "deepseek-v2-236b"):
        cfg = get_config(name)
        model = build_model(cfg)
        sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(s.size for s in jax.tree.leaves(sds))
        analytic = cfg.param_count()
        err = abs(actual - analytic) / actual
        assert err < 0.02, f"{name}: analytic {analytic/1e9:.2f}B vs actual {actual/1e9:.2f}B"
