"""Hypothesis property tests on system invariants.

The whole module is gated on hypothesis being importable: the seed
environment ships without it, and these tests skip cleanly there while the
plain parametrized suites still run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro import compression as compression_lib  # noqa: E402
from repro.core import consensus as cl  # noqa: E402
from repro.core import graph as gl  # noqa: E402
from repro.models import common  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(3, 12),
    seed=st.integers(0, 1000),
    p=st.floats(0.2, 0.9),
)
def test_property_random_graph_mixing(k, seed, p):
    g = gl.build_graph("erdos_renyi", k, p=p, seed=seed)
    n = np.random.default_rng(seed).integers(1, 100, size=k)
    w = gl.mixing_matrix(g, "data_weighted", data_sizes=n)
    assert np.allclose(w.sum(1), 1.0)
    assert (w >= -1e-12).all()
    # consensus contraction: applying W repeatedly converges to rank-1;
    # iteration budget scales with the spectral gap (hypothesis finds
    # near-bipartite graphs whose |lambda_2| is close to 1)
    gap = gl.spectral_gap(w)
    iters = min(20000, int(30 / max(gap, 1e-3)))
    x = np.random.default_rng(seed + 1).normal(size=(k, 3))
    for _ in range(iters):
        x = w @ x
    assert np.allclose(x, x[0], atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(2, 8),
    topo=st.sampled_from(["complete", "ring", "star", "chain"]),
    seed=st.integers(0, 100),
)
def test_mixing_preserves_consensus_and_mean_bounds(k, topo, seed):
    """Gossip never moves params outside the convex hull of peer values."""
    g = gl.build_graph(topo, k)
    w = jnp.asarray(gl.mixing_matrix(g, "metropolis"), jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(k, 6)), jnp.float32)
    out = np.asarray(cl.mix_stacked(w, {"x": x})["x"])
    assert (out.min(0) >= np.asarray(x).min(0) - 1e-5).all()
    assert (out.max(0) <= np.asarray(x).max(0) + 1e-5).all()
    # metropolis is doubly stochastic: the mean is invariant
    np.testing.assert_allclose(out.mean(0), np.asarray(x).mean(0), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(2, 6),
    steps=st.integers(1, 30),
    seed=st.integers(0, 100),
)
def test_consensus_error_monotone_under_gossip(k, steps, seed):
    g = gl.build_graph("complete", k)
    w = jnp.asarray(gl.mixing_matrix(g, "metropolis"), jnp.float32)
    x = {"x": jnp.asarray(np.random.default_rng(seed).normal(size=(k, 4)), jnp.float32)}
    errs = [float(cl.consensus_error(x))]
    for _ in range(steps):
        x = cl.mix_stacked(w, x)
        errs.append(float(cl.consensus_error(x)))
    assert all(b <= a + 1e-6 for a, b in zip(errs, errs[1:]))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 200),
    frac=st.floats(0.01, 1.0),
    seed=st.integers(0, 1000),
)
def test_property_topk_keeps_count_and_roundtrips(n, frac, seed):
    """Top-k ships exactly keep(n) slots and the kept coordinates round-trip
    bit for bit, for any leaf size and fraction."""
    comp = compression_lib.TopKCompressor(frac)
    leaf = jnp.asarray(
        np.random.default_rng(seed).normal(size=(2, n)), jnp.float32
    )
    payload = comp.compress(leaf)
    m = comp.keep(n)
    assert 1 <= m <= n and payload.values.shape == (2, m)
    dec = np.asarray(comp.decompress(payload, leaf))
    src = np.asarray(leaf)
    for row in range(2):
        for slot, i in enumerate(np.asarray(payload.indices)[row]):
            assert dec[row, i] == src[row, i]
        # everything un-shipped decompresses to exactly zero
        mask = np.ones(n, bool)
        mask[np.asarray(payload.indices)[row]] = False
        assert (dec[row, mask] == 0.0).all()


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 300),
    scale_mag=st.floats(1e-6, 1e3),
    seed=st.integers(0, 1000),
)
def test_property_qint8_error_bounded(n, scale_mag, seed):
    """Symmetric int8 round-trip error stays under half a quantization step
    across magnitudes; all-zero rows are exact."""
    comp = compression_lib.QInt8Compressor()
    rng_ = np.random.default_rng(seed)
    leaf = jnp.asarray(
        np.concatenate([rng_.normal(size=(1, n)) * scale_mag,
                        np.zeros((1, n))]), jnp.float32
    )
    payload = comp.compress(leaf)
    out = np.asarray(comp.decompress(payload, leaf))
    err = np.abs(out - np.asarray(leaf))
    bound = np.asarray(payload.scale) / 2.0 + 1e-6 * scale_mag
    assert (err <= bound).all()
    assert (out[1] == 0.0).all()


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(["topk", "qint8"]),
    n=st.integers(4, 120),
    frac=st.floats(0.05, 0.9),
    seed=st.integers(0, 1000),
)
def test_property_error_feedback_contracts(name, n, frac, seed):
    """Estimate tracking is a contraction toward a static target: after
    enough steps the public estimate is closer to x than at the start, for
    any compressor / leaf size / sparsity."""
    comp = compression_lib.get_compressor(name, topk_frac=frac)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(1, n)), jnp.float32)
    est = jnp.zeros_like(x)
    err0 = float(jnp.max(jnp.abs(x - est)))
    for _ in range(40):
        payload, est_new = compression_lib.ef_compress_leaf(comp, x, est)
        # the advance is EXACTLY est + D(payload): what the receivers apply
        # is what the sender's own estimate absorbs (replica lockstep)
        np.testing.assert_array_equal(
            np.asarray(est_new),
            np.asarray(est + comp.decompress(payload, x)),
        )
        est = est_new
    assert float(jnp.max(jnp.abs(x - est))) < 0.05 * max(err0, 1e-6)


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(2, 32),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 1000),
)
def test_rope_preserves_norm_and_relative_angle(s, d, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(1, s, d)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    y = common.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-4,
    )
    # relative property: <R(p)q, R(p+o)k> depends only on o
    q = jnp.asarray(np.random.default_rng(seed + 1).normal(size=(1, 1, d)), jnp.float32)
    kk = jnp.asarray(np.random.default_rng(seed + 2).normal(size=(1, 1, d)), jnp.float32)
    off = 3
    dots = []
    for p in (0, 5):
        qr = common.apply_rope(q, jnp.asarray([[p]], jnp.int32))
        kr = common.apply_rope(kk, jnp.asarray([[p + off]], jnp.int32))
        dots.append(float(jnp.sum(qr * kr)))
    assert abs(dots[0] - dots[1]) < 1e-3


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 64),
    v=st.integers(3, 50),
    seed=st.integers(0, 1000),
)
def test_cross_entropy_bounds(n, v, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(1, n, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, size=(1, n)), jnp.int32)
    loss = float(common.cross_entropy_loss(logits, labels))
    assert loss >= 0.0
    # perfect prediction drives loss to ~0
    perfect = jnp.full((1, n, v), -30.0).at[0, jnp.arange(n), labels[0]].set(30.0)
    assert float(common.cross_entropy_loss(perfect, labels)) < 1e-3
    # ignore_id masks out positions
    masked = labels.at[0, 0].set(-100)
    assert np.isfinite(float(common.cross_entropy_loss(logits, masked)))


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 100),
    decay_lo=st.floats(0.01, 1.0),
)
def test_wkv_chunk_invariance(t, seed, decay_lo):
    """Chunked WKV output is invariant to the chunk size."""
    from repro.kernels.rwkv6.ops import wkv6

    rng = np.random.default_rng(seed)
    b, h, dk = 1, 2, 8
    r = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    ld = -jnp.asarray(rng.uniform(decay_lo, 3.0, size=(b, t, h, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, dk)), jnp.float32)
    outs = [np.asarray(wkv6(r, k, v, ld, u, chunk=c)) for c in (4, 8, t)]
    np.testing.assert_allclose(outs[0], outs[1], atol=5e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(
    ng=st.integers(8, 512),
    e=st.sampled_from([4, 8, 16, 64]),
    k=st.integers(1, 4),
    cf=st.floats(0.5, 4.0),
)
def test_moe_capacity_invariants(ng, e, k, cf):
    from repro.configs.base import MoEConfig
    from repro.models import moe

    cfg = MoEConfig(num_experts=e, top_k=k, expert_ff=4, capacity_factor=cf)
    c = moe.capacity(cfg, ng)
    assert c % 8 == 0 and c >= 8
    assert c * e >= ng * k * cf * 0.99  # capacity covers the requested factor


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 64),
    t=st.integers(1, 64),
    profile=st.sampled_from(["uniform", "straggler", "linear"]),
    frac=st.floats(0.01, 1.0),
    period=st.integers(1, 32),
)
def test_compute_profile_floor_invariants(k, t, profile, frac, period):
    """compute_profile never emits a zero budget or zero period, whatever
    fleet size / step count / slowdown hypothesis throws at it, and the
    uniform profile is always exactly the synchronous (T, 1) fleet."""
    from repro.core import p2p

    cfg = p2p.P2PConfig(
        num_peers=k, local_steps=t, steps_profile=profile,
        straggler_frac=frac, straggler_period=period,
    )
    steps, periods = p2p.compute_profile(cfg)
    assert (steps >= 1).all() and (steps <= t).all()
    assert (periods >= 1).all()
    if profile == "uniform":
        assert (steps == t).all() and (periods == 1).all()
