"""Adaptive loss-driven partner selection (state-dependent topologies).

Contract under test (the tentpole's acceptance criteria):

* **On-device builders** — ``graph.adaptive_round_matrices`` produces exactly
  row- (gossip) / column- (push_sum) stochastic matrices from a traceable
  greedy matching that is symmetric, deterministic in (losses, key), and
  actually pairs loss-proximal peers under the ``loss_proximity`` rule.
* **One compile per run** — the selection happens inside the jitted round
  step (both the python-loop and scan drivers; the pod cells live in
  tests/test_mesh_runtime.py under the ``mesh`` marker), for both protocols.
* **Driver parity** — python-loop and scan drivers are fp32 BIT-identical on
  adaptive schedules, exactly as on pretraced ones.
* **Dense-dynamic kernel path** — ``consensus_mix_dense`` /
  ``consensus_mix_push_sum_dense`` match the runtime's einsum mix + affinity
  d for TRACED (K, K) matrices.
* **Config/CLI validation** — unknown rules and malformed eps fail fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as cl
from repro.core import graph as gl
from repro.core import p2p, protocols
from repro.kernels.consensus_mix import ops

K = 8
T = 3
CHUNK = 3


def _init_fn(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (6, 16)),
        "b1": jnp.zeros((16,)),
        "w2": jax.random.normal(k2, (16, 4)),
    }


def _mlp_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return jnp.mean(jnp.sum(jnp.square(h @ p["w2"] - y), axis=-1))


def _cfg(protocol: str, rule: str = "loss_proximity", num_peers: int = K):
    return p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=num_peers, local_steps=T,
        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
        schedule="adaptive", partner_rule=rule, protocol=protocol,
    )


def _round_batches(rng, t, k=K):
    x = jnp.asarray(rng.normal(size=(t, k, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(t, k, 10, 4)), jnp.float32)
    return (x, y)


# ---------------------------------------------------------------------------
# On-device builders
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", gl.ADAPTIVE_RULES)
@pytest.mark.parametrize("k", [2, 5, 8])
def test_matching_is_symmetric_and_stochastic(rule, k):
    """partner[partner[i]] == i; W rows (or columns) sum to exactly 1 with
    nonnegative entries; Beta rows are one-hot at the partner."""
    losses = jnp.asarray(np.random.default_rng(k).normal(size=(k,)), jnp.float32)
    key = jax.random.PRNGKey(7)
    partner = np.asarray(gl.greedy_matching(gl.partner_scores(losses, key, rule)))
    assert (partner[partner] == np.arange(k)).all()
    # even K: perfect matching; odd K: exactly one self-matched peer
    assert (partner == np.arange(k)).sum() == k % 2

    sizes = jnp.asarray(np.arange(1, k + 1), jnp.float32)
    w, beta = gl.adaptive_round_matrices(
        losses, key, rule=rule, data_sizes=sizes, stochasticity="row"
    )
    w, beta = np.asarray(w), np.asarray(beta)
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)
    a, _ = gl.adaptive_round_matrices(
        losses, key, rule=rule, data_sizes=sizes, stochasticity="column"
    )
    a = np.asarray(a)
    assert (a >= 0).all()
    np.testing.assert_allclose(a.sum(axis=0), 1.0, atol=1e-6)
    # beta: one-hot at the partner for matched peers, zero row otherwise
    for i in range(k):
        want = np.zeros(k)
        if partner[i] != i:
            want[partner[i]] = 1.0
        np.testing.assert_array_equal(beta[i], want)


def test_loss_proximity_pairs_nearest_losses():
    """Four well-separated loss clusters of two peers each: the greedy
    matching must pair within clusters."""
    losses = jnp.asarray([1.0, 3.0, 1.1, 2.9, 0.2, 0.25, 7.0, 6.9])
    partner = np.asarray(
        gl.greedy_matching(gl.partner_scores(losses, jax.random.PRNGKey(0),
                                             "loss_proximity"))
    )
    np.testing.assert_array_equal(partner, [2, 3, 0, 1, 5, 4, 7, 6])


def test_random_rule_varies_with_key_not_losses():
    losses_a = jnp.zeros((K,))
    losses_b = jnp.asarray(np.random.default_rng(0).normal(size=(K,)), jnp.float32)
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    m = lambda ls, key: np.asarray(  # noqa: E731
        gl.greedy_matching(gl.partner_scores(ls, key, "random"))
    )
    np.testing.assert_array_equal(m(losses_a, k1), m(losses_b, k1))
    assert not np.array_equal(m(losses_a, k1), m(losses_a, k2))


def test_eps_greedy_bounds():
    """eps=0 is pure loss proximity, eps=1 is pure random — bit for bit."""
    losses = jnp.asarray(np.random.default_rng(3).normal(size=(K,)), jnp.float32)
    key = jax.random.PRNGKey(3)
    greedy0 = gl.partner_scores(losses, key, "eps_greedy", eps=0.0)
    np.testing.assert_array_equal(
        np.asarray(greedy0), np.asarray(gl.partner_scores(losses, key, "loss_proximity"))
    )
    greedy1 = gl.partner_scores(losses, key, "eps_greedy", eps=1.0)
    np.testing.assert_array_equal(
        np.asarray(greedy1), np.asarray(gl.partner_scores(losses, key, "random"))
    )


def test_consensus_step_size_keeps_stochasticity():
    losses = jnp.asarray(np.random.default_rng(4).normal(size=(5,)), jnp.float32)
    w, _ = gl.adaptive_round_matrices(
        losses, jax.random.PRNGKey(4), consensus_step_size=0.3
    )
    np.testing.assert_allclose(np.asarray(w).sum(axis=1), 1.0, atol=1e-6)
    a, _ = gl.adaptive_round_matrices(
        losses, jax.random.PRNGKey(4), consensus_step_size=0.3,
        stochasticity="column",
    )
    np.testing.assert_allclose(np.asarray(a).sum(axis=0), 1.0, atol=1e-6)


def test_builders_reject_unknown_names():
    losses = jnp.zeros((4,))
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="partner rule"):
        gl.partner_scores(losses, key, "nope")
    with pytest.raises(ValueError, match="stochasticity"):
        gl.matching_matrices(jnp.arange(4, dtype=jnp.int32), stochasticity="diag")


# ---------------------------------------------------------------------------
# Runtime integration: one compile, state threading, driver parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_adaptive_round_fn_single_compile(protocol):
    """Adaptive selection runs INSIDE the jitted round fn: the loss traces
    once across many rounds (python-loop driver, vmap runtime)."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = _cfg(protocol, "eps_greedy")
    state = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg)
    fn = p2p.make_round_fn(counting_loss, cfg)
    rng = np.random.default_rng(0)
    for _ in range(7):
        _, state, losses = fn(state, _round_batches(rng, T))
    assert int(state.round_idx) == 7
    assert np.isfinite(np.asarray(losses)).all()
    assert traces[0] <= 2  # value + grad trace of the single compile
    assert fn._cache_size() == 1  # the jit cache agrees


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_adaptive_scan_driver_single_compile(protocol):
    """...and inside the scanned multi-round driver: one compile covers every
    chunk of an adaptive run."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = _cfg(protocol)
    state = p2p.init_state(jax.random.PRNGKey(1), _init_fn, cfg)
    drive = p2p.make_scan_driver(counting_loss, cfg)
    rng = np.random.default_rng(1)
    for _ in range(3):
        x = jnp.asarray(rng.normal(size=(CHUNK, T, K, 10, 6)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(CHUNK, T, K, 10, 4)), jnp.float32)
        _, state, losses = drive(state, (x, y))
    assert int(state.round_idx) == 3 * CHUNK
    assert np.isfinite(np.asarray(losses)).all()
    assert traces[0] <= 2
    assert drive._cache_size() == 1


def test_adaptive_state_threads_through_rounds():
    """The AdaptiveState leaves update per round: last_losses becomes this
    round's per-peer mean loss, the key advances, rows stay replicated."""
    cfg = _cfg("gossip")
    sizes = np.arange(1, K + 1)
    state = p2p.init_state(jax.random.PRNGKey(2), _init_fn, cfg, data_sizes=sizes)
    assert isinstance(state.adaptive, p2p.AdaptiveState)
    np.testing.assert_array_equal(np.asarray(state.adaptive.last_losses), 0.0)
    key0 = np.asarray(state.adaptive.key)
    assert (key0 == key0[0]).all()

    fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    rng = np.random.default_rng(2)
    prev_key = key0
    for _ in range(3):
        _, state, _ = fn(state, _round_batches(rng, T))
        ll = np.asarray(state.adaptive.last_losses)
        assert ll.shape == (K,) and np.isfinite(ll).all() and (ll > 0).any()
        keys = np.asarray(state.adaptive.key)
        assert (keys == keys[0]).all()  # still replicated row-wise
        assert not np.array_equal(keys, prev_key)  # and advanced
        prev_key = keys


def test_adaptive_push_sum_conserves_mass():
    cfg = _cfg("push_sum", "random")
    sizes = np.arange(1, K + 1)
    state = p2p.init_state(jax.random.PRNGKey(3), _init_fn, cfg, data_sizes=sizes)
    fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    rng = np.random.default_rng(3)
    for _ in range(5):
        _, state, _ = fn(state, _round_batches(rng, T))
        mass = np.asarray(state.protocol.mass)
        np.testing.assert_allclose(mass.sum(), K, rtol=1e-5)
        assert (mass > 0).all()


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("rule", ["loss_proximity", "eps_greedy"])
def test_adaptive_scan_driver_bit_identical_to_python_loop(protocol, rule):
    """Two adaptive scan chunks == 2*CHUNK python-loop rounds, bit for bit on
    every leaf — including the threaded AdaptiveState."""
    cfg = _cfg(protocol, rule)
    sizes = np.arange(1, K + 1)
    state0 = p2p.init_state(jax.random.PRNGKey(4), _init_fn, cfg, data_sizes=sizes)
    round_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    drive_fn = p2p.make_scan_driver(_mlp_loss, cfg, data_sizes=sizes, donate=False)

    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, CHUNK, T, K, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(2, CHUNK, T, K, 10, 4)), jnp.float32)

    s_py, losses_py, al_py = state0, [], None
    for c in range(2):
        for r in range(CHUNK):
            al_py, s_py, loss_r = round_fn(s_py, (x[c, r], y[c, r]))
            losses_py.append(np.asarray(loss_r))
    s_sc, al_sc, losses_sc = state0, None, []
    for c in range(2):
        al_sc, s_sc, loss_c = drive_fn(s_sc, (x[c], y[c]))
        losses_sc.append(np.asarray(loss_c))

    want = jax.tree_util.tree_leaves_with_path((al_py, s_py))
    got = jax.tree_util.tree_leaves_with_path((al_sc, s_sc))
    assert len(want) == len(got)
    for (path, w), (_, g) in zip(want, got):
        assert np.array_equal(np.asarray(w), np.asarray(g)), (
            f"{protocol}/{rule} leaf {jax.tree_util.keystr(path)} diverged"
        )
    assert np.array_equal(np.stack(losses_py), np.concatenate(losses_sc))


def test_adaptive_selection_actually_depends_on_state():
    """The tentpole's point: two runs with identical configs but different
    data must diverge in WHICH partners they pick (the topology is run-state
    -dependent, not pretraced)."""
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=T,
        consensus_steps=1, lr=0.3, eta_d=0.5, schedule="adaptive",
        partner_rule="loss_proximity",
    )
    proto = protocols.get_protocol(cfg.protocol)

    def matchings(data_seed, rounds=6):
        state = p2p.init_state(jax.random.PRNGKey(5), _init_fn, cfg)
        fn = p2p.make_round_fn(_mlp_loss, cfg)
        rng = np.random.default_rng(data_seed)
        picked = []
        for _ in range(rounds):
            _, state, _ = fn(state, _round_batches(rng, T))
            ad = state.adaptive
            partner = gl.greedy_matching(gl.partner_scores(
                ad.last_losses, jax.random.split(ad.key[0])[0],
                cfg.partner_rule, cfg.adaptive_eps,
            ))
            assert proto.stochasticity == "row"
            picked.append(np.asarray(partner))
        return np.stack(picked)

    a, b = matchings(10), matchings(11)
    assert not np.array_equal(a, b), "partner choice ignored the run state"


# ---------------------------------------------------------------------------
# Dense-dynamic kernel path
# ---------------------------------------------------------------------------


def test_consensus_mix_dense_matches_runtime_mix(rng):
    """TRACED (K, K) matrices through the fused kernel == the runtime's
    einsum mix + affinity-d update (adaptive matrices as the source)."""
    tree = {
        "a": jnp.asarray(rng.normal(size=(K, 5, 4)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(K, 17)), jnp.float32),
    }
    losses = jnp.asarray(rng.normal(size=(K,)), jnp.float32)
    w, beta = gl.adaptive_round_matrices(
        losses, jax.random.PRNGKey(6), data_sizes=jnp.arange(1.0, K + 1)
    )
    mixed_k, d_k = ops.consensus_mix_dense(tree, w, beta, T)
    mixed_ref = cl.mix_stacked(w, tree)
    nbr_avg = cl.mix_stacked(beta, tree)
    has = jnp.sum(beta, axis=1) > 0
    d_ref = jax.tree.map(
        lambda avg, x: jnp.where(
            has.reshape((-1,) + (1,) * (x.ndim - 1)), (avg - x) / T, 0.0
        ),
        nbr_avg, tree,
    )
    for leaf in tree:
        np.testing.assert_allclose(
            np.asarray(mixed_k[leaf]), np.asarray(mixed_ref[leaf]), atol=2e-6
        )
        np.testing.assert_allclose(
            np.asarray(d_k[leaf]), np.asarray(d_ref[leaf]), atol=2e-6
        )


def test_consensus_mix_push_sum_dense_matches_protocol(rng):
    tree = {"a": jnp.asarray(rng.normal(size=(K, 9)), jnp.float32)}
    losses = jnp.asarray(rng.normal(size=(K,)), jnp.float32)
    w, beta = gl.adaptive_round_matrices(
        losses, jax.random.PRNGKey(7), rule="random", stochasticity="column",
        data_sizes=jnp.arange(1.0, K + 1),
    )
    mass = jnp.asarray(K * rng.dirichlet(np.ones(K)), jnp.float32)
    proto = protocols.get_protocol("push_sum")
    ps_state, mixed_ref = proto.mix(
        protocols.PushSumState(mass=mass), tree,
        protocols.ProtocolConstants(w=w, beta=beta),
    )
    mixed_k, _, new_mass = ops.consensus_mix_push_sum_dense(tree, mass, w, beta, T)
    np.testing.assert_allclose(
        np.asarray(new_mass), np.asarray(ps_state.mass), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(mixed_k["a"]), np.asarray(mixed_ref["a"]), atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(new_mass).sum(), K, rtol=1e-5)


def test_consensus_mix_dense_traces_once_inside_jit():
    """The dense-dynamic path composes with an outer jit computing the
    matrices from run state — the adaptive-round usage pattern."""
    calls = [0]

    @jax.jit
    def round_like(tree, losses, key):
        calls[0] += 1
        w, beta = gl.adaptive_round_matrices(losses, key)
        return ops.consensus_mix_dense(tree, w, beta, T)

    tree = {"a": jnp.ones((4, 6), jnp.float32)}
    for i in range(3):
        losses = jnp.arange(4, dtype=jnp.float32) * (i + 1)
        mixed, _ = round_like(tree, losses, jax.random.PRNGKey(i))
    assert calls[0] == 1
    assert np.isfinite(np.asarray(mixed["a"])).all()


def test_consensus_mix_dense_rejects_singleton():
    with pytest.raises(ValueError, match="at least two peers"):
        ops.consensus_mix_dense(
            {"a": jnp.ones((1, 4))}, jnp.ones((1, 1)), jnp.zeros((1, 1)), T
        )


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------


def test_config_validation():
    with pytest.raises(ValueError, match="partner_rule"):
        p2p.P2PConfig(partner_rule="nope")
    with pytest.raises(ValueError, match="adaptive_eps"):
        p2p.P2PConfig(adaptive_eps=1.5)
    with pytest.raises(ValueError, match="two peers"):
        p2p.P2PConfig(schedule="adaptive", num_peers=1)
    with pytest.raises(ValueError, match="schedule"):
        p2p.P2PConfig(schedule="adaptve")
    # adaptive has no pretraced schedule to build
    with pytest.raises(ValueError, match="adaptive"):
        p2p.build_schedule(p2p.P2PConfig(schedule="adaptive", num_peers=2))
