"""The algorithm family: equivalences, affinity semantics, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as cl
from repro.core import p2p


def _quad_loss(params, batch):
    """Per-peer quadratic: ||w - target||^2; batch carries the target."""
    return jnp.sum(jnp.square(params["w"] - batch))


def _init_fn(key):
    return {"w": jax.random.normal(key, (4,))}


def _batches(targets, t, k):
    return jnp.broadcast_to(jnp.asarray(targets, jnp.float32), (t, k, 4))


def test_dsgd_is_special_case():
    """p2pl_affinity with S=T=1, mu=0, eta_d=eta_b=0 == dsgd exactly."""
    cfg_a = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=3, local_steps=1,
                          consensus_steps=1, lr=0.1, eta_d=0.0, eta_b=0.0,
                          max_norm_init=True)
    cfg_d = p2p.P2PConfig(algorithm="dsgd", num_peers=3, local_steps=1,
                          consensus_steps=1, lr=0.1, max_norm_init=True)
    rng = jax.random.PRNGKey(0)
    s_a = p2p.init_state(rng, _init_fn, cfg_a)
    s_d = p2p.init_state(rng, _init_fn, cfg_d)
    targets = np.random.default_rng(0).normal(size=(3, 4))
    batches = _batches(targets, 1, 3)
    fn_a = p2p.make_round_fn(_quad_loss, cfg_a)
    fn_d = p2p.make_round_fn(_quad_loss, cfg_d)
    _, a, _ = fn_a(s_a, batches)
    _, d, _ = fn_d(s_d, batches)
    np.testing.assert_allclose(a.params["w"], d.params["w"], atol=1e-6)


def test_isolated_never_mixes():
    cfg = p2p.P2PConfig(algorithm="isolated", num_peers=2, local_steps=3,
                        consensus_steps=0, lr=0.1, topology="disconnected",
                        mixing="identity")
    rng = jax.random.PRNGKey(1)
    state = p2p.init_state(rng, _init_fn, cfg)
    targets = np.array([[1.0] * 4, [-1.0] * 4])
    fn = p2p.make_round_fn(_quad_loss, cfg)
    for _ in range(30):
        _, state, _ = fn(state, _batches(targets, 3, 2))
    # peers converge to their own disparate targets — drift stays large
    np.testing.assert_allclose(state.params["w"][0], 1.0, atol=1e-2)
    np.testing.assert_allclose(state.params["w"][1], -1.0, atol=1e-2)


def test_consensus_pulls_to_global_minimum():
    """Non-IID quadratics: with consensus, both peers end at the average."""
    cfg = p2p.P2PConfig(algorithm="local_dsgd", num_peers=2, local_steps=2,
                        consensus_steps=1, lr=0.2, topology="complete",
                        mixing="uniform_neighbor")
    rng = jax.random.PRNGKey(2)
    state = p2p.init_state(rng, _init_fn, cfg)
    targets = np.array([[1.0] * 4, [-1.0] * 4])  # global min = 0
    fn = p2p.make_round_fn(_quad_loss, cfg)
    for _ in range(150):
        _, state, _ = fn(state, _batches(targets, 2, 2))
    drift = float(cl.pairwise_drift(state.params))
    assert drift < 0.5
    # consensus point is near the average of the two optima (0)
    assert float(jnp.abs(state.params["w"]).max()) < 0.7


def test_affinity_d_reduces_local_drift():
    """The d bias pulls peers together during LOCAL training (Sec. V-C)."""

    def run(algorithm, eta_d):
        cfg = p2p.P2PConfig(algorithm=algorithm, num_peers=2, local_steps=8,
                            consensus_steps=1, lr=0.1, eta_d=eta_d,
                            topology="complete", max_norm_init=True)
        rng = jax.random.PRNGKey(3)
        state = p2p.init_state(rng, _init_fn, cfg)
        targets = np.array([[2.0] * 4, [-2.0] * 4])
        fn = p2p.make_round_fn(_quad_loss, cfg)
        drifts = []
        for _ in range(10):
            after_local, state, _ = fn(state, _batches(targets, 8, 2))
            drifts.append(float(cl.pairwise_drift(after_local.params)))
        return np.mean(drifts[2:])  # skip rounds before d is first updated

    drift_plain = run("local_dsgd", 0.0)
    drift_affinity = run("p2pl_affinity", 1.0)
    assert drift_affinity < drift_plain


def test_affinity_b_zero_matches_paper_setting():
    """Sec. V-C uses b = 0: eta_b=0 must equal an explicit zero-b run."""
    common = dict(algorithm="p2pl_affinity", num_peers=2, local_steps=2,
                  consensus_steps=1, lr=0.1, eta_d=1.0, max_norm_init=True)
    cfg0 = p2p.P2PConfig(eta_b=0.0, **common)
    rng = jax.random.PRNGKey(4)
    s0 = p2p.init_state(rng, _init_fn, cfg0)
    targets = np.array([[1.0] * 4, [-1.0] * 4])
    fn0 = p2p.make_round_fn(_quad_loss, cfg0)
    _, out0, _ = fn0(s0, _batches(targets, 2, 2))
    assert np.all(np.asarray(out0.b_bias["w"]) == 0.0)


def test_momentum_polyak_formula():
    """buf = mu*buf + g; w -= lr*buf (PyTorch default, as in the paper)."""
    cfg = p2p.P2PConfig(algorithm="local_dsgd", num_peers=1, local_steps=2,
                        consensus_steps=1, lr=0.1, momentum=0.5,
                        topology="complete", mixing="identity")
    state = p2p.init_state(jax.random.PRNGKey(5), _init_fn, cfg)
    w0 = np.asarray(state.params["w"][0]).copy()
    target = np.zeros((1, 4))
    fn = p2p.make_round_fn(_quad_loss, cfg)
    _, out, _ = fn(state, _batches(target, 2, 1))
    # manual: g = 2w; buf1 = 2w0; w1 = w0 - .1*2w0 = .8 w0
    # g2 = 2*.8w0; buf2 = .5*2w0 + 1.6w0 = 2.6w0; w2 = .8w0 - .26w0 = .54w0
    np.testing.assert_allclose(out.params["w"][0], 0.54 * w0, rtol=1e-5)


def test_max_norm_init_only_for_p2pl():
    cfg = p2p.P2PConfig(algorithm="p2pl", num_peers=3, local_steps=2,
                        consensus_steps=1, momentum=0.5)
    state = p2p.init_state(jax.random.PRNGKey(6), _init_fn, cfg)
    w = np.asarray(state.params["w"])
    assert np.allclose(w[0], w[1]) and np.allclose(w[1], w[2])
    cfg2 = p2p.P2PConfig(algorithm="local_dsgd", num_peers=3, local_steps=2)
    state2 = p2p.init_state(jax.random.PRNGKey(6), _init_fn, cfg2)
    w2 = np.asarray(state2.params["w"])
    assert not np.allclose(w2[0], w2[1])


def test_config_validation():
    with pytest.raises(ValueError):
        p2p.P2PConfig(algorithm="dsgd", local_steps=5)
    with pytest.raises(ValueError):
        p2p.P2PConfig(algorithm="nope")
    with pytest.raises(ValueError):
        p2p.P2PConfig(algorithm="isolated", consensus_steps=2)
