"""Platform-aware Pallas lowering policy (repro.kernels.lowering).

``default_interpret`` is the single source of truth for whether a kernel runs
in interpret mode: CPU -> interpret (Pallas cannot compile there), real
accelerators -> compiled, ``REPRO_PALLAS_INTERPRET`` overriding both ways.
The grep-style test pins the policy structurally: no kernel entry point may
grow a hardcoded ``interpret=True`` default again.
"""
import pathlib
import re

import jax
import pytest

from repro.kernels import lowering

KERNELS_DIR = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro" / "kernels"


# ---------------------------------------------------------------------------
# default_interpret: platform rule + env override
# ---------------------------------------------------------------------------


def test_platform_rule_cpu_interprets(monkeypatch):
    monkeypatch.delenv(lowering.ENV_VAR, raising=False)
    assert lowering.default_interpret(backend="cpu") is True
    assert lowering.default_interpret(backend="tpu") is False
    assert lowering.default_interpret(backend="gpu") is False


def test_default_backend_is_used(monkeypatch):
    monkeypatch.delenv(lowering.ENV_VAR, raising=False)
    # the no-arg form must follow whatever jax's default backend is — on the
    # CPU CI that means interpret=True; on a GPU/TPU dev box, False
    assert lowering.default_interpret() is (jax.default_backend() == "cpu")


@pytest.mark.parametrize("value", ["1", "true", "YES", "on"])
def test_env_forces_interpret_on(monkeypatch, value):
    """Override in the ON direction even where the platform says compile."""
    monkeypatch.setenv(lowering.ENV_VAR, value)
    assert lowering.default_interpret(backend="tpu") is True


@pytest.mark.parametrize("value", ["0", "false", "No", "OFF"])
def test_env_forces_interpret_off(monkeypatch, value):
    """Override in the OFF direction even on CPU (e.g. asserting that a
    lowering path at least builds)."""
    monkeypatch.setenv(lowering.ENV_VAR, value)
    assert lowering.default_interpret(backend="cpu") is False


def test_env_garbage_raises(monkeypatch):
    monkeypatch.setenv(lowering.ENV_VAR, "maybe")
    with pytest.raises(ValueError, match=lowering.ENV_VAR):
        lowering.default_interpret(backend="cpu")


def test_resolve_explicit_beats_everything(monkeypatch):
    monkeypatch.setenv(lowering.ENV_VAR, "1")
    assert lowering.resolve_interpret(False, backend="cpu") is False
    assert lowering.resolve_interpret(True, backend="tpu") is True
    monkeypatch.delenv(lowering.ENV_VAR)
    assert lowering.resolve_interpret(None, backend="cpu") is True
    assert lowering.resolve_interpret(None, backend="tpu") is False


# ---------------------------------------------------------------------------
# Structural enforcement: every kernel routes through the policy
# ---------------------------------------------------------------------------

KERNEL_FAMILIES = ("consensus_mix", "flash_attention", "mamba2", "rwkv6")


def test_no_hardcoded_interpret_defaults_anywhere_in_kernels():
    """Grep-style gate: no ``interpret: bool = True``-shaped default (or
    ``interpret=True`` keyword default) may appear in any kernel source —
    the platform policy owns the default."""
    # catches annotated (interpret: bool = True) AND bare (interpret=True)
    # parameter defaults — and literal interpret=True call-site forwarding,
    # which kernel code also must not hardcode
    hardcoded = re.compile(r"interpret\s*(:[^=]+)?=\s*(True|False)")
    offenders = []
    for path in sorted(KERNELS_DIR.rglob("*.py")):
        if path.name == "lowering.py":  # the policy module narrates the history
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), start=1):
            if hardcoded.search(line):
                offenders.append(f"{path.relative_to(KERNELS_DIR)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "hardcoded interpret defaults found (route through "
        "repro.kernels.lowering instead):\n" + "\n".join(offenders)
    )


@pytest.mark.parametrize("family", KERNEL_FAMILIES)
def test_every_ops_entry_point_routes_through_lowering(family):
    """Each family's public ops.py (or the kernel module its entry point
    forwards ``interpret=None`` to) must resolve via the lowering policy."""
    ops = (KERNELS_DIR / family / "ops.py").read_text()
    kernel_sources = "".join(
        p.read_text() for p in sorted((KERNELS_DIR / family).glob("*.py"))
    )
    # every `interpret` default/assignment in the family is None, a pass-
    # through, or the policy resolution itself — never a literal bool
    for m in re.finditer(r"interpret\s*(?::[\w| ]+)?=\s*(\w+)", kernel_sources):
        assert m.group(1) in ("None", "interpret", "lowering"), m.group(0)
    # ...and the family actually consults the policy
    assert "resolve_interpret" in kernel_sources, family
    assert "interpret" in ops, family
