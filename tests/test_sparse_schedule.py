"""graph.SparseSchedule: the degree-bounded CSR-style schedule form.

The contract under test is LOSSLESS convertibility for K <= 64: the direct
sparse builders must produce float64-EXACT copies of the dense
``schedule_matrices`` values (np.array_equal, not allclose), and
``to_dense``/``from_dense`` must round-trip without changing a single bit.
That exactness is what lets the hierarchical runtime's bridge mode replay
the dense runtime's einsums bit-for-bit.
"""
import numpy as np
import pytest

from repro.core import graph as gl
from repro.core import p2p

K = 8

MIXINGS = ["data_weighted", "metropolis", "uniform_neighbor", "identity"]
SCHEDULES = [
    ("static", {}),
    ("link_dropout", {}),
    ("one_way_matching", {}),
    ("round_robin", {"round_robin_topologies": ("ring", "star")}),
]


def _schedule(name, extra, num_peers=K):
    cfg = p2p.P2PConfig(
        num_peers=num_peers, topology="ring", schedule=name,
        schedule_rounds=4, protocol="gossip", **extra,
    )
    return p2p.build_schedule(cfg)


@pytest.mark.parametrize("mixing", MIXINGS)
@pytest.mark.parametrize("stochasticity", ["row", "column"])
@pytest.mark.parametrize("name,extra", SCHEDULES, ids=[s for s, _ in SCHEDULES])
def test_from_schedule_exactly_matches_dense(name, extra, mixing, stochasticity):
    """Direct sparse build == dense schedule_matrices, bit for bit (f64)."""
    sched = _schedule(name, extra)
    sizes = np.arange(3, 3 + K)
    w, beta = gl.schedule_matrices(
        sched, mixing, data_sizes=sizes, consensus_step_size=0.9,
        stochasticity=stochasticity,
    )
    sp = gl.SparseSchedule.from_schedule(
        sched, mixing, data_sizes=sizes, consensus_step_size=0.9,
        stochasticity=stochasticity,
    )
    w2, beta2 = sp.to_dense()
    assert np.array_equal(w, w2), f"{name}/{mixing}/{stochasticity}: W differs"
    assert np.array_equal(beta, beta2), f"{name}/{mixing}: beta differs"


@pytest.mark.parametrize("name,extra", SCHEDULES, ids=[s for s, _ in SCHEDULES])
def test_from_dense_round_trip(name, extra):
    sched = _schedule(name, extra)
    sizes = np.arange(1, K + 1)
    w, beta = gl.schedule_matrices(sched, "data_weighted", data_sizes=sizes)
    sp = gl.SparseSchedule.from_dense(w, beta, stochasticity="row")
    w2, beta2 = sp.to_dense()
    assert np.array_equal(w, w2)
    assert np.array_equal(beta, beta2)


def test_round_edges_matches_dense_pattern():
    sched = _schedule("link_dropout", {})
    w, beta = gl.schedule_matrices(sched, "data_weighted",
                                   data_sizes=np.ones(K, int) * 5)
    sp = gl.SparseSchedule.from_dense(w, beta, stochasticity="row")
    for r in range(sp.period):
        send, recv, weights = sp.round_edges(r)
        dense_edges = {
            (j, i)
            for i in range(K)
            for j in range(K)
            if i != j and (w[r, i, j] != 0.0 or beta[r, i, j] != 0.0)
        }
        assert set(zip(send.tolist(), recv.tolist())) == dense_edges
        for j, i, wt in zip(send, recv, weights):
            assert wt == w[r, i, j]


def test_degree_bound_validation():
    sched = _schedule("static", {})
    w, beta = gl.schedule_matrices(sched, "data_weighted",
                                   data_sizes=np.ones(K, int))
    # ring in-degree is 2; a bound of 1 must refuse, not silently truncate
    with pytest.raises(ValueError, match="degree"):
        gl.SparseSchedule.from_dense(w, beta, stochasticity="row", degree_bound=1)
    # an explicit larger bound pads and still round-trips exactly
    sp = gl.SparseSchedule.from_dense(w, beta, stochasticity="row", degree_bound=5)
    assert sp.degree_bound == 5
    w2, beta2 = sp.to_dense()
    assert np.array_equal(w, w2)
    assert np.array_equal(beta, beta2)


def test_shapes_and_dtypes():
    sched = _schedule("link_dropout", {})
    sp = gl.SparseSchedule.from_schedule(
        sched, "data_weighted", data_sizes=np.ones(K, int) * 2,
        consensus_step_size=1.0,
    )
    r, k, d = sp.period, sp.num_peers, sp.degree_bound
    assert sp.self_w.shape == (r, k)
    assert sp.nbr_idx.shape == sp.nbr_w.shape == sp.beta.shape == (r, k, d)
    assert sp.nbr_idx.dtype == np.int32
    assert (sp.nbr_idx >= 0).all() and (sp.nbr_idx < k).all()


def test_large_k_build_stays_sparse():
    """K = 4096 ring: the sparse build never allocates a (K, K) array and the
    degree bound stays at the topology's in-degree (2), so the whole schedule
    is R * K * 2 weights — the form the large-K runtime consumes."""
    bigk = 4096
    cfg = p2p.P2PConfig(num_peers=bigk, topology="ring", schedule="static",
                        protocol="gossip")
    sched = p2p.build_schedule(cfg)
    sp = gl.SparseSchedule.from_schedule(
        sched, "metropolis", data_sizes=None, consensus_step_size=1.0,
    )
    assert sp.num_peers == bigk
    assert sp.degree_bound == 2
    assert sp.nbr_w.shape == (1, bigk, 2)
    # spot-check one row against the metropolis rule: ring degree 2
    # everywhere -> off-diagonal weight 1/3
    np.testing.assert_allclose(sp.nbr_w[0, 17], [1 / 3, 1 / 3])
