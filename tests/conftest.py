"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the 1 real CPU device
(the 512-device override belongs ONLY to repro.launch.dryrun)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def mnist_small():
    from repro.data import synthetic

    return synthetic.mnist_like(4000, 1000)
