"""Regression tests for the data/partition.py correctness fixes.

Each test pins a bug that silently corrupted the data-weighted consensus
math: dropped remainder samples (IID), empty peers (Dirichlet at small
alpha), and silently-empty class selections (pathological with a bad label).
"""
import numpy as np
import pytest

from repro.data import partition


def _toy(n, num_classes=10, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 4)).astype(np.float32)
    y = rng.integers(0, num_classes, size=n).astype(np.int64)
    return x, y


class TestIIDPartition:
    @pytest.mark.parametrize("n,k", [(103, 8), (100, 7), (64, 8), (9, 8)])
    def test_full_coverage_non_divisible(self, n, k):
        x, y = _toy(n)
        parts = partition.iid_partition(x, y, k, seed=1)
        assert len(parts) == k
        assert int(partition.data_sizes(parts).sum()) == n

    def test_remainder_spread_over_first_peers(self):
        x, y = _toy(103)
        sizes = partition.data_sizes(partition.iid_partition(x, y, 8))
        # 103 = 8*12 + 7: first 7 peers get 13, last gets 12.
        assert sizes.tolist() == [13] * 7 + [12]

    def test_partition_is_disjoint_union(self):
        x, y = _toy(50)
        x = np.arange(50, dtype=np.float32).reshape(50, 1)  # unique values
        parts = partition.iid_partition(x, y[:50], 7, seed=3)
        seen = np.concatenate([p[0][:, 0] for p in parts])
        assert sorted(seen.tolist()) == list(range(50))


class TestDirichletPartition:
    @pytest.mark.parametrize("alpha", [0.01, 0.05, 0.1])
    def test_small_alpha_no_empty_peers(self, alpha):
        x, y = _toy(200)
        for seed in range(5):
            parts = partition.dirichlet_partition(
                x, y, 16, alpha=alpha, seed=seed
            )
            sizes = partition.data_sizes(parts)
            assert (sizes >= 1).all(), f"empty peer at alpha={alpha} seed={seed}"
            assert int(sizes.sum()) == len(x)

    def test_too_few_samples_raises(self):
        x, y = _toy(4)
        with pytest.raises(ValueError, match="at least one sample per peer"):
            partition.dirichlet_partition(x, y, 8)

    def test_moderate_alpha_unchanged_total(self):
        x, y = _toy(500)
        parts = partition.dirichlet_partition(x, y, 8, alpha=0.5, seed=0)
        assert int(partition.data_sizes(parts).sum()) == 500


class TestPathologicalPartition:
    def test_bad_label_raises_with_offender(self):
        x, y = _toy(100, num_classes=10)
        with pytest.raises(ValueError, match="class 37"):
            partition.pathological_partition(x, y, [(0, 1), (37, 8)])

    def test_valid_labels_still_work(self):
        x, y = _toy(200, num_classes=10)
        parts = partition.pathological_partition(
            x, y, [(0, 1), (2, 3)], samples_per_class=5
        )
        assert len(parts) == 2
        assert set(np.unique(parts[0][1]).tolist()) <= {0, 1}
        assert set(np.unique(parts[1][1]).tolist()) <= {2, 3}
