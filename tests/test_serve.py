"""Serving-path contract: scanned decode, fleet routing, cache semantics.

The stacked K-model serving runtime (``launch/serve.py`` + the generate
builders in ``launch/steps.py``) has four load-bearing claims, each pinned
here at test scale:

* **Parity** — the fused ``lax.scan`` decode produces bit-identical greedy
  tokens to the legacy per-token python loop, for a text decoder AND a vlm
  (whose image patches shift the decode start position).
* **Fleet == sequential** — one stacked vmap call over K models is
  bit-identical (``np.array_equal``, not allclose) to serving each model
  separately, for the LLM generate path and the paper's 2NN classifier.
* **One compile** — ``peer_ids`` routing is traced: re-routing never
  retraces the jitted fleet; the scanned decode traces its step body once
  regardless of generation length.
* **Cache discipline** — generate fills cache position slots exactly
  0..dec_len+gen-2 (patches included in dec_len), and donated caches are
  consumed (buffers reused, inputs deleted).

The pod-layout test needs one device per peer and carries the ``mesh``
marker (same contract as tests/test_mesh_runtime.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import p2p
from repro.launch import serve as serve_lib
from repro.launch import steps as steps_lib
from repro.models import build_model, mlp

ARCHS = ["smollm-135m", "internvl2-2b"]  # text decoder + vlm (prefix patches)
K = 8

needs_mesh = pytest.mark.skipif(
    jax.device_count() < K,
    reason=f"needs >= {K} devices "
           f"(XLA_FLAGS=--xla_force_host_platform_device_count={K})",
)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            model = build_model(reduced(get_config(name)))
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (model, params)
        return cache[name]

    return get


# ---------------------------------------------------------------- parity


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_decode_matches_python_loop(arch, built):
    """Greedy generation under ONE lax.scan == the per-token python loop."""
    model, params = built(arch)
    batch_size, prompt_len, gen = 2, 8, 5
    prompt = model.make_batch(jax.random.PRNGKey(1), batch_size, prompt_len)
    dec_len = steps_lib.prompt_dec_len(prompt)

    prefill = jax.jit(steps_lib.make_prefill_step(model))
    serve = jax.jit(steps_lib.make_serve_step(model))
    tok, cache = prefill(params, prompt, model.init_cache(batch_size, dec_len + gen))
    pos = jnp.full((batch_size,), dec_len, jnp.int32)
    toks = [tok]
    for _ in range(gen - 1):
        tok, pos, cache = serve(params, cache, tok, pos)
        toks.append(tok)
    loop_tokens = np.asarray(jnp.stack(toks, axis=1))

    generate = jax.jit(steps_lib.make_generate_fn(model, gen))
    scan_tokens, _ = generate(params, prompt, model.init_cache(batch_size, dec_len + gen))
    assert np.array_equal(np.asarray(scan_tokens), loop_tokens)


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_batch_scan_matches_python_impl(arch):
    """The serve_batch entry point: both decode_impl values, same tokens."""
    out_scan = serve_lib.serve_batch(arch, batch=2, prompt_len=8, gen_tokens=5,
                                     decode_impl="scan")
    out_py = serve_lib.serve_batch(arch, batch=2, prompt_len=8, gen_tokens=5,
                                   decode_impl="python")
    assert np.array_equal(np.asarray(out_scan["tokens"]), np.asarray(out_py["tokens"]))
    assert out_scan["decode_steps"] == out_py["decode_steps"] == 4


# ------------------------------------------------- gen_tokens=1 boundary


def test_gen_tokens_one_is_explicit_empty_decode():
    """gen_tokens=1 samples ONLY the prefill token: (B, 1), no decode rate."""
    out = serve_lib.serve_batch("smollm-135m", batch=2, prompt_len=8, gen_tokens=1)
    assert out["tokens"].shape == (2, 1)
    assert out["decode_steps"] == 0
    assert out["decode_s_per_token"] is None
    # the single token is the prefill argmax, not a decode-step product
    many = serve_lib.serve_batch("smollm-135m", batch=2, prompt_len=8, gen_tokens=5)
    assert np.array_equal(np.asarray(out["tokens"]), np.asarray(many["tokens"][:, :1]))


def test_degenerate_lengths_rejected():
    with pytest.raises(ValueError, match="gen_tokens"):
        serve_lib.serve_batch("smollm-135m", gen_tokens=0)
    model = build_model(reduced(get_config("smollm-135m")))
    with pytest.raises(ValueError, match="gen_tokens"):
        steps_lib.make_generate_fn(model, 0)
    with pytest.raises(ValueError, match="num_steps"):
        steps_lib.make_decode_scan(model, 0)
    with pytest.raises(ValueError, match="decode_impl"):
        serve_lib.serve_batch("smollm-135m", decode_impl="loop")


# ------------------------------------------------------- cache semantics


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_positions_filled_exactly(arch, built):
    """Generate fills cache slots 0..dec_len+gen-2; untouched slots stay -1.

    dec_len counts vlm patches (they occupy decoder cache slots before the
    text tokens), which is exactly what ``prompt_dec_len`` exists to get
    right — the internvl2 case fails if decode restarts at tokens-only
    length.
    """
    model, params = built(arch)
    batch_size, prompt_len, gen = 2, 8, 5
    prompt = model.make_batch(jax.random.PRNGKey(1), batch_size, prompt_len)
    dec_len = steps_lib.prompt_dec_len(prompt)
    if arch == "internvl2-2b":
        assert dec_len > prompt["tokens"].shape[1]  # patches really add slots

    generate = jax.jit(steps_lib.make_generate_fn(model, gen))
    cache_size = dec_len + gen + 3  # slack: unwritten slots must stay -1
    _, cache = generate(params, prompt, model.init_cache(batch_size, cache_size))
    pos_ids = np.asarray(cache["main"]["pos_ids"])  # (layers, B, cache_len)
    # prefill writes 0..dec_len-1, the gen-1 decode steps write up to
    # dec_len+gen-2; the prefill-sampled token itself is never cached
    expect = set(range(dec_len + gen - 1)) | {-1}
    for layer in range(pos_ids.shape[0]):
        for row in range(batch_size):
            assert set(pos_ids[layer, row].tolist()) == expect


def test_generate_cache_donation():
    """jit(generate, donate_argnums=(2,)) consumes the input cache buffers."""
    model, params = built_single("smollm-135m")
    prompt = model.make_batch(jax.random.PRNGKey(1), 2, 8)
    cache = model.init_cache(2, 13)
    cache = jax.tree.map(jnp.asarray, cache)  # materialize donate-able buffers
    generate = jax.jit(steps_lib.make_generate_fn(model, 5), donate_argnums=(2,))
    jax.block_until_ready(generate(params, prompt, cache))
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(cache))


def built_single(name):
    model = build_model(reduced(get_config(name)))
    return model, model.init(jax.random.PRNGKey(0))


# ------------------------------------------------------- one-compile rule


def test_decode_scan_traces_step_once():
    """The scanned decode traces its per-token body ONCE, not once per token."""
    model, _ = built_single("smollm-135m")
    traces = [0]
    inner = model.decode_step

    def counting_decode_step(params, token, pos, cache):
        traces[0] += 1
        return inner(params, token, pos, cache)

    counted = dataclasses.replace(model, decode_step=counting_decode_step)
    params = counted.init(jax.random.PRNGKey(0))
    prompt = counted.make_batch(jax.random.PRNGKey(1), 2, 8)
    generate = jax.jit(steps_lib.make_generate_fn(counted, 7))
    jax.block_until_ready(generate(params, prompt, counted.init_cache(2, 15)))
    # one trace inside lax.scan (jax may re-trace once for lowering); the
    # python loop would hit this 6 times even under jit
    assert traces[0] <= 2
    assert generate._cache_size() == 1


def test_fleet_routing_is_traced_one_compile():
    """Re-routing peer_ids re-uses the ONE compiled fleet executable."""
    model, _ = built_single("smollm-135m")
    stacked = jax.vmap(model.init)(jax.random.split(jax.random.PRNGKey(0), 3))
    prompts = jax.vmap(lambda k: model.make_batch(k, 2, 8))(
        jax.random.split(jax.random.PRNGKey(1), 2)
    )
    fleet = jax.jit(serve_lib.make_fleet_generate_fn(model, 4))

    def caches():
        return serve_lib.stack_request_caches(model.init_cache(2, 12), 2)

    toks_a, _ = fleet(stacked, prompts, caches(), jnp.array([2, 0], jnp.int32))
    toks_b, _ = fleet(stacked, prompts, caches(), jnp.array([1, 1], jnp.int32))
    assert fleet._cache_size() == 1  # routing is data, not structure

    # and the routing is CORRECT: group g decoded under params[peer_ids[g]]
    single = jax.jit(steps_lib.make_generate_fn(model, 4))
    for g, k in [(0, 2), (1, 0)]:
        want, _ = single(
            jax.tree.map(lambda p, k=k: p[k], stacked),
            jax.tree.map(lambda p, g=g: p[g], prompts),
            model.init_cache(2, 12),
        )
        assert np.array_equal(np.asarray(toks_a[g]), np.asarray(want))
    want, _ = single(
        jax.tree.map(lambda p: p[1], stacked),
        jax.tree.map(lambda p: p[0], prompts),
        model.init_cache(2, 12),
    )
    assert np.array_equal(np.asarray(toks_b[0]), np.asarray(want))


# -------------------------------------------------- fleet == sequential


def test_fleet_generate_bit_identical_to_sequential():
    """One stacked call == K separate serves, token for token (fp32 CPU)."""
    model, _ = built_single("smollm-135m")
    k = 3
    stacked = jax.vmap(model.init)(jax.random.split(jax.random.PRNGKey(0), k))
    prompts = jax.vmap(lambda r: model.make_batch(r, 2, 8))(
        jax.random.split(jax.random.PRNGKey(1), k)
    )
    fleet = jax.jit(serve_lib.make_fleet_generate_fn(model, 5), donate_argnums=(2,))
    toks, _ = fleet(
        stacked, prompts,
        serve_lib.stack_request_caches(model.init_cache(2, 13), k),
        jnp.arange(k, dtype=jnp.int32),
    )
    single = jax.jit(steps_lib.make_generate_fn(model, 5))
    for i in range(k):
        want, _ = single(
            jax.tree.map(lambda p, i=i: p[i], stacked),
            jax.tree.map(lambda p, i=i: p[i], prompts),
            model.init_cache(2, 13),
        )
        assert np.array_equal(np.asarray(toks[i]), np.asarray(want))


def test_fleet_classify_bit_identical_to_sequential():
    """The 2NN classifier fleet (the paper's model): stacked == per-peer."""
    k, n = 4, 16
    stacked = jax.vmap(lambda r: mlp.init_2nn(r))(
        jax.random.split(jax.random.PRNGKey(0), k)
    )
    inputs = jax.random.normal(jax.random.PRNGKey(1), (k, n, 784))
    classify = jax.jit(serve_lib.make_fleet_classify_fn(mlp.apply_2nn))
    logits = classify(stacked, inputs, jnp.arange(k, dtype=jnp.int32))
    for i in range(k):
        want = mlp.apply_2nn(jax.tree.map(lambda p, i=i: p[i], stacked), inputs[i])
        assert np.array_equal(np.asarray(logits[i]), np.asarray(want))
    # permuted routing: every group classified under the REVERSED peer's model
    rev = classify(stacked, inputs, jnp.arange(k - 1, -1, -1, dtype=jnp.int32))
    for i in range(k):
        want = mlp.apply_2nn(
            jax.tree.map(lambda p, i=i: p[k - 1 - i], stacked), inputs[i]
        )
        assert np.array_equal(np.asarray(rev[i]), np.asarray(want))
    assert classify._cache_size() == 1


# ------------------------------------------- consensus-averaged baseline


def test_consensus_averaged_params_layout_and_values():
    """Averaged baseline: every peer row == the (weighted) fleet mean."""
    k = 4
    stacked = jax.vmap(lambda r: mlp.init_2nn(r, in_dim=6, hidden=5))(
        jax.random.split(jax.random.PRNGKey(0), k)
    )
    avg = p2p.consensus_averaged_params(stacked)
    for leaf, src in zip(jax.tree.leaves(avg), jax.tree.leaves(stacked)):
        assert leaf.shape == src.shape  # same stacked layout: serving reuses it
        want = np.mean(np.asarray(src), axis=0)
        for row in np.asarray(leaf):
            np.testing.assert_allclose(row, want, rtol=1e-5, atol=1e-7)
    sizes = np.array([1.0, 3.0, 0.0, 0.0])
    weighted = p2p.consensus_averaged_params(stacked, data_sizes=sizes)
    for leaf, src in zip(jax.tree.leaves(weighted), jax.tree.leaves(stacked)):
        want = 0.25 * np.asarray(src)[0] + 0.75 * np.asarray(src)[1]
        np.testing.assert_allclose(np.asarray(leaf)[2], want, rtol=1e-5, atol=1e-7)


# ------------------------------------------------------------ pod layout


@needs_mesh
@pytest.mark.mesh
def test_fleet_pod_layout_matches_vmap():
    """The SAME jitted fleet over mesh-sharded rows: bit-identical tokens."""
    from repro.launch import mesh as mesh_lib
    from repro.sharding import specs as specs_lib

    model, _ = built_single("smollm-135m")
    stacked = jax.vmap(model.init)(jax.random.split(jax.random.PRNGKey(0), K))
    prompts = jax.vmap(lambda r: model.make_batch(r, 2, 8))(
        jax.random.split(jax.random.PRNGKey(1), K)
    )
    ids = jnp.arange(K, dtype=jnp.int32)
    fleet = jax.jit(serve_lib.make_fleet_generate_fn(model, 4), donate_argnums=(2,))

    def caches():
        return serve_lib.stack_request_caches(model.init_cache(2, 12), K)

    ref, _ = fleet(stacked, prompts, caches(), ids)

    mesh = mesh_lib.make_peer_mesh(K)
    pod, _ = fleet(
        specs_lib.shard_peer_tree(stacked, mesh),
        specs_lib.shard_peer_tree(prompts, mesh),
        specs_lib.shard_peer_tree(caches(), mesh),
        specs_lib.shard_peer_tree(ids, mesh),
    )
    assert np.array_equal(np.asarray(ref), np.asarray(pod))
