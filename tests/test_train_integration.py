"""End-to-end integration: the paper's phenomena on synthetic MNIST, and
P2P training of the LLM substrate.  Slower tests (~2 min total on CPU)."""
import numpy as np
import pytest

from repro.configs.p2pl_mnist import directed_k8, noniid_k2
from repro.data import synthetic
from repro.launch.train import run_p2p_lm, run_paper_experiment


@pytest.fixture(scope="module")
def data():
    return synthetic.mnist_like(6000, 1500)


@pytest.fixture(scope="module")
def local_dsgd_log(data):
    return run_paper_experiment(
        noniid_k2(algorithm="local_dsgd", local_steps=10), rounds=12, data=data)


def test_forgetting_and_consensus_recovery(local_dsgd_log):
    """Fig. 3c: local training forgets unseen classes (down to ~0%), consensus
    restores them; accuracy after consensus > after local on unseen."""
    log = local_dsgd_log
    # device A (peer 0): unseen classes are peer 1's {7, 8}
    a_local = np.stack(log.after_local["peer1_seen"])[:, 0]
    a_cons = np.stack(log.after_consensus["peer1_seen"])[:, 0]
    assert a_local.min() < 0.05  # forgetting: drops to ~0% after local phase
    assert (a_cons - a_local).mean() > 0.1  # consensus recovers unseen classes


def test_seen_class_oscillation_is_opposite(local_dsgd_log):
    """Seen classes: local training helps, consensus pulls down (Fig. 3d)."""
    log = local_dsgd_log
    s_local = np.stack(log.after_local["peer0_seen"])[:, 0]
    s_cons = np.stack(log.after_consensus["peer0_seen"])[:, 0]
    assert (s_local - s_cons).mean() > 0.0


def test_affinity_damps_oscillations(data, local_dsgd_log):
    """Fig. 6: P2PL with Affinity reduces unseen-class oscillation amplitude
    vs. local DSGD at identical communication cost."""
    log_aff = run_paper_experiment(
        noniid_k2(algorithm="p2pl_affinity", local_steps=10), rounds=12,
        data=data)
    osc_plain = local_dsgd_log.mean_oscillation("peer1_seen")
    osc_aff = log_aff.mean_oscillation("peer1_seen")
    assert osc_aff < osc_plain, (osc_aff, osc_plain)


def test_dsgd_smaller_oscillation_than_local_dsgd(data, local_dsgd_log):
    """Fig. 4: fewer local steps between consensus -> smaller oscillations."""
    log_dsgd = run_paper_experiment(
        noniid_k2(algorithm="dsgd", local_steps=1), rounds=12, data=data)
    assert log_dsgd.mean_oscillation("peer1_seen") < local_dsgd_log.mean_oscillation(
        "peer1_seen"
    )


def test_drift_grows_locally_shrinks_at_consensus(local_dsgd_log):
    drift = np.asarray(local_dsgd_log.drift)  # recorded after local phase
    cons_err = np.asarray(local_dsgd_log.consensus_error)  # after consensus
    assert drift.mean() > cons_err.mean()


def test_directed_k8_push_sum_trains(data):
    """The directed-ring push-sum experiment runs end to end: finite losses,
    conserved mass, consensus actually mixes the one-way ring."""
    exp = directed_k8(schedule="static", protocol="push_sum",
                      algorithm="p2pl_affinity", local_steps=10)
    log = run_paper_experiment(exp, rounds=6, data=data)
    assert np.isfinite(log.train_loss).all()
    # consensus over the directed ring must pull peers together vs local drift
    assert np.asarray(log.consensus_error).mean() < np.asarray(log.drift).mean()


def test_cli_round_robin_and_protocol_flags(data, capsys, monkeypatch):
    """--schedule round_robin + --round-robin-topologies + --protocol are
    reachable from the command line (satellite: round_robin was Python-only)."""
    from repro.launch import train as train_mod

    monkeypatch.setattr(
        train_mod, "run_paper_experiment",
        # `data` binds the module fixture (main() never passes it): the CLI
        # test must run on the small dataset, not the 60k default
        lambda exp, rounds=None, **kw:
        run_paper_experiment(exp, rounds=1, data=data, **kw),
    )
    train_mod.main([
        "--experiment", "timevarying_k2", "--schedule", "round_robin",
        "--round-robin-topologies", "complete,disconnected",
        "--protocol", "push_sum", "--rounds", "1",
    ])
    assert "done in" in capsys.readouterr().out


def test_cli_adaptive_composes_with_scan_driver(data, capsys, monkeypatch):
    """--schedule adaptive + --partner-rule + --adaptive-eps reach the
    runtime and compose with --driver scan (the default production driver)."""
    from repro.launch import train as train_mod

    seen = {}

    def _capture(exp, rounds=None, **kw):
        seen["exp"], seen["kw"] = exp, kw
        return run_paper_experiment(exp, rounds=1, data=data, **kw)

    monkeypatch.setattr(train_mod, "run_paper_experiment", _capture)
    train_mod.main([
        "--experiment", "timevarying_k2", "--schedule", "adaptive",
        "--partner-rule", "eps_greedy", "--adaptive-eps", "0.3",
        "--adaptive-seed", "7", "--driver", "scan", "--rounds", "1",
    ])
    assert "done in" in capsys.readouterr().out
    assert seen["exp"].p2p.schedule == "adaptive"
    assert seen["exp"].p2p.partner_rule == "eps_greedy"
    assert seen["exp"].p2p.adaptive_eps == 0.3
    assert seen["exp"].p2p.adaptive_seed == 7
    assert seen["kw"]["driver"] == "scan"


def test_cli_rejects_unknown_partner_rule(capsys):
    from repro.launch import train

    with pytest.raises(SystemExit) as excinfo:
        train.main(["--experiment", "timevarying_k8", "--schedule", "adaptive",
                    "--partner-rule", "loss_proximty", "--rounds", "1"])
    assert excinfo.value.code == 2  # argparse choices error, before any jax work
    assert "--partner-rule" in capsys.readouterr().err


def test_cli_rejects_out_of_range_adaptive_eps(capsys):
    from repro.launch import train

    with pytest.raises(SystemExit) as excinfo:
        train.main(["--experiment", "timevarying_k8", "--schedule", "adaptive",
                    "--partner-rule", "eps_greedy", "--adaptive-eps", "1.5",
                    "--rounds", "1"])
    assert excinfo.value.code == 2
    assert "--adaptive-eps" in capsys.readouterr().err


@pytest.mark.skipif(
    __import__("jax").device_count() >= 2,
    reason="exercises the too-few-devices CLI error (single-device env only)",
)
def test_cli_adaptive_pod_still_fails_fast_on_missing_devices(capsys):
    """--schedule adaptive composes with --peer-axis pod: the device-count
    fail-fast (with the XLA_FLAGS hint) fires before tracing, exactly as on
    pretraced schedules."""
    from repro.launch import train

    with pytest.raises(SystemExit) as excinfo:
        train.main(["--experiment", "sharded_k8", "--schedule", "adaptive",
                    "--peer-axis", "pod", "--rounds", "1"])
    assert excinfo.value.code == 2
    err = capsys.readouterr().err
    assert "xla_force_host_platform_device_count" in err
    assert "num_peers=8" in err


def test_p2p_lm_training_reduces_loss_and_drift():
    """The paper's algorithm drives a (reduced) assigned arch: loss falls,
    consensus keeps peer models close."""
    out = run_p2p_lm("smollm-135m", num_peers=2, local_steps=4, rounds=25,
                     batch=8, seq=16, lr=5e-2, momentum=0.5)
    # vocab restricted to per-peer spans: achievable loss is ln(vocab/2),
    # ~0.7 nats under the ln(vocab) starting point — expect a clear drop
    assert min(out["losses"][-5:]) < out["losses"][0] - 0.3, out["losses"]
    assert np.isfinite(out["final_drift"])
