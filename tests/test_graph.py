"""Communication graphs and mixing matrices.

Hypothesis-based property tests over random graphs live in
tests/test_property.py (skipped cleanly when hypothesis is absent); this
module must collect and pass on the bare seed environment.
"""
import numpy as np
import pytest

from repro.core import graph as gl


@pytest.mark.parametrize("topo,k", [
    ("complete", 5), ("ring", 6), ("chain", 4), ("star", 7),
    ("torus2d", 9), ("hypercube", 8), ("erdos_renyi", 10),
])
def test_topologies_connected(topo, k):
    g = gl.build_graph(topo, k)
    assert g.num_peers == k
    assert g.is_connected()
    assert not g.adjacency.diagonal().any()


def test_disconnected_graph():
    g = gl.build_graph("disconnected", 4)
    assert not g.is_connected()
    assert g.degree().sum() == 0


def test_torus_requires_square():
    with pytest.raises(ValueError):
        gl.build_graph("torus2d", 8)


@pytest.mark.parametrize("mixing", ["data_weighted", "metropolis", "uniform_neighbor"])
@pytest.mark.parametrize("topo", ["complete", "ring", "star"])
def test_mixing_row_stochastic(mixing, topo):
    g = gl.build_graph(topo, 6)
    n = np.array([10, 20, 30, 40, 50, 60])
    w = gl.mixing_matrix(g, mixing, data_sizes=n)
    assert np.allclose(w.sum(1), 1.0)
    assert (w >= -1e-12).all()
    # zeros outside the graph edges (+diagonal)
    mask = g.adjacency | np.eye(6, dtype=bool)
    assert np.allclose(w[~mask], 0.0)


def test_paper_data_weighted_formula():
    """alpha_kj = n_j / (n_k + sum_{i in N(k)} n_i) — Sec. V-A."""
    g = gl.build_graph("complete", 3)
    n = np.array([100.0, 200.0, 300.0])
    w = gl.mixing_matrix(g, "data_weighted", data_sizes=n)
    assert np.isclose(w[0, 1], 200 / 600)
    assert np.isclose(w[0, 2], 300 / 600)
    assert np.isclose(w[0, 0], 100 / 600)


def test_metropolis_doubly_stochastic():
    g = gl.build_graph("erdos_renyi", 8, seed=3)
    w = gl.mixing_matrix(g, "metropolis")
    assert np.allclose(w.sum(0), 1.0)
    assert np.allclose(w.sum(1), 1.0)


def test_consensus_step_size():
    g = gl.build_graph("ring", 4)
    w1 = gl.mixing_matrix(g, "metropolis", consensus_step_size=1.0)
    w0 = gl.mixing_matrix(g, "metropolis", consensus_step_size=0.0)
    wh = gl.mixing_matrix(g, "metropolis", consensus_step_size=0.5)
    assert np.allclose(w0, np.eye(4))
    assert np.allclose(wh, 0.5 * np.eye(4) + 0.5 * w1)


def test_affinity_matrix_rows():
    g = gl.build_graph("star", 5)
    b = gl.affinity_matrix(g, data_sizes=[1, 2, 3, 4, 5])
    assert np.allclose(b.sum(1), 1.0)  # rows sum to 1 over neighbors
    assert np.allclose(np.diag(b), 0.0)  # no self weight in beta


def test_spectral_gap_ordering():
    """Better-connected graphs have larger spectral gaps (faster consensus)."""
    gaps = {}
    for topo in ("complete", "torus2d", "ring", "chain"):
        g = gl.build_graph(topo, 16)
        gaps[topo] = gl.spectral_gap(gl.mixing_matrix(g, "metropolis"))
    assert gaps["complete"] > gaps["torus2d"] > gaps["ring"] > gaps["chain"] > 0


# ---------------------------------------------------------------------------
# Permutation-lane extraction (sharded peer-axis runtime)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo,k", [
    ("complete", 5), ("ring", 6), ("chain", 4), ("star", 7),
    ("hypercube", 8), ("erdos_renyi", 10), ("directed_ring", 6),
    ("disconnected", 4),
])
def test_edge_color_lanes_partition_the_edge_set(topo, k):
    """Lanes cover every edge exactly once, and each lane is ppermute-legal
    (distinct sources, distinct destinations)."""
    g = gl.build_graph(topo, k)
    lanes = gl.edge_color_lanes(g.adjacency)
    seen = np.zeros((k, k), dtype=int)
    for lane in lanes:
        srcs = [s for s, _ in lane.perm]
        dsts = [d for _, d in lane.perm]
        assert len(set(srcs)) == len(srcs), "duplicate source in one ppermute"
        assert len(set(dsts)) == len(dsts), "duplicate destination in one ppermute"
        for s, d in lane.perm:
            seen[s, d] += 1
        # src_for_dst is the receiver-side view of the same pairs
        src_map = np.asarray(lane.src_for_dst)
        assert src_map.shape == (k,)
        for d in range(k):
            if src_map[d] == k:
                assert d not in dsts
            else:
                assert (int(src_map[d]), d) in lane.perm
    np.testing.assert_array_equal(seen, g.adjacency.astype(int))


def test_edge_color_lanes_count_is_tight_for_regular_graphs():
    ring = gl.build_graph("ring", 6)
    assert len(gl.edge_color_lanes(ring.adjacency)) == 2  # one per direction
    d_ring = gl.build_graph("directed_ring", 6)
    assert len(gl.edge_color_lanes(d_ring.adjacency)) == 1
    assert gl.edge_color_lanes(np.zeros((4, 4), dtype=bool)) == ()


def test_schedule_lanes_cover_the_period_union():
    sched = gl.link_dropout_schedule(gl.build_graph("ring", 8), 0.6, 5, seed=3)
    lanes = gl.schedule_lanes(sched)
    covered = np.zeros((8, 8), dtype=bool)
    for lane in lanes:
        for s, d in lane.perm:
            covered[s, d] = True
    np.testing.assert_array_equal(covered, sched.union_graph().adjacency)
