"""Per-kernel shape/dtype sweeps: pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.consensus_mix import ops as cm_ops
from repro.kernels.consensus_mix import ref as cm_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2.ops import ssd
from repro.kernels.mamba2.ref import ssd_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref

TOL = {jnp.float32: dict(atol=5e-5, rtol=1e-4), jnp.bfloat16: dict(atol=5e-2, rtol=5e-2)}


# ---------------------------------------------------------------------------
# consensus_mix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 257, 1000, 4096])
@pytest.mark.parametrize("d", [1, 3, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_consensus_mix_sweep(n, d, dtype, rng):
    x = jnp.asarray(rng.normal(size=n), dtype)
    nbrs = jnp.asarray(rng.normal(size=(d, n)), dtype)
    w_nbr = jnp.asarray(rng.dirichlet(np.ones(d + 1))[:d], jnp.float32)
    w_self = jnp.asarray(1.0 - w_nbr.sum())
    beta = jnp.asarray(rng.dirichlet(np.ones(d)), jnp.float32)
    got_m, got_d = cm_ops.consensus_mix_flat(x, nbrs, w_self, w_nbr, beta, 10)
    want_m, want_d = cm_ref.consensus_mix_ref(x, nbrs, w_self, w_nbr, beta, 10)
    np.testing.assert_allclose(
        np.asarray(got_m, np.float32), np.asarray(want_m, np.float32), **TOL[dtype]
    )
    np.testing.assert_allclose(
        np.asarray(got_d, np.float32), np.asarray(want_d, np.float32), **TOL[dtype]
    )


def test_consensus_mix_preserves_constant(rng):
    """Row-stochastic mixing of identical params is the identity."""
    n = 512
    x = jnp.ones((n,), jnp.float32) * 3.25
    nbrs = jnp.broadcast_to(x, (4, n))
    w_nbr = jnp.full((4,), 0.2, jnp.float32)
    got_m, got_d = cm_ops.consensus_mix_flat(x, nbrs, jnp.asarray(0.2), w_nbr,
                                             jnp.full((4,), 0.25, jnp.float32), 5)
    np.testing.assert_allclose(np.asarray(got_m), 3.25, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_d), 0.0, atol=1e-7)


# ---------------------------------------------------------------------------
# consensus_mix segment (edge-list gather inside the kernel)
# ---------------------------------------------------------------------------


def _sparse_round(k, rng, schedule="link_dropout", stochasticity="row"):
    from repro.core import graph as gl
    from repro.core import p2p

    cfg = p2p.P2PConfig(num_peers=k, topology="ring", schedule=schedule,
                        schedule_rounds=3, protocol="gossip")
    sp = gl.SparseSchedule.from_schedule(
        p2p.build_schedule(cfg), "data_weighted",
        data_sizes=rng.integers(5, 30, size=k),
        consensus_step_size=0.8, stochasticity=stochasticity,
    )
    return sp, sp.to_dense()


@pytest.mark.parametrize("k,n", [(8, 64), (16, 300), (8, 1000)])
def test_segment_mix_matches_dense_ref(k, n, rng):
    from repro.kernels.consensus_mix import segment as cm_seg

    sp, (w_np, b_np) = _sparse_round(k, rng)
    flat = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    stacked = {"w": flat}
    for r in range(sp.period):
        got_m, got_d = cm_seg.segment_mix_stacked(
            stacked, jnp.asarray(sp.self_w[r], jnp.float32),
            jnp.asarray(sp.nbr_idx[r]), jnp.asarray(sp.nbr_w[r], jnp.float32),
            jnp.asarray(sp.beta[r], jnp.float32), 5,
        )
        want_m, want_d = cm_ref.segment_mix_ref(
            flat, jnp.asarray(w_np[r], jnp.float32),
            jnp.asarray(b_np[r], jnp.float32), 5,
        )
        np.testing.assert_allclose(
            np.asarray(got_m["w"]), np.asarray(want_m), atol=5e-5, rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(got_d["w"]), np.asarray(want_d), atol=5e-5, rtol=1e-4
        )


def test_segment_mix_push_sum_matches_dense_ref(rng):
    from repro.kernels.consensus_mix import segment as cm_seg

    k, n = 16, 200
    sp, (a_np, b_np) = _sparse_round(k, rng, stochasticity="column")
    flat = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    mass = jnp.asarray(rng.uniform(0.5, 2.0, size=k), jnp.float32)
    for r in range(sp.period):
        got_m, got_d, got_y = cm_seg.segment_mix_push_sum_stacked(
            {"w": flat}, mass, jnp.asarray(sp.self_w[r], jnp.float32),
            jnp.asarray(sp.nbr_idx[r]), jnp.asarray(sp.nbr_w[r], jnp.float32),
            jnp.asarray(sp.beta[r], jnp.float32), 5,
        )
        want_m, want_d, want_y = cm_ref.segment_mix_push_sum_ref(
            flat, mass, jnp.asarray(a_np[r], jnp.float32),
            jnp.asarray(b_np[r], jnp.float32), 5,
        )
        np.testing.assert_allclose(
            np.asarray(got_m["w"]), np.asarray(want_m), atol=5e-5, rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(got_d["w"]), np.asarray(want_d), atol=5e-5, rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(got_y), np.asarray(want_y), atol=5e-6, rtol=1e-5
        )


def test_segment_mix_schedule_selects_round(rng):
    from repro.kernels.consensus_mix import segment as cm_seg

    k, n = 8, 128
    sp, (w_np, b_np) = _sparse_round(k, rng)
    flat = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    stacks = (
        jnp.asarray(sp.self_w, jnp.float32), jnp.asarray(sp.nbr_idx),
        jnp.asarray(sp.nbr_w, jnp.float32), jnp.asarray(sp.beta, jnp.float32),
    )
    got_m, _ = cm_seg.segment_mix_schedule({"w": flat}, jnp.int32(4), *stacks, 5)
    r = 4 % sp.period
    want_m, _ = cm_ref.segment_mix_ref(
        flat, jnp.asarray(w_np[r], jnp.float32),
        jnp.asarray(b_np[r], jnp.float32), 5,
    )
    np.testing.assert_allclose(
        np.asarray(got_m["w"]), np.asarray(want_m), atol=5e-5, rtol=1e-4
    )


def test_segment_mix_isolated_peer_keeps_zero_d(rng):
    """A peer with an all-zero beta row (degree-0 this round) keeps d = 0."""
    from repro.kernels.consensus_mix import segment as cm_seg

    k, n = 4, 128
    flat = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    # peer 0 isolated: its slots point at itself with zero weights
    nbr_idx = jnp.asarray([[0, 0], [0, 2], [1, 3], [2, 2]], jnp.int32)
    nbr_w = jnp.asarray([[0, 0], [0.3, 0.3], [0.3, 0.3], [0.3, 0]], jnp.float32)
    beta = jnp.asarray([[0, 0], [0.5, 0.5], [0.5, 0.5], [1.0, 0]], jnp.float32)
    self_w = jnp.asarray([1.0, 0.4, 0.4, 0.7], jnp.float32)
    _, d = cm_seg.segment_mix_stacked({"w": flat}, self_w, nbr_idx, nbr_w, beta, 5)
    np.testing.assert_array_equal(np.asarray(d["w"][0]), 0.0)
    assert np.abs(np.asarray(d["w"][1:])).max() > 0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,d,bq,bk", [(128, 32, 32, 32), (256, 64, 64, 128), (64, 128, 64, 16)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, d, bq, bk, causal, window, dtype, rng):
    q = jnp.asarray(rng.normal(size=(1, 2, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, s, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, block_q=bq, block_k=bk)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_flash_attention_matches_model_gqa(rng):
    """ops.gqa_flash_attention == the model's _attend for GQA shapes."""
    from repro.kernels.flash_attention.ops import gqa_flash_attention

    b, s, h, kh, d = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)), jnp.float32)
    got = gqa_flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = gqa_flash_attention(q, k, v, causal=True, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


# ---------------------------------------------------------------------------
# rwkv6 / mamba2
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,h,dk,chunk", [(64, 2, 32, 16), (32, 4, 16, 8), (48, 1, 64, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(t, h, dk, chunk, dtype, rng):
    b = 2
    r = jnp.asarray(rng.normal(size=(b, t, h, dk)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, h, dk)), dtype)
    ld = -jnp.asarray(rng.uniform(0.01, 4.0, size=(b, t, h, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, dk)), jnp.float32) * 0.5
    got = wkv6(r, k, v, ld, u, chunk=chunk)
    want, _ = wkv6_ref(r, k, v, ld, u)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **(dict(atol=1e-3, rtol=1e-3) if dtype == jnp.float32 else dict(atol=0.15, rtol=0.1)),
    )


def test_wkv6_extreme_decay_no_overflow(rng):
    """Strong decays must not overflow the chunked form (safe formulation)."""
    b, t, h, dk = 1, 32, 1, 16
    r = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dk)), jnp.float32)
    ld = jnp.full((b, t, h, dk), -50.0, jnp.float32)  # near-instant forgetting
    u = jnp.zeros((h, dk), jnp.float32)
    got = wkv6(r, k, v, ld, u, chunk=8)
    want, _ = wkv6_ref(r, k, v, ld, u)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize(
    "t,h,p,n,chunk", [(64, 2, 32, 16, 16), (32, 3, 16, 8, 8), (48, 1, 64, 32, 48)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(t, h, p, n, chunk, dtype, rng):
    b = 2
    x = jnp.asarray(rng.normal(size=(b, t, h, p)), dtype)
    bm = jnp.asarray(rng.normal(size=(b, t, h, n)), dtype)
    cm = jnp.asarray(rng.normal(size=(b, t, h, n)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 1.0, size=(b, t, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    got = ssd(x, bm, cm, dt, a, chunk=chunk)
    want, _ = ssd_ref(x, bm, cm, dt, a)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **(TOL[dtype] if dtype == jnp.float32 else dict(atol=0.15, rtol=0.1)),
    )
