"""Hierarchical runtime (vmap-within-device x shard_map): sparse vs dense.

Tier-1 half: a 1-slice mesh holds ALL K = 8 peers on one device
(peers_per_device = K), so the sparse degree-bounded consensus path runs in
the ordinary single-device environment.  Bridge mode must be fp32
BIT-identical (np.array_equal) to the vmap runtime on every state leaf,
every round, for both protocols across the schedule grid — the acceptance
contract of the sparse path.  Segment mode (the large-K form) is allclose:
its degree-bounded sums reduce in slot order by design.

Mesh half (``-m mesh``, 8 forced host devices): the same parity across a
REAL multi-slice layout, plus the K = 4096 / peers_per_device = 512 smoke
asserting the compiled program never materializes a (K, K) array.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import p2p
from repro.launch import mesh as mesh_lib
from repro.sharding import specs as specs_lib

K = 8

needs_mesh = pytest.mark.skipif(
    jax.device_count() < K,
    reason=f"needs >= {K} devices "
           f"(XLA_FLAGS=--xla_force_host_platform_device_count={K})",
)


def _init_fn(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (6, 16)),
        "b1": jnp.zeros((16,)),
        "w2": jax.random.normal(k2, (16, 4)),
    }


def _mlp_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return jnp.mean(jnp.sum(jnp.square(h @ p["w2"] - y), axis=-1))


def _round_batches(rng, t, k=K):
    x = jnp.asarray(rng.normal(size=(t, k, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(t, k, 10, 4)), jnp.float32)
    return (x, y)


def _one_device_mesh():
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("pod",))


def _cfg(protocol, schedule, extra):
    return p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=3,
        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
        topology="ring", protocol=protocol, schedule=schedule,
        schedule_rounds=5, **extra,
    )


SCHEDULE_GRID = [
    ("static", {}),
    ("link_dropout", {}),
    ("round_robin", {"round_robin_topologies": ("ring", "star")}),
]


def _run_parity(protocol, schedule, extra, mesh, peers_per_device, mix_mode):
    """Returns the worst leaf mismatch info across 6 rounds (crossing R=5)."""
    cfg = _cfg(protocol, schedule, extra)
    sizes = np.arange(1, K + 1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        vmap_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
        hier_fn = p2p.make_sharded_round_fn(
            _mlp_loss, cfg, mesh, data_sizes=sizes,
            peers_per_device=peers_per_device, mix_mode=mix_mode,
        )
    s_vmap = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    s_hier = specs_lib.shard_peer_tree(s_vmap, mesh)

    rng = np.random.default_rng(0)
    mismatches = []
    for r in range(6):
        batches = _round_batches(rng, cfg.local_steps)
        al_v, s_vmap, loss_v = vmap_fn(s_vmap, batches)
        al_h, s_hier, loss_h = hier_fn(s_hier, batches)
        want = jax.tree_util.tree_leaves_with_path((al_v, s_vmap, loss_v))
        got = jax.tree_util.tree_leaves_with_path((al_h, s_hier, loss_h))
        assert len(want) == len(got)
        for (path, w), (_, g) in zip(want, got):
            w, g = np.asarray(w), np.asarray(g)
            if not np.array_equal(w, g):
                err = np.abs(w.astype(np.float64) - g.astype(np.float64)).max()
                mismatches.append((r, jax.tree_util.keystr(path), err))
    return mismatches


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("schedule,extra", SCHEDULE_GRID,
                         ids=[s for s, _ in SCHEDULE_GRID])
def test_bridge_bit_identical_to_vmap(protocol, schedule, extra):
    """Sparse bridge path == dense vmap runtime, bit for bit, K = 8."""
    mismatches = _run_parity(
        protocol, schedule, extra, _one_device_mesh(),
        peers_per_device=K, mix_mode="bridge",
    )
    assert not mismatches, (
        f"{protocol}/{schedule} bridge diverged from the dense runtime: "
        + "; ".join(f"round {r} {p} max|diff|={e:.3e}" for r, p, e in mismatches[:5])
    )


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_auto_mode_is_bridge_at_small_k(protocol):
    """mix_mode='auto' at K = 8 must select the bit-parity bridge."""
    mismatches = _run_parity(
        protocol, "link_dropout", {}, _one_device_mesh(),
        peers_per_device=K, mix_mode="auto",
    )
    assert not mismatches


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_segment_allclose_to_vmap(protocol):
    """The large-K segment path: allclose (slot-ordered sums), NOT bitwise."""
    cfg = _cfg(protocol, "link_dropout", {})
    sizes = np.arange(1, K + 1)
    mesh = _one_device_mesh()
    vmap_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    hier_fn = p2p.make_sharded_round_fn(
        _mlp_loss, cfg, mesh, data_sizes=sizes,
        peers_per_device=K, mix_mode="segment",
    )
    s_vmap = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    s_hier = specs_lib.shard_peer_tree(s_vmap, mesh)
    rng = np.random.default_rng(0)
    for _ in range(4):
        batches = _round_batches(rng, cfg.local_steps)
        _, s_vmap, _ = vmap_fn(s_vmap, batches)
        _, s_hier, _ = hier_fn(s_hier, batches)
    for w, g in zip(jax.tree.leaves(s_vmap), jax.tree.leaves(s_hier)):
        np.testing.assert_allclose(
            np.asarray(w, np.float64), np.asarray(g, np.float64),
            rtol=1e-5, atol=1e-5,
        )


# ---------------------------------------------------------------------------
# Fail-fast paths (run everywhere, no mesh needed)
# ---------------------------------------------------------------------------


def test_peers_per_device_needs_mesh():
    cfg = _cfg("gossip", "static", {})
    with pytest.raises(ValueError, match="needs a mesh"):
        p2p._make_round_step(_mlp_loss, cfg, peers_per_device=4)


def test_adaptive_schedule_rejected():
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=2,
        consensus_steps=1, lr=0.1, topology="ring", schedule="adaptive",
    )
    with pytest.raises(ValueError, match="adaptive"):
        p2p.make_sharded_round_fn(
            _mlp_loss, cfg, _one_device_mesh(), peers_per_device=K,
        )


def test_layout_validation():
    mesh = _one_device_mesh()
    with pytest.raises(ValueError, match="peers_per_device"):
        specs_lib.hierarchical_layout(K, mesh, peers_per_device=1)
    with pytest.raises(ValueError, match="num_peers"):
        specs_lib.hierarchical_layout(K, mesh, peers_per_device=3)
    with pytest.raises(ValueError, match="no axis"):
        specs_lib.hierarchical_layout(K, mesh, peer_axis="model",
                                      peers_per_device=K)
    assert specs_lib.hierarchical_layout(K, mesh, peers_per_device=K) == (1, K)


def test_bad_mix_mode_rejected():
    cfg = _cfg("gossip", "static", {})
    with pytest.raises(ValueError, match="mix_mode"):
        p2p.make_sharded_round_fn(
            _mlp_loss, cfg, _one_device_mesh(), peers_per_device=K,
            mix_mode="dense",
        )


# ---------------------------------------------------------------------------
# Multi-slice mesh half
# ---------------------------------------------------------------------------


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("devices,ppd", [(2, 4), (4, 2)])
def test_bridge_bit_identical_multi_device(protocol, devices, ppd):
    """Bridge parity holds when the blocks genuinely live on different
    devices and the gathered view crosses the mesh."""
    mesh = mesh_lib.make_peer_mesh(devices)
    mismatches = _run_parity(
        protocol, "link_dropout", {}, mesh,
        peers_per_device=ppd, mix_mode="bridge",
    )
    assert not mismatches, (
        f"{protocol} bridge ({devices} dev x {ppd} peers) diverged: "
        + "; ".join(f"round {r} {p} max|diff|={e:.3e}" for r, p, e in mismatches[:5])
    )


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_segment_allclose_multi_device(protocol):
    cfg = _cfg(protocol, "static", {})
    sizes = np.arange(1, K + 1)
    mesh = mesh_lib.make_peer_mesh(4)
    vmap_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    hier_fn = p2p.make_sharded_round_fn(
        _mlp_loss, cfg, mesh, data_sizes=sizes,
        peers_per_device=2, mix_mode="segment",
    )
    s_vmap = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    s_hier = specs_lib.shard_peer_tree(s_vmap, mesh)
    rng = np.random.default_rng(0)
    for _ in range(3):
        batches = _round_batches(rng, cfg.local_steps)
        _, s_vmap, _ = vmap_fn(s_vmap, batches)
        _, s_hier, _ = hier_fn(s_hier, batches)
    for w, g in zip(jax.tree.leaves(s_vmap), jax.tree.leaves(s_hier)):
        np.testing.assert_allclose(
            np.asarray(w, np.float64), np.asarray(g, np.float64),
            rtol=1e-5, atol=1e-5,
        )


def _no_kk_avals(jaxpr, k, path="jaxpr"):
    """Recursively assert no aval in the jaxpr has two dims == k."""
    bad = []

    def visit(jx, where):
        for eqn in jx.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(var, "aval", None)
                shape = getattr(aval, "shape", ())
                if sum(1 for d in shape if d == k) >= 2:
                    bad.append((where, eqn.primitive.name, shape))
            for val in eqn.params.values():
                for v in val if isinstance(val, (list, tuple)) else (val,):
                    # bare Jaxpr (e.g. shard_map's body) has .eqns itself;
                    # ClosedJaxpr wraps one under .jaxpr
                    inner = v if hasattr(v, "eqns") else getattr(v, "jaxpr", None)
                    if inner is not None and hasattr(inner, "eqns"):
                        visit(inner, f"{where}/{eqn.primitive.name}")

    visit(jaxpr, path)
    return bad


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_large_k_fleet_runs_without_dense_matrix(protocol):
    """K = 4096 on an 8-slice mesh, 512 peers per slice: one full round of
    the sparse segment runtime completes with finite outputs, and the traced
    program NEVER materializes a (4096, 4096) array — peak per-device
    consensus memory is O(K * degree_bound * feat / devices)."""
    bigk, devices = 4096, 8
    ppd = bigk // devices
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=bigk, local_steps=1,
        consensus_steps=1, lr=0.1, eta_d=0.5, topology="ring",
        protocol=protocol, schedule="static",
    )
    mesh = mesh_lib.make_peer_mesh(devices)

    def tiny_loss(p, batch):
        x, y = batch
        return jnp.mean(jnp.square(x @ p["w"] - y))

    def tiny_init(key):
        return {"w": jax.random.normal(key, (3, 2)) * 0.1}

    step = p2p._make_round_step(
        tiny_loss, cfg, None, mesh=mesh, peers_per_device=ppd,
        mix_mode="segment",
    )
    state = specs_lib.shard_peer_tree(
        p2p.init_state(jax.random.PRNGKey(0), tiny_init, cfg), mesh
    )
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, bigk, 2, 3)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(1, bigk, 2, 2)), jnp.float32)

    jaxpr = jax.make_jaxpr(step)(state, (x, y))
    bad = _no_kk_avals(jaxpr.jaxpr, bigk)
    assert not bad, f"dense (K, K) intermediates found: {bad[:5]}"

    after_local, after_cons, losses = jax.jit(step)(state, (x, y))
    assert np.isfinite(np.asarray(losses)).all()
    assert int(after_cons.round_idx) == 1
    for leaf in jax.tree.leaves(after_cons.params):
        assert np.isfinite(np.asarray(leaf)).all()
