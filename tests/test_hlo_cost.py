"""HLO cost model: closed-form validation (the roofline's data source)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_single_matmul_flops():
    c = _compile(
        lambda x, w: x @ w,
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 64), jnp.float32),
    )
    hc = hlo_cost.analyze(c.as_text())
    assert hc.flops == 2 * 128 * 256 * 64


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(carry, _):
            return carry @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    hc = hlo_cost.analyze(c.as_text())
    assert hc.flops == 10 * 2 * 128**3
    assert any(v == 10.0 for v in hc.loop_info.values())


def test_nested_scan_multipliers_compose():
    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    hc = hlo_cost.analyze(c.as_text())
    assert hc.flops == 15 * 2 * 64**3


def test_grad_of_scan_counts_fwd_and_bwd():
    def loss(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()

    c = _compile(jax.grad(loss), jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((8, 64), jnp.float32))
    hc = hlo_cost.analyze(c.as_text())
    # fwd (1 dot) + bwd (2 dots) per step
    assert hc.flops == pytest.approx(3 * 10 * 2 * 8 * 64 * 64, rel=0.01)


def test_bytes_reasonable_for_copy():
    c = _compile(lambda x: x * 2.0, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    hc = hlo_cost.analyze(c.as_text())
    nbytes = 1024 * 1024 * 4
    # read + write, within fusion-accounting slack
    assert nbytes <= hc.bytes_accessed <= 6 * nbytes


def test_tuple_collective_parse():
    hlo = """
HloModule m

ENTRY %main.1 (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %ar = (f32[64,64]{1,0}, f32[32,16]{1,0}) all-reduce(%a, %a), replica_groups={}, to_apply=%add
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%ar), index=0
}
"""
    hc = hlo_cost.analyze(hlo)
    want = (64 * 64 * 4 + 32 * 16 * 4) * 2.0  # wire factor 2 for all-reduce
    assert hc.coll_wire_bytes == want
