"""The declarative feature-compatibility table (core/features.py).

Three contracts:

* **Table integrity** — every incompatibility references registered features,
  carries a reason and a workaround, and the one formatter produces the
  documented ``A is not supported with B: reason; workaround`` shape.
* **Single source of truth** — the composition rejections that used to be
  scattered across ``P2PConfig.__post_init__``, ``make_sharded_round_fn``,
  the launcher, and argparse all fire FROM the table now: grepping the source
  tree finds the formatter's phrase in exactly one module.
* **Behavior** — configs that activate an incompatible pair are rejected with
  the table's message at every entry point (config construction for
  config-level pairs, the runtime/launcher for hierarchical pairs).
"""
import pathlib

import pytest

from repro.core import features, p2p

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


# ---------------------------------------------------------------------------
# table integrity
# ---------------------------------------------------------------------------


def test_every_incompatibility_references_registered_features():
    for inc in features.INCOMPATIBILITIES:
        assert inc.a in features.FEATURES, inc.a
        assert inc.b in features.FEATURES, inc.b
        assert inc.reason and inc.workaround


def test_feature_names_match_registry_keys():
    for name, feat in features.FEATURES.items():
        assert feat.name == name


def test_incompatibilities_are_unique_pairs():
    pairs = [frozenset((i.a, i.b)) for i in features.INCOMPATIBILITIES]
    assert len(pairs) == len(set(pairs))
    assert all(len(p) == 2 for p in pairs)  # no self-pairs


def test_formatter_shape():
    ctx = features.FeatureContext(schedule="adaptive", staleness_bound=2)
    (inc,) = features.violations(ctx)
    msg = features.format_violation(inc, ctx)
    a = features.FEATURES[inc.a].describe(ctx)
    b = features.FEATURES[inc.b].describe(ctx)
    assert msg == f"{a} is not supported with {b}: {inc.reason}; {inc.workaround}"


def test_active_features_reflect_context():
    ctx = features.FeatureContext()
    assert features.active_features(ctx) == ()
    ctx = features.FeatureContext(
        schedule="adaptive", compressor="topk", model="rwkv6_seqmnist",
        peers_per_device=2,
    )
    assert set(features.active_features(ctx)) == {
        "adaptive", "compression", "real_model", "hierarchical"
    }


def test_support_matrix_has_one_row_per_incompatibility():
    md = features.support_matrix_markdown()
    rows = [ln for ln in md.splitlines() if ln.startswith("|")]
    assert len(rows) == 2 + len(features.INCOMPATIBILITIES)  # header + rule


# ---------------------------------------------------------------------------
# single source of truth (the grep gate)
# ---------------------------------------------------------------------------


def test_formatter_phrase_appears_only_in_features_module():
    offenders = [
        p.relative_to(SRC)
        for p in SRC.rglob("*.py")
        if "is not supported with" in p.read_text() and p.name != "features.py"
    ]
    assert not offenders, (
        f"composition rejections outside core/features.py: {offenders}"
    )


# ---------------------------------------------------------------------------
# behavior at the entry points
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs,first,second", [
    (dict(schedule="adaptive", staleness_bound=2), "staleness", "adaptive"),
    (dict(compressor="topk", staleness_bound=2), "staleness", "compressor"),
])
def test_config_level_pairs_reject_at_construction(kwargs, first, second):
    with pytest.raises(ValueError, match=second):
        p2p.P2PConfig(num_peers=8, **kwargs)


@pytest.mark.parametrize("kwargs,match", [
    (dict(schedule="adaptive"), "adaptive.*peers_per_device"),
    (dict(compressor="qint8"), "compressor.*peers_per_device"),
    (dict(steps_profile="straggler"), "steps-profile"),
    (dict(model="rwkv6_seqmnist"), "rwkv6_seqmnist.*hierarchical"),
])
def test_hierarchical_pairs_reject_with_peers_per_device(kwargs, match):
    cfg = p2p.P2PConfig(num_peers=8, **kwargs)
    with pytest.raises(ValueError, match=match):
        features.check_config(cfg, peers_per_device=2)
    # ... and compose fine with one peer per device
    features.check_config(cfg, peers_per_device=1)


def test_real_model_rejected_by_hier_round_step_builder():
    cfg = p2p.P2PConfig(num_peers=8, model="rwkv6_seqmnist")
    with pytest.raises(ValueError, match="rwkv6_seqmnist.*hierarchical"):
        p2p._make_hier_round_step(
            lambda p, b: 0.0, cfg, mesh=object(), axis_name="pod",
            peers_per_device=2,
        )


def test_launcher_rejects_real_model_with_peers_per_device():
    from repro.configs.p2pl_mnist import seqmnist_k8
    from repro.launch import train

    with pytest.raises(ValueError, match="rwkv6_seqmnist.*hierarchical"):
        train.run_paper_experiment(
            seqmnist_k8(), rounds=1, peer_axis="pod", peers_per_device=2
        )


def test_cli_rejects_real_model_with_peers_per_device(capsys):
    from repro.launch import train

    with pytest.raises(SystemExit) as ex:
        train.main([
            "--experiment", "seqmnist_k8", "--peer-axis", "pod",
            "--peers-per-device", "2",
        ])
    assert ex.value.code != 0
    assert "rwkv6_seqmnist" in capsys.readouterr().err


def test_unknown_model_rejected_with_known_names():
    with pytest.raises(ValueError, match="unknown model.*mnist_mlp"):
        p2p.P2PConfig(model="resnet50")
