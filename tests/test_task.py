"""The TrainTask registry (core/task.py) and its two tasks.

The load-bearing contract is the **bit-parity booby trap**: selecting
``model="mnist_mlp"`` must not merely be equivalent to the pre-TrainTask
trainer — it must BE it, structurally.  The task's callables are asserted to
be the legacy functions themselves (identity, not equality), and a full
task-routed ``run_paper_experiment`` run is compared leaf-for-leaf, bit-for-
bit against a hand-built legacy driver loop under both gossip and push_sum.

``rwkv6_seqmnist`` is covered end-to-end at CI scale: tokenization is a
fixed, deterministic dataset transform; a K=2 fleet trains under gossip and
push_sum in the vmap runtime (the pod runtime rides the mesh marker) and the
training loss must actually decrease.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.p2pl_mnist import PaperExperiment, noniid_k2, seqmnist_k8
from repro.core import p2p
from repro.core import task as task_lib
from repro.data import partition, pipeline, synthetic
from repro.launch.train import run_paper_experiment
from repro.models import mlp

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_task_names_sorted_and_complete():
    names = task_lib.task_names()
    assert names == tuple(sorted(names))
    assert "mnist_mlp" in names and "rwkv6_seqmnist" in names


def test_get_task_unknown_lists_known_names():
    with pytest.raises(ValueError, match="unknown model.*mnist_mlp"):
        task_lib.get_task("vit_b16")


def test_register_rejects_duplicate():
    with pytest.raises(ValueError, match="already registered"):
        task_lib.register_task("mnist_mlp", lambda: None)


def test_get_task_is_cached():
    assert task_lib.get_task("mnist_mlp") is task_lib.get_task("mnist_mlp")


# ---------------------------------------------------------------------------
# the booby trap, part 1: structural identity of the legacy task
# ---------------------------------------------------------------------------


def test_mnist_mlp_callables_are_the_legacy_functions():
    t = task_lib.get_task("mnist_mlp")
    assert t.loss_fn is mlp.loss_2nn
    assert t.init_params is mlp.init_2nn
    assert t.apply_fn is mlp.apply_2nn
    assert t.make_peer_batches is pipeline.PeerBatcher
    assert t.eval_batch_size is None and t.eval_set_size is None


def test_resolvers_pass_bare_callables_through_untouched():
    f = lambda p, b: 0.0  # noqa: E731
    assert p2p.resolve_loss_fn(f) is f
    assert p2p.resolve_init_fn(f) is f
    t = task_lib.get_task("mnist_mlp")
    assert p2p.resolve_loss_fn(t) is mlp.loss_2nn
    assert p2p.resolve_init_fn(t) is mlp.init_2nn


# ---------------------------------------------------------------------------
# the booby trap, part 2: bit parity against a hand-built legacy driver
# ---------------------------------------------------------------------------

ROUNDS = 4


def _legacy_final_state(exp, data, rounds, *, seed=0):
    """The pre-TrainTask trainer, reconstructed from primitives: bare
    ``mlp.*`` callables and ``pipeline.PeerBatcher``, scan driver, one-round
    chunks (``eval_every=1``'s layout)."""
    x_tr, y_tr, _, _ = data
    parts = partition.pathological_partition(
        x_tr, y_tr, list(exp.peer_classes),
        samples_per_class=exp.samples_per_class,
    )
    sizes = partition.data_sizes(parts)
    cfg = exp.p2p
    batcher = pipeline.PeerBatcher(parts, exp.batch_size, seed=seed)
    state = p2p.init_state(
        jax.random.PRNGKey(seed), mlp.init_2nn, cfg, data_sizes=sizes
    )
    drive = p2p.make_scan_driver(mlp.loss_2nn, cfg, data_sizes=sizes)
    for _ in range(rounds):
        bx, by = batcher.round_batches(cfg.local_steps)
        bx = bx.reshape((1, cfg.local_steps) + bx.shape[1:])
        by = by.reshape((1, cfg.local_steps) + by.shape[1:])
        _, state, _ = drive(state, (jnp.asarray(bx), jnp.asarray(by)))
    return state


def _assert_params_bit_identical(want, got):
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path(want),
        jax.tree_util.tree_leaves_with_path(got),
    ):
        assert pa == pb
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"leaf {pa} differs: task-routed trainer is not bit-identical "
            "to the legacy path"
        )


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_mnist_mlp_task_path_bit_identical_to_legacy(protocol, mnist_small):
    exp = noniid_k2(algorithm="p2pl_affinity", local_steps=4)
    exp = dataclasses.replace(
        exp, p2p=dataclasses.replace(exp.p2p, protocol=protocol)
    )
    _, state = run_paper_experiment(
        exp, rounds=ROUNDS, data=mnist_small, return_state=True
    )
    legacy = _legacy_final_state(exp, mnist_small, ROUNDS)
    _assert_params_bit_identical(legacy.params, state.params)


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_mnist_mlp_task_path_bit_identical_pod(protocol, mnist_small):
    """Pod runtime, task-routed, vs the hand-built vmap legacy trainer: the
    task layer must preserve the runtimes' cross-parity bits too."""
    from repro.configs.p2pl_mnist import sharded_k8

    exp = sharded_k8(protocol=protocol, local_steps=2)
    _, state = run_paper_experiment(
        exp, rounds=2, data=mnist_small, peer_axis="pod", return_state=True
    )
    legacy = _legacy_final_state(exp, mnist_small, 2)
    _assert_params_bit_identical(
        legacy.params, jax.device_get(state.params)
    )


# ---------------------------------------------------------------------------
# sequential-MNIST tokenization
# ---------------------------------------------------------------------------


def test_images_to_tokens_shape_range_determinism():
    x = synthetic.mnist_like(256, 10)[0][:64]
    tok = pipeline.images_to_tokens(x)
    assert tok.shape == (64, 196) and tok.dtype == np.int32
    assert tok.min() >= 0 and tok.max() < 16
    # a dataset CONSTANT, not a per-batch statistic: same pixels, same tokens,
    # regardless of what else is in the batch
    np.testing.assert_array_equal(tok[:8], pipeline.images_to_tokens(x[:8]))


def test_images_to_tokens_rejects_bad_pool():
    with pytest.raises(ValueError, match="pool"):
        pipeline.images_to_tokens(np.zeros((2, 784), np.float32), pool=3)


def test_token_sequence_batcher_contract():
    x, y, _, _ = synthetic.mnist_like(512, 10)
    parts = partition.pathological_partition(
        x, y, [(0, 1), (2, 3)], samples_per_class=20
    )
    b = pipeline.TokenSequenceBatcher(parts, batch_size=4, seed=7)
    assert b.num_peers == 2
    bx, by = b.round_batches(3)
    assert bx.shape == (3, 2, 4, 196) and bx.dtype == np.int32
    assert by.shape == (3, 2, 4) and by.dtype == np.int32
    # same cursor/reshuffle behavior as PeerBatcher: the label stream of an
    # identically-seeded image batcher matches step for step
    ref = pipeline.PeerBatcher(parts, batch_size=4, seed=7)
    _, ry = ref.round_batches(3)
    np.testing.assert_array_equal(by, ry)


# ---------------------------------------------------------------------------
# rwkv6_seqmnist end-to-end (CI scale)
# ---------------------------------------------------------------------------


def _rwkv6_smoke_exp(protocol: str) -> PaperExperiment:
    return PaperExperiment(
        name=f"rwkv6_smoke_{protocol}",
        p2p=p2p.P2PConfig(
            algorithm="p2pl",
            num_peers=2,
            local_steps=2,
            consensus_steps=1,
            lr=0.05,
            topology="complete",
            mixing="data_weighted",
            protocol=protocol,
            model="rwkv6_seqmnist",
        ),
        batch_size=8,
        samples_per_class=20,
        peer_classes=((0, 1), (2, 3)),
    )


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_rwkv6_seqmnist_trains_vmap(protocol):
    data = synthetic.mnist_like(2000, 300)
    log = run_paper_experiment(_rwkv6_smoke_exp(protocol), rounds=3, data=data)
    losses = np.asarray(log.train_loss, np.float64)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], (
        f"rwkv6 loss did not decrease under {protocol}: {losses}"
    )
    acc = log.after_consensus["all"][-1]
    assert np.isfinite(acc).all()


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_rwkv6_seqmnist_trains_pod(protocol):
    data = synthetic.mnist_like(2000, 300)
    exp = seqmnist_k8(protocol=protocol, local_steps=2, rounds=2)
    log = run_paper_experiment(
        exp, rounds=2, data=data, peer_axis="pod", eval_every=2
    )
    losses = np.asarray(log.train_loss, np.float64)
    assert np.isfinite(losses).all()


# ---------------------------------------------------------------------------
# experiment/config model plumbing
# ---------------------------------------------------------------------------


def test_seqmnist_k8_builder_sets_model_both_places():
    exp = seqmnist_k8()
    assert exp.model == "rwkv6_seqmnist"
    assert exp.p2p.model == "rwkv6_seqmnist"
    assert exp.p2p.num_peers == 8


def test_experiment_model_propagates_to_p2p_config():
    exp = PaperExperiment(
        name="x", p2p=p2p.P2PConfig(num_peers=2), model="rwkv6_seqmnist"
    )
    assert exp.p2p.model == "rwkv6_seqmnist"
    # ... and the other direction
    exp = PaperExperiment(
        name="x", p2p=p2p.P2PConfig(num_peers=2, model="rwkv6_seqmnist")
    )
    assert exp.model == "rwkv6_seqmnist"


def test_experiment_model_conflict_rejected():
    # two DIFFERENT non-default models on the two sides must never silently
    # pick one; needs a second registered non-default task to synthesize
    task_lib.register_task(
        "tmp_conflict_task", lambda: task_lib.get_task("mnist_mlp")
    )
    try:
        with pytest.raises(ValueError, match="conflicts"):
            PaperExperiment(
                name="x",
                p2p=p2p.P2PConfig(num_peers=2, model="tmp_conflict_task"),
                model="rwkv6_seqmnist",
            )
    finally:
        task_lib._BUILDERS.pop("tmp_conflict_task", None)
        task_lib._CACHE.pop("tmp_conflict_task", None)
