"""The consensus-protocol API: registry, gossip bit-identity with the PR 1
runtime, and the push-sum invariants (mass conservation, de-biased
convergence to the data-weighted average on directed schedules)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as cl
from repro.core import graph as gl
from repro.core import p2p, protocols


def _quad_loss(params, batch):
    return jnp.sum(jnp.square(params["w"] - batch))


def _init_fn(key):
    return {"w": jax.random.normal(key, (4,))}


def _batches(targets, t, k):
    return jnp.broadcast_to(jnp.asarray(targets, jnp.float32), (t, k, 4))


# ---------------------------------------------------------------------------
# Registry + config plumbing
# ---------------------------------------------------------------------------


def test_registry_contents_and_lookup():
    names = protocols.protocol_names()
    assert "gossip" in names and "push_sum" in names
    assert protocols.get_protocol("gossip").name == "gossip"
    assert isinstance(protocols.get_protocol("push_sum"), protocols.PushSumProtocol)
    with pytest.raises(ValueError):
        protocols.get_protocol("nope")


def test_register_rejects_duplicates_and_unnamed():
    with pytest.raises(ValueError):
        protocols.register_protocol(protocols.GossipProtocol())  # name taken
    with pytest.raises(ValueError):
        protocols.register_protocol(protocols.ConsensusProtocol())  # name "base"


def test_config_validates_protocol_and_round_robin_topologies():
    with pytest.raises(ValueError):
        p2p.P2PConfig(protocol="nope")
    with pytest.raises(ValueError):  # typo'd name fails fast, not in build_schedule
        p2p.P2PConfig(schedule="round_robin", round_robin_topologies=("ring", "sta"))
    with pytest.raises(ValueError):
        p2p.P2PConfig(round_robin_topologies=(3, "ring"))
    # list input is coerced to tuple; valid names pass
    cfg = p2p.P2PConfig(schedule="round_robin", round_robin_topologies=["ring", "star"])
    assert cfg.round_robin_topologies == ("ring", "star")
    assert cfg.protocol == "gossip"


def test_protocol_state_in_p2pstate():
    cfg_g = p2p.P2PConfig(num_peers=3, local_steps=2)
    sg = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg_g)
    assert sg.protocol == ()
    cfg_p = p2p.P2PConfig(num_peers=3, local_steps=2, protocol="push_sum")
    sp = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg_p)
    assert isinstance(sp.protocol, protocols.PushSumState)
    np.testing.assert_allclose(np.asarray(sp.protocol.mass), 1.0)
    # data-size-weighted mass init, normalized to sum K
    sp2 = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg_p,
                         data_sizes=np.array([1, 2, 3]))
    np.testing.assert_allclose(np.asarray(sp2.protocol.mass),
                               3 * np.array([1, 2, 3]) / 6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Gossip protocol == the PR 1 runtime, bit for bit
# ---------------------------------------------------------------------------


def _pr1_round_fn(loss_fn, cfg, data_sizes=None):
    """The pre-protocol (PR 1) round function, reconstructed verbatim: dense
    row-stochastic W/Beta stacks hardwired into the consensus loop."""
    w_np, beta_np, _ = p2p.mixing_constants(cfg, data_sizes)
    w_sched = jnp.asarray(w_np, jnp.float32)
    beta_sched = jnp.asarray(beta_np, jnp.float32)
    period = w_sched.shape[0]

    def consensus_phase(state, w_mat, beta_mat):
        if cfg.consensus_steps == 0:
            return state._replace(round_idx=state.round_idx + 1)
        params, d_bias = state.params, state.d_bias
        has_nbrs = jnp.sum(beta_mat, axis=1) > 0
        for _ in range(cfg.consensus_steps):
            if cfg.use_affinity_d:
                nbr_avg = cl.mix_stacked(beta_mat, params)
                d_bias = jax.tree.map(
                    lambda avg, w: jnp.where(
                        has_nbrs.reshape((-1,) + (1,) * (w.ndim - 1)),
                        (avg - w) / cfg.local_steps,
                        jnp.zeros_like(w),
                    ),
                    nbr_avg,
                    params,
                )
            mixed = cl.mix_stacked(w_mat, params)
            if cfg.use_affinity_b:
                mixed = jax.tree.map(
                    lambda m, b: m + cfg.eta_b * b, mixed, state.b_bias
                )
            params = mixed
        return state._replace(params=params, d_bias=d_bias,
                              round_idx=state.round_idx + 1)

    @jax.jit
    def round_fn(state, batches):
        idx = jax.lax.rem(state.round_idx, jnp.int32(period))
        after_local, losses = p2p.local_phase(state, loss_fn, batches, cfg)
        after_cons = consensus_phase(after_local, w_sched[idx], beta_sched[idx])
        return after_local, after_cons, losses

    return round_fn


@pytest.mark.parametrize("schedule,extra", [
    ("static", {}),
    ("link_dropout", {}),
    ("random_matching", {}),
    ("peer_churn", {}),
    ("round_robin", {"round_robin_topologies": ("ring", "star")}),
])
def test_gossip_bit_identical_to_pr1_path(schedule, extra):
    """The default protocol through make_round_fn reproduces the PR 1 results
    bit for bit on every existing schedule, every state leaf, every round."""
    cfg = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=4, local_steps=3,
                        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5,
                        eta_b=0.1, topology="ring", schedule=schedule,
                        schedule_rounds=5, **extra)
    sizes = np.array([3, 1, 4, 2])
    new_fn = p2p.make_round_fn(_quad_loss, cfg, data_sizes=sizes)
    old_fn = _pr1_round_fn(_quad_loss, cfg, data_sizes=sizes)
    s_new = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    s_old = s_new._replace(protocol=())  # PR 1 state had no protocol leaf
    targets = np.random.default_rng(0).normal(size=(4, 4))
    batches = _batches(targets, 3, 4)
    for _ in range(7):
        al_n, s_new, loss_n = new_fn(s_new, batches)
        al_o, s_old, loss_o = old_fn(s_old, batches)
        new_leaves = jax.tree.leaves(
            (al_n._replace(protocol=()), s_new._replace(protocol=()), loss_n)
        )
        old_leaves = jax.tree.leaves((al_o, s_old, loss_o))
        for leaf_n, leaf_o in zip(new_leaves, old_leaves):
            assert np.array_equal(np.asarray(leaf_n), np.asarray(leaf_o))


# ---------------------------------------------------------------------------
# Push-sum invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule,extra", [
    ("one_way_matching", {}),
    ("link_dropout", {"topology": "directed_ring"}),
    ("peer_churn", {"topology": "ring"}),
])
def test_push_sum_mass_conservation(schedule, extra):
    """sum_k y_k == K after every round of any (directed, churning) schedule,
    and every peer's mass stays strictly positive."""
    k = 6
    cfg = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=k, local_steps=2,
                        consensus_steps=1, lr=0.05, eta_d=0.5,
                        protocol="push_sum", schedule=schedule,
                        schedule_rounds=7, **extra)
    state = p2p.init_state(jax.random.PRNGKey(1), _init_fn, cfg)
    fn = p2p.make_round_fn(_quad_loss, cfg)
    targets = np.random.default_rng(1).normal(size=(k, 4))
    for _ in range(12):
        _, state, _ = fn(state, _batches(targets, 2, k))
        mass = np.asarray(state.protocol.mass)
        np.testing.assert_allclose(mass.sum(), k, rtol=1e-5)
        assert (mass > 0).all()


def test_push_sum_pure_mix_reaches_data_weighted_average():
    """Repeated push-sum steps on a directed ring drive every de-biased
    estimate to sum_j n_j x_j / sum_j n_j (which row-stochastic gossip on the
    same directed graph provably misses)."""
    k = 8
    g = gl.build_graph("directed_ring", k)
    sched = gl.static_schedule(g)
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 50, k)
    x0 = rng.normal(size=(k, 5)).astype(np.float32)
    target = (sizes[:, None] * x0).sum(0) / sizes.sum()
    params = {"w": jnp.asarray(x0)}

    def run(protocol):
        proto = protocols.get_protocol(protocol)
        consts_np = proto.constants(sched, "data_weighted", data_sizes=sizes)
        consts = protocols.round_constants(
            protocols.ProtocolConstants(
                jnp.asarray(consts_np.w, jnp.float32),
                jnp.asarray(consts_np.beta, jnp.float32),
            ),
            0,
        )
        st, x = proto.init_state(params, sizes), params
        for _ in range(400):
            st, x = proto.mix(st, x, consts)
        return np.abs(np.asarray(x["w"]) - target[None, :]).max()

    assert run("push_sum") < 1e-3
    assert run("gossip") > 1e-2  # directed ring biases plain gossip


def test_push_sum_training_on_directed_ring_converges():
    """Regression for the acceptance criterion: push_sum on a directed-ring
    GraphSchedule drives the consensus error of the de-biased estimates
    toward the data-weighted average, with exactly ONE jit compile."""
    k = 8
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _quad_loss(params, batch)

    cfg = p2p.P2PConfig(algorithm="local_dsgd", num_peers=k, local_steps=1,
                        consensus_steps=1, lr=0.0,  # lr=0: pure consensus
                        topology="directed_ring", protocol="push_sum")
    sizes = np.arange(1, k + 1).astype(np.float64)
    state = p2p.init_state(jax.random.PRNGKey(2), _init_fn, cfg, data_sizes=sizes)
    target = (sizes[:, None] * np.asarray(state.params["w"])).sum(0) / sizes.sum()
    fn = p2p.make_round_fn(counting_loss, cfg, data_sizes=sizes)
    batches = _batches(np.zeros((k, 4)), 1, k)
    err0 = float(cl.consensus_error(state.params))
    for _ in range(120):
        _, state, _ = fn(state, batches)
    assert float(cl.consensus_error(state.params)) < 1e-3 * err0
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.broadcast_to(target, (k, 4)), atol=1e-3)
    assert traces[0] <= 2  # value + grad trace of the single compile


def test_push_sum_with_metropolis_on_undirected_equals_gossip():
    """On an undirected graph with doubly-stochastic (metropolis) weights the
    mass stays exactly 1 and push-sum degenerates to plain gossip."""
    k = 5
    g = gl.build_graph("ring", k)
    sched = gl.static_schedule(g)
    rng = np.random.default_rng(3)
    params = {"w": jnp.asarray(rng.normal(size=(k, 6)), jnp.float32)}
    outs = {}
    for name in ("gossip", "push_sum"):
        proto = protocols.get_protocol(name)
        consts_np = proto.constants(sched, "metropolis")
        consts = protocols.round_constants(
            protocols.ProtocolConstants(
                jnp.asarray(consts_np.w, jnp.float32),
                jnp.asarray(consts_np.beta, jnp.float32),
            ),
            0,
        )
        st, x = proto.init_state(params), params
        for _ in range(3):
            st, x = proto.mix(st, x, consts)
        outs[name] = np.asarray(x["w"])
        if name == "push_sum":
            np.testing.assert_allclose(np.asarray(st.mass), 1.0, rtol=1e-6)
    np.testing.assert_allclose(outs["push_sum"], outs["gossip"], atol=1e-6)


def test_push_sum_isolated_peer_untouched():
    """A churned-out peer keeps its parameters and all of its mass."""
    k = 4
    base = gl.build_graph("directed_ring", k)
    a = base.adjacency.copy()
    a[2, :] = a[:, 2] = False  # peer 2 fully offline this round
    g = gl.CommGraph(a, directed=True)
    proto = protocols.get_protocol("push_sum")
    consts_np = proto.constants(gl.static_schedule(g), "uniform_neighbor")
    consts = protocols.round_constants(
        protocols.ProtocolConstants(
            jnp.asarray(consts_np.w, jnp.float32),
            jnp.asarray(consts_np.beta, jnp.float32),
        ),
        0,
    )
    rng = np.random.default_rng(4)
    params = {"w": jnp.asarray(rng.normal(size=(k, 3)), jnp.float32)}
    st, x = proto.init_state(params), params
    st, x = proto.mix(st, x, consts)
    np.testing.assert_allclose(np.asarray(x["w"])[2], np.asarray(params["w"])[2],
                               rtol=1e-6)
    np.testing.assert_allclose(float(st.mass[2]), 1.0, rtol=1e-6)


def test_one_compile_per_run_all_protocols():
    """Both protocols keep the one-compile property on time-varying schedules."""
    for protocol, schedule, topo in (
        ("gossip", "link_dropout", "ring"),
        ("push_sum", "one_way_matching", "complete"),
        ("push_sum", "link_dropout", "directed_ring"),
    ):
        traces = [0]

        def counting_loss(params, batch):
            traces[0] += 1
            return _quad_loss(params, batch)

        cfg = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=4,
                            local_steps=2, consensus_steps=1, lr=0.1,
                            topology=topo, protocol=protocol,
                            schedule=schedule, schedule_rounds=5)
        state = p2p.init_state(jax.random.PRNGKey(5), _init_fn, cfg)
        fn = p2p.make_round_fn(counting_loss, cfg)
        targets = np.random.default_rng(5).normal(size=(4, 4))
        for _ in range(11):
            _, state, losses = fn(state, _batches(targets, 2, 4))
        assert int(state.round_idx) == 11
        assert np.isfinite(float(losses.mean()))
        assert traces[0] <= 2, (protocol, schedule, traces[0])
