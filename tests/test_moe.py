"""MoE dispatch: grouped-capacity path vs dense oracle; capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe


def _setup(rng_seed=0, e=4, k=2, d=16, f=32, shared=0, groups=1, cf=8.0):
    cfg = MoEConfig(num_experts=e, top_k=k, expert_ff=f, num_shared=shared,
                    capacity_factor=cf, router_groups=groups)
    params = moe.init(jax.random.PRNGKey(rng_seed), d, cfg, jnp.float32)
    return cfg, params


@pytest.mark.parametrize("groups", [1, 2])
@pytest.mark.parametrize("shared", [0, 1])
def test_grouped_matches_dense_reference_when_no_drops(groups, shared):
    """With a huge capacity factor nothing is dropped: exact match."""
    cfg, params = _setup(shared=shared, groups=groups, cf=64.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    out_g, aux_g = moe.apply(params, cfg, x)
    out_d, aux_d = moe.apply_dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_d), atol=1e-4)
    if groups == 1:
        np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-4)
    else:
        # per-group load-balance stats differ slightly from global ones
        np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=0.05)


def test_capacity_drops_tokens():
    """Tiny capacity: output is a (strictly) partial version of the dense one."""
    cfg, params = _setup(cf=0.25)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16), jnp.float32)
    out_small, _ = moe.apply(params, cfg, x)
    out_full, _ = moe.apply(params, cfg.__class__(**{**cfg.__dict__, "capacity_factor": 64.0}), x)
    # some tokens dropped -> outputs differ; but finite and same shape
    assert out_small.shape == out_full.shape
    assert np.isfinite(np.asarray(out_small)).all()
    assert not np.allclose(np.asarray(out_small), np.asarray(out_full))


def test_capacity_value():
    cfg = MoEConfig(num_experts=8, top_k=2, expert_ff=4, capacity_factor=1.25)
    c = moe.capacity(cfg, 1024)
    assert c >= 1024 * 2 * 1.25 / 8
    assert c % 8 == 0


def test_aux_loss_uniform_router_is_one():
    """With perfectly uniform routing, E * sum f_e P_e / k -> ~1."""
    cfg, params = _setup(e=4, k=1, cf=64.0)
    # force uniform router
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 16), jnp.float32)
    _, aux = moe.apply(params, cfg, x)
    assert 0.8 <= float(aux) <= 1.3


def test_group_count_divisibility_fallback():
    cfg = MoEConfig(num_experts=4, top_k=2, expert_ff=8, router_groups=16)
    assert moe._num_groups(cfg, 1) == 1  # long_500k decode: N=1
    assert moe._num_groups(cfg, 24) == 8  # gcd(16, 24)
    assert moe._num_groups(cfg, 32) == 16


def test_gradients_flow_through_dispatch():
    cfg, params = _setup(cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 16), jnp.float32)

    def loss(p):
        out, aux = moe.apply(p, cfg, x)
        return jnp.sum(out**2) + 0.01 * aux

    grads = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        g = np.asarray(grads[name])
        assert np.isfinite(g).all()
        assert np.abs(g).sum() > 0, f"no gradient through {name}"
