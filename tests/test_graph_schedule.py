"""Time-varying GraphSchedule: builders, matrices, and runtime integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as gl
from repro.core import p2p, protocols

K = 6


def test_static_schedule_wraps_graph():
    g = gl.build_graph("ring", K)
    s = gl.static_schedule(g)
    assert s.period == 1 and s.num_peers == K
    assert s.graph_at(0) is s.graph_at(17)
    assert s.union_is_connected()


def test_link_dropout_subset_and_determinism():
    base = gl.build_graph("complete", K)
    s1 = gl.link_dropout_schedule(base, 0.5, 10, seed=7)
    s2 = gl.link_dropout_schedule(base, 0.5, 10, seed=7)
    s3 = gl.link_dropout_schedule(base, 0.5, 10, seed=8)
    for g1, g2 in zip(s1.graphs, s2.graphs):
        assert np.array_equal(g1.adjacency, g2.adjacency)
    assert any(
        not np.array_equal(g1.adjacency, g3.adjacency)
        for g1, g3 in zip(s1.graphs, s3.graphs)
    )
    for g in s1.graphs:
        assert not (g.adjacency & ~base.adjacency).any()  # edges only from base


def test_link_dropout_survival_rate():
    base = gl.build_graph("complete", 10)
    q = 0.7
    s = gl.link_dropout_schedule(base, q, 400, seed=0)
    rate = np.mean([g.degree().sum() for g in s.graphs]) / base.degree().sum()
    assert abs(rate - q) < 0.05


def test_random_matching_is_a_matching():
    for k in (6, 7):  # even and odd peer counts
        s = gl.random_matching_schedule(k, 20, seed=1)
        for g in s.graphs:
            deg = g.degree()
            assert (deg <= 1).all()
            assert deg.sum() == 2 * ((k // 2))  # floor(k/2) pairs
    # odd K: exactly one idle peer per round
    s = gl.random_matching_schedule(7, 20, seed=1)
    assert all((g.degree() == 0).sum() == 1 for g in s.graphs)


def test_peer_churn_offline_peers_isolated():
    base = gl.build_graph("complete", K)
    s = gl.peer_churn_schedule(base, 0.5, 30, seed=0)
    degs = np.stack([g.degree() for g in s.graphs])
    assert (degs == 0).any(), "some peer must churn out at this online_prob"
    for g in s.graphs:
        assert not (g.adjacency & ~base.adjacency).any()


def test_round_robin_cycles():
    graphs = [gl.build_graph("ring", K), gl.build_graph("star", K)]
    s = gl.round_robin_schedule(graphs)
    assert s.period == 2
    assert s.graph_at(0) is graphs[0] and s.graph_at(3) is graphs[1]


def test_schedule_rejects_mismatched_peer_counts():
    with pytest.raises(ValueError):
        gl.GraphSchedule((gl.build_graph("ring", 4), gl.build_graph("ring", 6)))
    with pytest.raises(ValueError):
        gl.GraphSchedule(())


def test_schedule_matrices_shapes_and_stochasticity():
    base = gl.build_graph("ring", K)
    s = gl.peer_churn_schedule(base, 0.5, 12, seed=2)
    sizes = np.arange(1, K + 1)
    w, beta = gl.schedule_matrices(s, "data_weighted", data_sizes=sizes)
    assert w.shape == (12, K, K) and beta.shape == (12, K, K)
    for t in range(12):
        assert np.allclose(w[t].sum(axis=1), 1.0)
        assert (w[t] >= -1e-12).all()
        # isolated peers: self-loop row in W, zero row in Beta
        iso = s.graphs[t].degree() == 0
        assert np.allclose(w[t][iso], np.eye(K)[iso])
        assert np.allclose(beta[t][iso], 0.0)
        # connected peers' beta rows sum to 1 over neighbors
        assert np.allclose(beta[t][~iso].sum(axis=1), 1.0)


# ---------------------------------------------------------------------------
# Runtime integration
# ---------------------------------------------------------------------------


def _quad_loss(params, batch):
    return jnp.sum(jnp.square(params["w"] - batch))


def _init_fn(key):
    return {"w": jax.random.normal(key, (4,))}


def _batches(targets, t, k):
    return jnp.broadcast_to(jnp.asarray(targets, jnp.float32), (t, k, 4))


def test_static_schedule_bit_identical_to_static_path():
    """make_round_fn (schedule runtime) == run_round with fixed (K, K) mats,
    bit for bit, on every state leaf over several rounds."""
    cfg = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=3, local_steps=4,
                        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5,
                        topology="ring", schedule="static")
    g = gl.build_graph("ring", 3)
    w_mat = jnp.asarray(gl.mixing_matrix(g, cfg.mixing), jnp.float32)
    beta_mat = jnp.asarray(gl.affinity_matrix(g), jnp.float32)

    sched_fn = p2p.make_round_fn(_quad_loss, cfg)
    consts = protocols.ProtocolConstants(w=w_mat, beta=beta_mat)
    static_fn = jax.jit(
        lambda s, b: p2p.run_round(s, _quad_loss, b, cfg, consts)
    )
    targets = np.random.default_rng(0).normal(size=(3, 4))
    batches = _batches(targets, 4, 3)

    s_sched = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg)
    s_static = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg)
    for _ in range(5):
        al_a, s_sched, loss_a = sched_fn(s_sched, batches)
        al_b, s_static, loss_b = static_fn(s_static, batches)
        for leaf_a, leaf_b in zip(jax.tree.leaves((al_a, s_sched, loss_a)),
                                  jax.tree.leaves((al_b, s_static, loss_b))):
            assert np.array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


@pytest.mark.parametrize("schedule,extra", [
    ("link_dropout", {}),
    ("random_matching", {}),
    ("peer_churn", {}),
    ("round_robin", {"round_robin_topologies": ("ring", "star")}),
])
def test_timevarying_round_fn_single_compile(schedule, extra):
    """Every schedule runs through ONE jitted round fn: the loss is traced
    only during the initial compile, never re-traced across rounds."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _quad_loss(params, batch)

    cfg = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=4, local_steps=2,
                        consensus_steps=1, lr=0.1, topology="ring",
                        schedule=schedule, schedule_rounds=5, **extra)
    state = p2p.init_state(jax.random.PRNGKey(1), _init_fn, cfg)
    fn = p2p.make_round_fn(counting_loss, cfg)
    targets = np.random.default_rng(1).normal(size=(4, 4))
    for _ in range(12):
        _, state, losses = fn(state, _batches(targets, 2, 4))
    assert int(state.round_idx) == 12
    assert np.isfinite(float(losses.mean()))
    assert traces[0] <= 2  # value + grad trace of the single compile


def test_churned_out_peer_untouched_by_consensus():
    """A round whose graph isolates peer i must leave peer i's params equal
    to its after-local params and its d bias zero."""
    base = gl.build_graph("complete", 3)
    # round 0 isolates peer 2; round 1 is fully connected
    a0 = base.adjacency.copy()
    a0[2, :] = a0[:, 2] = False
    sched_graphs = (gl.CommGraph(a0), base)
    cfg = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=3, local_steps=2,
                        consensus_steps=1, lr=0.1, eta_d=1.0)
    w, beta = gl.schedule_matrices(gl.round_robin_schedule(sched_graphs), cfg.mixing)
    state = p2p.init_state(jax.random.PRNGKey(2), _init_fn, cfg)
    targets = np.random.default_rng(2).normal(size=(3, 4))
    after_local, after_cons, _ = p2p.run_round(
        state, _quad_loss, _batches(targets, 2, 3), cfg,
        protocols.ProtocolConstants(
            w=jnp.asarray(w[0], jnp.float32), beta=jnp.asarray(beta[0], jnp.float32)
        ),
    )
    np.testing.assert_array_equal(
        np.asarray(after_cons.params["w"][2]), np.asarray(after_local.params["w"][2])
    )
    np.testing.assert_array_equal(np.asarray(after_cons.d_bias["w"][2]), 0.0)
    # the two connected peers did mix
    assert not np.array_equal(
        np.asarray(after_cons.params["w"][0]), np.asarray(after_local.params["w"][0])
    )


def test_config_schedule_validation():
    with pytest.raises(ValueError):
        p2p.P2PConfig(schedule="nope")
    with pytest.raises(ValueError):
        p2p.P2PConfig(schedule="link_dropout", schedule_rounds=0)
    with pytest.raises(ValueError):
        p2p.P2PConfig(schedule="round_robin")  # needs topologies
    with pytest.raises(ValueError):
        gl.link_dropout_schedule(gl.build_graph("ring", 4), 0.0, 4)
    with pytest.raises(ValueError):
        gl.peer_churn_schedule(gl.build_graph("ring", 4), 1.5, 4)


# ---------------------------------------------------------------------------
# Directed graphs
# ---------------------------------------------------------------------------


def test_directed_ring_builder():
    g = gl.build_graph("directed_ring", K)
    assert g.directed
    assert not np.array_equal(g.adjacency, g.adjacency.T)  # genuinely one-way
    np.testing.assert_array_equal(g.out_degree(), 1)
    np.testing.assert_array_equal(g.in_degree(), 1)
    assert g.is_strongly_connected() and g.is_connected()
    # chain of one-way edges: strongly connected breaks when one edge is cut
    a = g.adjacency.copy()
    a[0, 1] = False
    cut = gl.CommGraph(a, directed=True)
    assert not cut.is_strongly_connected()
    assert cut.is_connected()  # still weakly connected


def test_commgraph_rejects_asymmetric_unless_directed():
    a = np.zeros((3, 3), dtype=bool)
    a[0, 1] = True
    with pytest.raises(ValueError):
        gl.CommGraph(a)
    g = gl.CommGraph(a, directed=True)
    assert g.in_degree().tolist() == [0, 1, 0]


def test_one_way_matching_is_directed_matching():
    for k in (6, 7):
        s = gl.one_way_matching_schedule(k, 20, seed=1)
        assert s.directed
        for g in s.graphs:
            assert (g.out_degree() <= 1).all() and (g.in_degree() <= 1).all()
            assert not (g.adjacency & g.adjacency.T).any()  # strictly one-way
            assert g.adjacency.sum() == k // 2  # floor(k/2) one-way pairs
    assert gl.one_way_matching_schedule(8, 40, seed=0).union_is_strongly_connected()


def test_directed_link_dropout_drops_directions_independently():
    base = gl.build_graph("complete", K)
    dbase = gl.CommGraph(base.adjacency, directed=True)
    s = gl.link_dropout_schedule(dbase, 0.5, 30, seed=0)
    assert s.directed
    for g in s.graphs:
        assert not (g.adjacency & ~dbase.adjacency).any()
    assert any(
        not np.array_equal(g.adjacency, g.adjacency.T) for g in s.graphs
    ), "independent per-direction dropout must produce an asymmetric round"


def test_column_stochastic_matrix_properties():
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 50, K)
    for topo in ("directed_ring", "ring", "star"):
        g = gl.build_graph(topo, K)
        for mixing in ("data_weighted", "metropolis", "uniform_neighbor", "identity"):
            a = gl.column_stochastic_matrix(g, mixing, data_sizes=sizes)
            np.testing.assert_allclose(a.sum(axis=0), 1.0)
            assert (a >= -1e-12).all()
            assert (np.diag(a) > 0).all()  # senders keep some mass
            # mass only flows along edges (plus the diagonal)
            off = a - np.diag(np.diag(a))
            assert not (off[~g.adjacency.T] != 0).any()
    # eps blending keeps column stochasticity
    g = gl.build_graph("directed_ring", K)
    a = gl.column_stochastic_matrix(g, "uniform_neighbor", consensus_step_size=0.5)
    np.testing.assert_allclose(a.sum(axis=0), 1.0)
    np.testing.assert_allclose(np.diag(a), 0.5 + 0.5 * 0.5)  # (1-eps) + eps/2


def test_schedule_matrices_column_stochastic():
    s = gl.one_way_matching_schedule(K, 8, seed=2)
    sizes = np.arange(1, K + 1)
    a, beta = gl.schedule_matrices(
        s, "data_weighted", data_sizes=sizes, stochasticity="column"
    )
    assert a.shape == (8, K, K) and beta.shape == (8, K, K)
    for t in range(8):
        np.testing.assert_allclose(a[t].sum(axis=0), 1.0)
        # receivers' beta rows sum to 1 over in-neighbors; senders get 0 rows
        iso = s.graphs[t].in_degree() == 0
        np.testing.assert_allclose(beta[t][iso], 0.0)
        np.testing.assert_allclose(beta[t][~iso].sum(axis=1), 1.0)
    with pytest.raises(ValueError):
        gl.schedule_matrices(s, "data_weighted", stochasticity="diagonal")


def test_metropolis_column_equals_row_on_undirected():
    """On symmetric graphs metropolis weights are doubly stochastic: the
    column-stochastic builder reproduces the row-stochastic matrix exactly."""
    g = gl.build_graph("ring", K)
    w = gl.mixing_matrix(g, "metropolis")
    a = gl.column_stochastic_matrix(g, "metropolis")
    np.testing.assert_allclose(a, w, atol=1e-12)
