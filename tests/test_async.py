"""Asynchronous rounds: per-peer step budgets + bounded-staleness gossip.

Contract under test (the acceptance criteria of the async PR):

* **Config + profiles** — ``compute_profile`` honors its >= 1 invariants and
  the documented straggler/linear shapes; invalid profiles/bounds and the
  unsupported staleness x adaptive / staleness x compressed combinations
  fail loudly at config time, and the CLI surfaces the same errors.
* **Weight renormalization** — ``age_decayed_constants`` keeps gossip rows /
  push-sum columns exactly stochastic for any decay vector, and decay=1 is
  the identity.
* **Synchronous bypass** — ``staleness_bound=0`` with a uniform profile is a
  STRUCTURAL bypass (booby-trap test, like ``compressor="none"``): the async
  machinery is never entered, so bit-parity with the legacy round holds by
  construction — in both runtimes.
* **Staleness semantics** — snapshot ages never exceed the bound (forced
  delivery), a straggler's published row is frozen between publications,
  push-sum mass is conserved exactly under maximal staleness, and capped
  peers freeze parameters exactly at their budget.
* **Drivers + compilation** — the fused scan driver is bit-identical to the
  python round loop on every async state leaf, and a time-varying async run
  keeps the one-compile contract.
* **Runtimes** — the pod (shard_map) async round is fp32 BIT-identical to
  the vmap round, leaf for leaf (mesh marker: one device per peer); the
  hierarchical runtime rejects async configs with an actionable error.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import p2p
from repro.core import protocols as protocols_lib

K = 4
T = 6


def _init_fn(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (6, 16)),
        "b1": jnp.zeros((16,)),
        "w2": jax.random.normal(k2, (16, 4)),
    }


def _mlp_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return jnp.mean(jnp.sum(jnp.square(h @ p["w2"] - y), axis=-1))


def _cfg(protocol="gossip", schedule="static", num_peers=K, **kw):
    base = dict(
        algorithm="p2pl_affinity", num_peers=num_peers, local_steps=T,
        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
        topology="ring", protocol=protocol, schedule=schedule,
        schedule_rounds=3, steps_profile="straggler", staleness_bound=3,
    )
    if schedule == "round_robin":
        base["round_robin_topologies"] = ("ring", "star")
    base.update(kw)
    return p2p.P2PConfig(**base)


def _round_batches(rng, t, k=K):
    x = jnp.asarray(rng.normal(size=(t, k, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(t, k, 10, 4)), jnp.float32)
    return (x, y)


def _assert_trees_equal(want, got, context):
    want_leaves = jax.tree_util.tree_leaves_with_path(want)
    got_leaves = jax.tree_util.tree_leaves_with_path(got)
    assert len(want_leaves) == len(got_leaves)
    for (path, w), (_, g) in zip(want_leaves, got_leaves):
        assert np.array_equal(np.asarray(w), np.asarray(g)), (
            f"{context} leaf {jax.tree_util.keystr(path)} diverged"
        )


# ---------------------------------------------------------------------------
# config validation + compute profiles
# ---------------------------------------------------------------------------


def test_config_rejects_unknown_steps_profile():
    with pytest.raises(ValueError, match="steps_profile"):
        p2p.P2PConfig(num_peers=K, steps_profile="warp")


def test_config_rejects_negative_bound():
    with pytest.raises(ValueError, match="staleness_bound"):
        p2p.P2PConfig(num_peers=K, staleness_bound=-1)


def test_config_rejects_staleness_with_adaptive():
    with pytest.raises(ValueError, match="adaptive"):
        p2p.P2PConfig(num_peers=K, schedule="adaptive", staleness_bound=2)


def test_config_rejects_staleness_with_compressor():
    with pytest.raises(ValueError, match="compressor"):
        p2p.P2PConfig(num_peers=K, compressor="topk", staleness_bound=2)


def test_steps_profile_composes_with_adaptive_and_compressor():
    """Heterogeneous step budgets alone (bound=0) compose with everything:
    the mask lives in the local phase, which neither subsystem touches."""
    p2p.P2PConfig(num_peers=K, schedule="adaptive", steps_profile="straggler")
    p2p.P2PConfig(num_peers=K, compressor="topk", steps_profile="linear")


def test_use_async_property():
    assert not p2p.P2PConfig(num_peers=K).use_async
    assert p2p.P2PConfig(num_peers=K, staleness_bound=1).use_async
    assert p2p.P2PConfig(num_peers=K, steps_profile="linear").use_async


def test_uniform_profile_is_full_steps_every_round():
    cfg = p2p.P2PConfig(num_peers=K, local_steps=T)
    steps, period = p2p.compute_profile(cfg)
    assert steps.tolist() == [T] * K
    assert period.tolist() == [1] * K


def test_straggler_profile_shapes():
    cfg = p2p.P2PConfig(
        num_peers=8, local_steps=8, steps_profile="straggler",
        straggler_frac=0.25, straggler_period=4,
    )
    steps, period = p2p.compute_profile(cfg)
    # last quarter of the fleet is slow: T/4 steps, publishes every 4 rounds
    assert steps.tolist() == [8] * 6 + [2] * 2
    assert period.tolist() == [1] * 6 + [4] * 2


def test_linear_profile_ramps_and_honors_floor():
    cfg = p2p.P2PConfig(
        num_peers=5, local_steps=4, steps_profile="linear",
        straggler_period=8,
    )
    steps, period = p2p.compute_profile(cfg)
    assert steps[0] == 4 and steps[-1] >= 1
    assert (np.diff(steps) <= 0).all()  # monotone slowdown across the fleet
    assert (steps >= 1).all() and (period >= 1).all()


@pytest.mark.parametrize("profile", sorted(p2p.STEPS_PROFILES))
@pytest.mark.parametrize("num_peers,local_steps", [(2, 1), (3, 2), (8, 8),
                                                   (16, 5)])
@pytest.mark.parametrize("straggler_period", [1, 4, 16])
def test_compute_profile_invariants(profile, num_peers, local_steps,
                                    straggler_period):
    """The documented invariants hold for EVERY profile x shape: per-peer
    budgets and publication periods never fall below 1 (a zero-step peer
    would freeze, a zero period divides by zero in the delivery rule), and
    the uniform profile is exactly the synchronous (T, 1) fleet."""
    cfg = p2p.P2PConfig(
        num_peers=num_peers, local_steps=local_steps, steps_profile=profile,
        straggler_period=straggler_period,
    )
    steps, period = p2p.compute_profile(cfg)
    assert steps.shape == period.shape == (num_peers,)
    assert steps.dtype == np.int32 and period.dtype == np.int32
    assert (steps >= 1).all() and (period >= 1).all()
    assert (steps <= local_steps).all()
    if profile == "uniform":
        assert (steps == local_steps).all() and (period == 1).all()


# ---------------------------------------------------------------------------
# age-decayed weight renormalization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stochasticity", ["row", "column"])
def test_age_decayed_constants_stay_stochastic(stochasticity):
    rng = np.random.default_rng(0)
    w = rng.random((K, K)).astype(np.float32)
    w = w / w.sum(axis=0 if stochasticity == "column" else 1, keepdims=True)
    consts = protocols_lib.ProtocolConstants(
        w=jnp.asarray(w), beta=jnp.asarray(w)
    )
    decay = jnp.asarray([1.0, 0.5, 0.25, 0.125], jnp.float32)
    out = protocols_lib.age_decayed_constants(consts, decay, stochasticity)
    sums = np.asarray(out.w).sum(axis=1 if stochasticity == "row" else 0)
    np.testing.assert_allclose(sums, np.ones(K), atol=1e-6)
    # stale senders' outgoing weight shrinks; the diagonal absorbs the slack
    off = np.asarray(out.w) - np.diag(np.diag(np.asarray(out.w)))
    orig_off = w - np.diag(np.diag(w))
    np.testing.assert_allclose(off, orig_off * np.asarray(decay)[None, :],
                               atol=1e-7)
    # beta stays a distribution over neighbors: decayed, then row-renormalized
    # (an unnormalized beta would shrink nbr_avg — and with it every
    # parameter, through d — toward the origin)
    np.testing.assert_allclose(np.asarray(out.beta).sum(axis=1), np.ones(K),
                               atol=1e-6)


def test_age_decayed_constants_identity_at_decay_one():
    w = jnp.asarray(np.full((K, K), 1.0 / K, np.float32))
    consts = protocols_lib.ProtocolConstants(w=w, beta=w)
    out = protocols_lib.age_decayed_constants(
        consts, jnp.ones((K,), jnp.float32), "row"
    )
    np.testing.assert_allclose(np.asarray(out.w), np.asarray(w), atol=1e-7)
    np.testing.assert_allclose(np.asarray(out.beta), np.asarray(w), atol=1e-7)


def test_age_decayed_constants_rejects_unknown_stochasticity():
    w = jnp.eye(K)
    consts = protocols_lib.ProtocolConstants(w=w, beta=w)
    with pytest.raises(ValueError, match="stochasticity"):
        protocols_lib.age_decayed_constants(consts, jnp.ones((K,)), "diagonal")


# ---------------------------------------------------------------------------
# delivery rule
# ---------------------------------------------------------------------------


def test_delivery_on_schedule_and_forced_at_bound():
    cfg = _cfg(num_peers=8, straggler_frac=0.25, straggler_period=4,
               staleness_bound=2)
    # straggler periods: peers 0-5 publish every round, 6-7 every 4th round
    age = jnp.zeros((8,), jnp.int32)
    delivered, age, decay = p2p._staleness_delivery(cfg, jnp.int32(0), age)
    d = np.asarray(delivered)
    assert d[:6].all() and not d[6:].any()  # round 0: rem(0, 4) != 3
    np.testing.assert_allclose(np.asarray(decay)[6:], [0.5, 0.5])
    # ages keep climbing until the bound forces delivery at age+1 > bound
    delivered, age, _ = p2p._staleness_delivery(cfg, jnp.int32(1), age)
    assert not np.asarray(delivered)[6:].any()
    assert np.asarray(age)[6:].tolist() == [2, 2]
    delivered, age, decay = p2p._staleness_delivery(cfg, jnp.int32(2), age)
    assert np.asarray(delivered)[6:].all()  # forced: age would hit 3 > bound
    assert np.asarray(age)[6:].tolist() == [0, 0]
    np.testing.assert_allclose(np.asarray(decay)[6:], [1.0, 1.0])


# ---------------------------------------------------------------------------
# synchronous structural bypass (the compressor="none" idiom)
# ---------------------------------------------------------------------------


def test_sync_config_takes_synchronous_code_path(monkeypatch):
    """bound=0 + uniform profile is a STRUCTURAL bypass: the async machinery
    is never entered, so fp32 bit-parity with the pre-async runtime holds by
    construction.  A round with every async entry point booby-trapped must
    still run."""
    def boom(*a, **k):  # pragma: no cover - must never run
        raise AssertionError("async machinery entered on the sync path")

    monkeypatch.setattr(p2p, "_consensus_phase_async", boom)
    monkeypatch.setattr(p2p, "_consensus_phase_sharded_async", boom)
    monkeypatch.setattr(p2p, "_staleness_delivery", boom)
    monkeypatch.setattr(p2p, "compute_profile", boom)
    monkeypatch.setattr(protocols_lib, "age_decayed_constants", boom)
    cfg = _cfg(steps_profile="uniform", staleness_bound=0)
    state = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg)
    assert state.staleness == ()
    fn = p2p.make_round_fn(_mlp_loss, cfg)
    x, y = _round_batches(np.random.default_rng(0), T)
    _, state, losses = fn(state, (x, y))
    assert np.isfinite(np.asarray(losses)).all()
    assert state.staleness == ()


def test_uniform_profile_scan_is_structurally_unmasked():
    """The uniform profile passes ``steps_k=None``: the local-phase scan body
    is the legacy one with NO mask in the graph — identical jaxprs, not just
    identical numbers."""
    cfg_sync = _cfg(steps_profile="uniform", staleness_bound=0)
    state = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg_sync)
    x, y = _round_batches(np.random.default_rng(0), T)

    def run(steps_k):
        s, losses = p2p.local_phase(
            state, _mlp_loss, (x, y), cfg_sync, steps_k=steps_k
        )
        return s.params, losses

    unmasked = jax.make_jaxpr(lambda: run(None))()
    full_mask = jax.make_jaxpr(lambda: run(jnp.full((K,), T, jnp.int32)))()
    assert "while" in str(unmasked) or "scan" in str(unmasked)
    assert str(unmasked) != str(full_mask)  # the mask would cost real FLOPs
    # ... and the full-budget mask is numerically the identity
    p_unmasked, l_unmasked = run(None)
    p_masked, l_masked = run(jnp.full((K,), T, jnp.int32))
    _assert_trees_equal(p_unmasked, p_masked, "full-budget mask")
    _assert_trees_equal(l_unmasked, l_masked, "full-budget losses")


# ---------------------------------------------------------------------------
# staleness semantics
# ---------------------------------------------------------------------------


def test_ages_never_exceed_bound():
    cfg = _cfg(num_peers=8, protocol="gossip", schedule="round_robin",
               straggler_period=6, staleness_bound=3)
    state = p2p.init_state(jax.random.PRNGKey(1), _init_fn, cfg)
    fn = p2p.make_round_fn(_mlp_loss, cfg)
    rng = np.random.default_rng(1)
    seen_ages = []
    for _ in range(10):
        x, y = _round_batches(rng, T, k=8)
        _, state, losses = fn(state, (x, y))
        assert np.isfinite(np.asarray(losses)).all()
        ages = np.asarray(state.staleness.age)
        seen_ages.append(ages)
        assert (ages <= cfg.staleness_bound).all(), ages
    # the profile actually produces staleness (ages > 0 occur)
    assert max(a.max() for a in seen_ages) > 0


def test_published_rows_frozen_between_publications():
    """A straggler's published snapshot must not move while undelivered, and
    must equal its live post-local params on publication rounds."""
    cfg = _cfg(num_peers=8, straggler_frac=0.25, straggler_period=4,
               staleness_bound=3)
    state = p2p.init_state(jax.random.PRNGKey(2), _init_fn, cfg)
    fn = p2p.make_round_fn(_mlp_loss, cfg)
    rng = np.random.default_rng(2)
    prev_pub = jax.tree.map(np.asarray, state.staleness.published)
    for r in range(8):
        x, y = _round_batches(rng, T, k=8)
        after_local, state, _ = fn(state, (x, y))
        pub = jax.tree.map(np.asarray, state.staleness.published)
        delivered = np.asarray(state.staleness.age) == 0
        for (path, p_leaf), (_, al_leaf), (_, prev_leaf) in zip(
            jax.tree_util.tree_leaves_with_path(pub),
            jax.tree_util.tree_leaves_with_path(after_local.params),
            jax.tree_util.tree_leaves_with_path(prev_pub),
        ):
            al_leaf = np.asarray(al_leaf)
            for k in range(8):
                want = al_leaf[k] if delivered[k] else prev_leaf[k]
                assert np.array_equal(p_leaf[k], want), (
                    f"round {r} peer {k} {jax.tree_util.keystr(path)}"
                )
        prev_pub = pub


@pytest.mark.parametrize("schedule", ["static", "round_robin"])
def test_push_sum_mass_conserved_under_maximal_staleness(schedule):
    """Column-renormalization makes push-sum's invariant EXACT under async
    delivery: sum(mass) == K on every round, even with every straggler at
    the bound."""
    cfg = _cfg(protocol="push_sum", schedule=schedule, num_peers=8,
               straggler_frac=0.5, straggler_period=8, staleness_bound=7)
    state = p2p.init_state(jax.random.PRNGKey(3), _init_fn, cfg)
    fn = p2p.make_round_fn(_mlp_loss, cfg)
    rng = np.random.default_rng(3)
    for _ in range(8):
        x, y = _round_batches(rng, T, k=8)
        _, state, _ = fn(state, (x, y))
        np.testing.assert_allclose(
            float(jnp.sum(state.protocol.mass)), 8.0, rtol=1e-6
        )
        assert (np.asarray(state.staleness.age) <= 7).all()


def test_capped_peers_freeze_exactly_at_budget():
    """Peer k's local phase with budget s equals a T=s run of the legacy
    scan, bit for bit — the mask freezes params, it does not perturb them."""
    cfg = _cfg(steps_profile="uniform", staleness_bound=0, momentum=0.3)
    state = p2p.init_state(jax.random.PRNGKey(4), _init_fn, cfg)
    x, y = _round_batches(np.random.default_rng(4), T)
    s = 2
    steps_k = jnp.asarray([T, s, T, s], jnp.int32)
    capped, _ = p2p.local_phase(state, _mlp_loss, (x, y), cfg, steps_k=steps_k)
    cfg_short = dataclasses.replace(cfg, local_steps=s)
    short, _ = p2p.local_phase(
        state, _mlp_loss, (x[:s], y[:s]), cfg_short
    )
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_leaves_with_path(capped.params),
        jax.tree_util.tree_leaves_with_path(short.params),
    ):
        a, b = np.asarray(a), np.asarray(b)
        for k in (1, 3):  # the capped peers
            assert np.array_equal(a[k], b[k]), (
                f"peer {k} {jax.tree_util.keystr(path)}"
            )


# ---------------------------------------------------------------------------
# drivers + compilation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_scan_driver_bit_identical_async(protocol):
    """The fused scan driver and the python round loop agree bit for bit on
    every async state leaf — staleness buffer included."""
    cfg = _cfg(protocol=protocol, schedule="round_robin")
    sizes = np.arange(1, K + 1)
    state0 = p2p.init_state(jax.random.PRNGKey(5), _init_fn, cfg, data_sizes=sizes)
    round_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    drive_fn = p2p.make_scan_driver(_mlp_loss, cfg, data_sizes=sizes, donate=False)

    chunk = 4
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(chunk, T, K, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(chunk, T, K, 10, 4)), jnp.float32)

    s_py = state0
    for r in range(chunk):
        _, s_py, _ = round_fn(s_py, (x[r], y[r]))
    _, s_scan, _ = drive_fn(state0, (x, y))
    _assert_trees_equal(s_py, s_scan, f"{protocol} async scan vs python")


def test_async_one_compile():
    """A time-varying async run traces the loss once: delivery masks are
    traced per-round booleans, never compile-time constants."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = _cfg(schedule="round_robin")
    state = p2p.init_state(jax.random.PRNGKey(6), _init_fn, cfg)
    fn = p2p.make_round_fn(counting_loss, cfg)
    rng = np.random.default_rng(6)
    for _ in range(5):
        x, y = _round_batches(rng, T)
        _, state, _ = fn(state, (x, y))
    assert traces[0] <= 2  # value + grad trace of the single compile


# ---------------------------------------------------------------------------
# runtime guards
# ---------------------------------------------------------------------------


def test_hier_runtime_rejects_async():
    cfg = _cfg(num_peers=8)
    with pytest.raises(ValueError, match="asynchronous.*not supported"):
        p2p._make_hier_round_step(
            _mlp_loss, cfg, mesh=None, axis_name="pod", peers_per_device=2
        )


def test_hier_runtime_rejects_steps_profile_alone():
    cfg = _cfg(num_peers=8, staleness_bound=0)
    assert cfg.use_async
    with pytest.raises(ValueError, match="asynchronous.*not supported"):
        p2p._make_hier_round_step(
            _mlp_loss, cfg, mesh=None, axis_name="pod", peers_per_device=2
        )


@pytest.mark.parametrize("argv,msg", [
    (["--experiment", "timevarying_k8", "--schedule", "adaptive",
      "--staleness-bound", "2"], "adaptive"),
    (["--experiment", "timevarying_k8", "--compressor", "topk",
      "--staleness-bound", "2"], "compressor"),
    (["--experiment", "straggler_k8", "--compressor", "topk"], "compressor"),
    (["--experiment", "straggler_k8", "--peer-axis", "pod",
      "--peers-per-device", "2"], "steps-profile"),
    (["--experiment", "straggler_k8", "--schedule", "link_dropout"],
     "static|round_robin"),
])
def test_cli_rejects_bad_async_combinations(argv, msg, capsys):
    from repro.launch import train

    with pytest.raises(SystemExit) as ex:
        train.main(argv)
    assert ex.value.code == 2  # argparse usage error, before any training
    assert msg in capsys.readouterr().err


# ---------------------------------------------------------------------------
# pod (shard_map) runtime — mesh marker: one device per peer
# ---------------------------------------------------------------------------

K8 = 8

needs_mesh = pytest.mark.skipif(
    jax.device_count() < K8,
    reason=f"needs >= {K8} devices "
           f"(XLA_FLAGS=--xla_force_host_platform_device_count={K8})",
)


@needs_mesh
@pytest.mark.mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("schedule", ["static", "round_robin"])
def test_pod_bit_identical_to_vmap_async(protocol, schedule):
    """The async pod round — split mix (this peer's row of the vmap path's
    diag/off-diag decomposition) over the once-per-round gathered snapshot
    stack — is fp32 BIT-identical to the vmap round, leaf for leaf."""
    from repro.launch import mesh as mesh_lib
    from repro.sharding import specs as specs_lib

    cfg = _cfg(protocol=protocol, schedule=schedule, num_peers=K8,
               straggler_frac=0.25, straggler_period=4)
    sizes = np.arange(1, K8 + 1)
    state0 = p2p.init_state(jax.random.PRNGKey(7), _init_fn, cfg, data_sizes=sizes)
    vmap_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
    mesh = mesh_lib.make_peer_mesh(K8)
    pod_fn = p2p.make_sharded_round_fn(_mlp_loss, cfg, mesh, data_sizes=sizes)

    s_vmap = state0
    s_pod = specs_lib.shard_peer_tree(state0, mesh)
    rng = np.random.default_rng(7)
    for r in range(6):  # crosses both the schedule and straggler periods
        x, y = _round_batches(rng, T, k=K8)
        al_v, s_vmap, loss_v = vmap_fn(s_vmap, (x, y))
        al_p, s_pod, loss_p = pod_fn(s_pod, (x, y))
        _assert_trees_equal(
            (al_v, s_vmap, loss_v), (al_p, s_pod, loss_p),
            f"{protocol}/{schedule} round {r}",
        )


@needs_mesh
@pytest.mark.mesh
def test_pod_sync_config_takes_synchronous_code_path(monkeypatch):
    """The pod runtime's bound=0 bypass is structural too."""
    from repro.launch import mesh as mesh_lib
    from repro.sharding import specs as specs_lib

    def boom(*a, **k):  # pragma: no cover - must never run
        raise AssertionError("async machinery entered on the sync pod path")

    monkeypatch.setattr(p2p, "_consensus_phase_sharded_async", boom)
    monkeypatch.setattr(p2p, "_staleness_delivery", boom)
    monkeypatch.setattr(protocols_lib, "age_decayed_constants", boom)
    cfg = _cfg(steps_profile="uniform", staleness_bound=0, num_peers=K8)
    state = p2p.init_state(jax.random.PRNGKey(8), _init_fn, cfg)
    assert state.staleness == ()
    mesh = mesh_lib.make_peer_mesh(K8)
    fn = p2p.make_sharded_round_fn(_mlp_loss, cfg, mesh)
    state = specs_lib.shard_peer_tree(state, mesh)
    x, y = _round_batches(np.random.default_rng(8), T, k=K8)
    _, state, losses = fn(state, (x, y))
    assert np.isfinite(np.asarray(losses)).all()
    assert state.staleness == ()
