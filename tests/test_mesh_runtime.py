"""Sharded peer-axis runtime: shard_map over a REAL mesh vs the vmap runtime.

The parity tests assert fp32 BIT-identity (np.array_equal, not allclose) on
every state leaf, every round, for both protocols on every schedule family —
the acceptance contract of the sharded runtime.  They need one device per
peer, so they carry the ``mesh`` marker and skip unless launched with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python -m pytest -m mesh

(CI's multi-device job does exactly this).  The fail-fast tests at the bottom
run everywhere — including the single-device tier-1 environment, where they
exercise the too-few-devices error paths.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus as cl
from repro.core import graph as gl
from repro.core import p2p, protocols
from repro.launch import mesh as mesh_lib
from repro.sharding import specs as specs_lib

K = 8

needs_mesh = pytest.mark.skipif(
    jax.device_count() < K,
    reason=f"needs >= {K} devices "
           f"(XLA_FLAGS=--xla_force_host_platform_device_count={K})",
)


def _init_fn(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (6, 16)),
        "b1": jnp.zeros((16,)),
        "w2": jax.random.normal(k2, (16, 4)),
    }


def _mlp_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return jnp.mean(jnp.sum(jnp.square(h @ p["w2"] - y), axis=-1))


def _round_batches(rng, t):
    x = jnp.asarray(rng.normal(size=(t, K, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(t, K, 10, 4)), jnp.float32)
    return (x, y)


SCHEDULE_GRID = [
    ("static", {}),
    ("link_dropout", {}),
    ("round_robin", {"round_robin_topologies": ("ring", "star")}),
    ("one_way_matching", {}),
    ("random_matching", {}),
    ("peer_churn", {}),  # degree-0 rounds: churned-out peers keep their state
    # state-dependent topology: partners picked ON DEVICE from run state each
    # round (pod side: complete-graph candidate lanes, adaptively nulled)
    ("adaptive", {"partner_rule": "loss_proximity"}),
    ("adaptive", {"partner_rule": "eps_greedy"}),
]


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("schedule,extra", SCHEDULE_GRID, ids=[s for s, _ in SCHEDULE_GRID])
def test_shard_map_round_bit_identical_to_vmap(protocol, schedule, extra):
    """Every leaf of (after_local, after_consensus, losses) matches the vmap
    runtime bit for bit, on every round of a full schedule period."""
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=3,
        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
        topology="ring", protocol=protocol, schedule=schedule,
        schedule_rounds=5, **extra,
    )
    sizes = np.arange(1, K + 1)
    with warnings.catch_warnings():
        # gossip on the directed one_way_matching schedule warns (biased
        # consensus point) — deliberate here: parity covers the grid anyway
        warnings.simplefilter("ignore")
        vmap_fn = p2p.make_round_fn(_mlp_loss, cfg, data_sizes=sizes)
        mesh = mesh_lib.make_peer_mesh(K)
        shard_fn = p2p.make_sharded_round_fn(_mlp_loss, cfg, mesh, data_sizes=sizes)
    s_vmap = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    s_shard = specs_lib.shard_peer_tree(s_vmap, mesh)

    rng = np.random.default_rng(0)
    for r in range(6):  # crosses the period boundary (R=5)
        batches = _round_batches(rng, cfg.local_steps)
        al_v, s_vmap, loss_v = vmap_fn(s_vmap, batches)
        al_s, s_shard, loss_s = shard_fn(s_shard, batches)
        want = jax.tree_util.tree_leaves_with_path((al_v, s_vmap, loss_v))
        got = jax.tree_util.tree_leaves_with_path((al_s, s_shard, loss_s))
        assert len(want) == len(got)
        for (path, w), (_, g) in zip(want, got):
            assert np.array_equal(np.asarray(w), np.asarray(g)), (
                f"{protocol}/{schedule} round {r} leaf "
                f"{jax.tree_util.keystr(path)} diverged: max |diff| = "
                f"{np.abs(np.asarray(w, np.float64) - np.asarray(g, np.float64)).max():.3e}"
            )


@pytest.mark.mesh
@needs_mesh
def test_sharded_runtime_one_compile():
    """The sharded round keeps the one-compile property on a time-varying
    schedule (round selection happens inside the traced program)."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=2,
        consensus_steps=1, lr=0.05, eta_d=0.5, topology="ring",
        schedule="link_dropout", schedule_rounds=4,
    )
    mesh = mesh_lib.make_peer_mesh(K)
    fn = p2p.make_sharded_round_fn(counting_loss, cfg, mesh)
    state = specs_lib.shard_peer_tree(
        p2p.init_state(jax.random.PRNGKey(1), _init_fn, cfg), mesh
    )
    rng = np.random.default_rng(1)
    for _ in range(9):
        _, state, losses = fn(state, _round_batches(rng, cfg.local_steps))
    assert int(state.round_idx) == 9
    assert np.isfinite(float(jnp.mean(losses)))
    assert traces[0] <= 2  # value + grad trace of the single compile


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
@pytest.mark.parametrize("schedule,extra", [
    ("static", {}),
    ("round_robin", {"round_robin_topologies": ("ring", "star")}),
    ("adaptive", {"partner_rule": "loss_proximity"}),
], ids=["static", "round_robin", "adaptive"])
def test_scan_driver_pod_bit_identical_to_python_loop_and_vmap(
    protocol, schedule, extra
):
    """The scanned driver on the POD runtime: bit-identical to (a) the
    python-loop pod driver and (b) the scanned VMAP driver, across two chunks
    that cross the schedule period — the leaf-pipelined ppermute overlap must
    not cost a single ulp."""
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=3,
        consensus_steps=2, lr=0.1, momentum=0.3, eta_d=0.5, eta_b=0.1,
        topology="ring", protocol=protocol, schedule=schedule,
        schedule_rounds=5, **extra,
    )
    sizes = np.arange(1, K + 1)
    chunk = 3
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        mesh = mesh_lib.make_peer_mesh(K)
        pod_round = p2p.make_sharded_round_fn(_mlp_loss, cfg, mesh, data_sizes=sizes)
        pod_drive = p2p.make_scan_driver(
            _mlp_loss, cfg, data_sizes=sizes, mesh=mesh, donate=False
        )
        vmap_drive = p2p.make_scan_driver(_mlp_loss, cfg, data_sizes=sizes, donate=False)
    state0 = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg, data_sizes=sizes)
    state0_pod = specs_lib.shard_peer_tree(state0, mesh)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, chunk, 3, K, 10, 6)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(2, chunk, 3, K, 10, 4)), jnp.float32)

    s_py, al_py, losses_py = state0_pod, None, []
    for c in range(2):
        for r in range(chunk):
            al_py, s_py, loss_r = pod_round(s_py, (x[c, r], y[c, r]))
            losses_py.append(np.asarray(loss_r))
    s_pod, al_pod, losses_pod = state0_pod, None, []
    s_vmap, al_vmap, losses_vmap = state0, None, []
    for c in range(2):
        al_pod, s_pod, loss_c = pod_drive(s_pod, (x[c], y[c]))
        losses_pod.append(np.asarray(loss_c))
        al_vmap, s_vmap, loss_v = vmap_drive(s_vmap, (x[c], y[c]))
        losses_vmap.append(np.asarray(loss_v))

    for tag, want, got in [
        ("pod python-loop vs pod scan",
         (al_py, s_py, np.stack(losses_py)),
         (al_pod, s_pod, np.concatenate(losses_pod))),
        ("pod scan vs vmap scan",
         (al_pod, s_pod, np.concatenate(losses_pod)),
         (al_vmap, s_vmap, np.concatenate(losses_vmap))),
    ]:
        want_l = jax.tree_util.tree_leaves_with_path(want)
        got_l = jax.tree_util.tree_leaves_with_path(got)
        assert len(want_l) == len(got_l)
        for (path, w), (_, g) in zip(want_l, got_l):
            assert np.array_equal(np.asarray(w), np.asarray(g)), (
                f"{protocol}/{schedule} {tag}: leaf "
                f"{jax.tree_util.keystr(path)} diverged"
            )


@pytest.mark.mesh
@needs_mesh
def test_scan_driver_pod_one_compile_and_donation():
    """One compile for a multi-chunk pod scan run + the donated input state
    is consumed (its sharded buffers deleted)."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=2,
        consensus_steps=1, lr=0.05, eta_d=0.5, topology="ring",
        schedule="link_dropout", schedule_rounds=4,
    )
    mesh = mesh_lib.make_peer_mesh(K)
    drive = p2p.make_scan_driver(counting_loss, cfg, mesh=mesh)
    state = specs_lib.shard_peer_tree(
        p2p.init_state(jax.random.PRNGKey(1), _init_fn, cfg), mesh
    )
    first_state = state
    rng = np.random.default_rng(1)
    chunk = 4
    for _ in range(3):
        x = jnp.asarray(rng.normal(size=(chunk, 2, K, 10, 6)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(chunk, 2, K, 10, 4)), jnp.float32)
        _, state, losses = drive(state, (x, y))
    assert int(state.round_idx) == 3 * chunk
    assert np.isfinite(np.asarray(losses)).all()
    assert traces[0] <= 2  # value + grad trace of the single compile
    assert drive._cache_size() == 1  # the jit cache agrees
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(first_state))


@pytest.mark.mesh
@needs_mesh
@pytest.mark.parametrize("protocol", ["gossip", "push_sum"])
def test_adaptive_sharded_one_compile(protocol):
    """Adaptive partner selection inside the sharded round: the on-device
    matching (all_gather'd loss K-vector + threaded key) keeps the
    one-compile property — no host callback, no retrace across rounds."""
    traces = [0]

    def counting_loss(params, batch):
        traces[0] += 1
        return _mlp_loss(params, batch)

    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=2,
        consensus_steps=1, lr=0.05, eta_d=0.5, schedule="adaptive",
        partner_rule="eps_greedy", protocol=protocol,
    )
    mesh = mesh_lib.make_peer_mesh(K)
    fn = p2p.make_sharded_round_fn(counting_loss, cfg, mesh)
    state = specs_lib.shard_peer_tree(
        p2p.init_state(jax.random.PRNGKey(3), _init_fn, cfg), mesh
    )
    rng = np.random.default_rng(3)
    for _ in range(6):
        _, state, losses = fn(state, _round_batches(rng, cfg.local_steps))
    assert int(state.round_idx) == 6
    assert np.isfinite(float(jnp.mean(losses)))
    assert traces[0] <= 2  # value + grad trace of the single compile
    assert fn._cache_size() == 1  # the jit cache agrees
    if protocol == "push_sum":
        mass = np.asarray(state.protocol.mass)
        np.testing.assert_allclose(mass.sum(), K, rtol=1e-5)


@pytest.mark.mesh
@needs_mesh
def test_sharded_push_sum_mass_conservation():
    """The ppermute'd mass lane conserves sum_k y_k == K across rounds."""
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=2,
        consensus_steps=1, lr=0.05, eta_d=0.5, protocol="push_sum",
        schedule="one_way_matching", schedule_rounds=6,
    )
    mesh = mesh_lib.make_peer_mesh(K)
    fn = p2p.make_sharded_round_fn(_mlp_loss, cfg, mesh)
    state = specs_lib.shard_peer_tree(
        p2p.init_state(jax.random.PRNGKey(2), _init_fn, cfg), mesh
    )
    rng = np.random.default_rng(2)
    for _ in range(8):
        _, state, _ = fn(state, _round_batches(rng, cfg.local_steps))
        mass = np.asarray(state.protocol.mass)
        np.testing.assert_allclose(mass.sum(), K, rtol=1e-5)
        assert (mass > 0).all()


@pytest.mark.mesh
@needs_mesh
@pytest.mark.slow
def test_sharded_paper_experiment_matches_vmap_end_to_end(mnist_small):
    """The full training driver (--peer-axis pod) reproduces the vmap
    driver's accuracy trajectories exactly on the sharded_k8 workload."""
    from repro.configs.p2pl_mnist import sharded_k8
    from repro.launch.train import run_paper_experiment

    exp = sharded_k8(schedule="link_dropout", protocol="gossip", local_steps=2)
    log_v = run_paper_experiment(exp, rounds=2, data=mnist_small, peer_axis="vmap")
    log_p = run_paper_experiment(exp, rounds=2, data=mnist_small, peer_axis="pod")
    for attr in ("after_local", "after_consensus"):
        want, got = getattr(log_v, attr), getattr(log_p, attr)
        assert want.keys() == got.keys()
        for group in want:
            assert np.array_equal(np.stack(want[group]), np.stack(got[group])), (
                attr, group,
            )
    assert log_v.train_loss == log_p.train_loss


# ---------------------------------------------------------------------------
# Spec helpers + fail-fast paths (run everywhere, including tier-1's single
# device — that environment is exactly where the error paths are reachable)
# ---------------------------------------------------------------------------


def test_peer_stacked_pspecs_shapes():
    from jax.sharding import PartitionSpec as P

    tree = {
        "w": jnp.zeros((4, 5, 3)),
        "mass": jnp.zeros((4,)),
        "step": jnp.zeros(()),
    }
    specs = specs_lib.peer_stacked_pspecs(tree, peer_axis="pod")
    assert specs["w"] == P("pod", None, None)
    assert specs["mass"] == P("pod")
    assert specs["step"] == P()

    batches = {"x": jnp.zeros((3, 4, 10, 6))}
    bspecs = specs_lib.peer_batch_pspecs(batches, peer_axis="pod")
    assert bspecs["x"] == P(None, "pod", None, None)
    with pytest.raises(ValueError):
        specs_lib.peer_batch_pspecs({"x": jnp.zeros((3,))})


def test_make_peer_mesh_fails_fast_with_hint():
    too_many = jax.device_count() + 1
    with pytest.raises(RuntimeError, match="xla_force_host_platform_device_count"):
        mesh_lib.make_peer_mesh(too_many)
    with pytest.raises(ValueError):
        mesh_lib.make_peer_mesh(0)


def test_make_sharded_round_fn_validates_mesh_axis():
    mesh = mesh_lib.make_peer_mesh(1)
    cfg = p2p.P2PConfig(num_peers=2, local_steps=1)
    with pytest.raises(ValueError, match="num_peers"):
        p2p.make_sharded_round_fn(_mlp_loss, cfg, mesh)
    with pytest.raises(ValueError, match="num_peers"):
        p2p.make_sharded_round_fn(
            _mlp_loss, p2p.P2PConfig(num_peers=1, local_steps=1), mesh,
            axis_name="nope",
        )


@pytest.mark.skipif(
    jax.device_count() >= 2,
    reason="exercises the too-few-devices CLI error (single-device env only)",
)
def test_train_cli_fails_fast_on_missing_devices(capsys):
    from repro.launch import train

    with pytest.raises(SystemExit) as excinfo:
        train.main(["--experiment", "noniid_affinity", "--peer-axis", "pod",
                    "--rounds", "1"])
    assert excinfo.value.code == 2  # argparse error, not an XLA shape error
    err = capsys.readouterr().err
    assert "xla_force_host_platform_device_count" in err
    assert "num_peers=2" in err


class _LegacyGossip(protocols.ConsensusProtocol):
    """A protocol written against the PRE-scan sharded interface: whole-tree
    ``mix_sharded`` override, no ``mix_sharded_begin``/``mix_sharded_leaf``."""

    name = "legacy_gossip_test"

    def init_state(self, params, data_sizes=None):
        return ()

    def mix(self, proto_state, params, consts):
        return proto_state, cl.mix_stacked(consts.w, params)

    def mix_sharded(self, proto_state, params, params_full, w_mat, *, axis_name, lanes):
        my = jax.lax.axis_index(axis_name)
        w_row = jnp.take(w_mat, my, axis=0)[None]
        return proto_state, cl.mix_stacked(w_row, params_full)


def test_legacy_protocol_mix_sharded_fallback(rng):
    """consensus_phase_sharded must route a begin/leaf-less protocol through
    its whole-tree mix_sharded override (unpipelined fallback) instead of
    hitting the base class's NotImplementedError or ignoring the override."""
    if _LegacyGossip.name not in protocols.protocol_names():
        protocols.register_protocol(_LegacyGossip())
    k = 4
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=k, local_steps=2,
        consensus_steps=1, eta_d=0.5, topology="ring",
        protocol=_LegacyGossip.name,
    )
    g = gl.build_graph("ring", k)
    sched = gl.static_schedule(g)
    w, beta = gl.schedule_matrices(sched, "metropolis")
    lanes = gl.schedule_lanes(sched)
    consts = protocols.ProtocolConstants(
        jnp.asarray(w[0], jnp.float32), jnp.asarray(beta[0], jnp.float32)
    )
    params = {"w": jnp.asarray(rng.normal(size=(k, 5)), jnp.float32)}
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = p2p.P2PState(
        params=params, momentum=zeros, d_bias=zeros, b_bias=zeros,
        round_idx=jnp.zeros((), jnp.int32), protocol=(),
    )

    blocked = p2p.P2PState(
        params=jax.tree.map(lambda x: x[:, None], params),
        momentum=jax.tree.map(lambda x: x[:, None], zeros),
        d_bias=jax.tree.map(lambda x: x[:, None], zeros),
        b_bias=jax.tree.map(lambda x: x[:, None], zeros),
        round_idx=state.round_idx, protocol=(),
    )
    axes = p2p.P2PState(
        params=0, momentum=0, d_bias=0, b_bias=0, round_idx=None, protocol=None
    )

    def per_peer(block):
        out = p2p.consensus_phase_sharded(
            block, cfg, consts, axis_name="peer", lanes=lanes
        )
        return jax.tree.map(lambda x: x[0], (out.params, out.d_bias))

    got_params, got_d = jax.vmap(per_peer, in_axes=(axes,), axis_name="peer")(blocked)
    want = p2p.consensus_phase(state, cfg, consts)
    np.testing.assert_allclose(
        np.asarray(got_params["w"]), np.asarray(want.params["w"]), atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(got_d["w"]), np.asarray(want.d_bias["w"]), atol=1e-6
    )


def test_gossip_mix_sharded_under_vmap_axis(rng):
    """The protocol's sharded mix rule is exercisable without a mesh: a vmap
    axis stands in for the pod axis (lane gather + row einsum == dense mix)."""
    k = 6
    g = gl.build_graph("ring", k)
    sched = gl.static_schedule(g)
    w, _ = gl.schedule_matrices(sched, "metropolis")
    lanes = gl.schedule_lanes(sched)
    w_dev = jnp.asarray(w[0], jnp.float32)
    tree = {"w": jnp.asarray(rng.normal(size=(k, 5)), jnp.float32)}
    proto = protocols.get_protocol("gossip")

    def per_peer(block):
        full = cl.gather_peer_rows(block, "peer", lanes, k)
        _, mixed = proto.mix_sharded(
            (), block, full, w_dev, axis_name="peer", lanes=lanes
        )
        return jax.tree.map(lambda x: x[0], mixed)

    blocks = jax.tree.map(lambda x: x[:, None], tree)  # (K, 1, ...) blocks
    out = jax.vmap(per_peer, axis_name="peer")(blocks)
    want = cl.mix_stacked(w_dev, tree)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want["w"]), atol=1e-6)
