"""Sharding/dry-run machinery on a small 8-device mesh (subprocess: the
device-count override must not leak into other tests)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch import dryrun_lib
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh({shape}, {axes})
res = dryrun_lib.run_case(
    "{arch}", "{shape_name}", mesh,
    multi_pod={multi}, mesh_name="test", with_consensus={multi},
)
print(json.dumps({{
    "ok": res.ok,
    "error": res.error[-2000:] if res.error else "",
    "dominant": res.report.dominant if res.report else "",
    "coll": res.report.coll_wire_bytes_per_chip if res.report else 0,
    "consensus": bool(res.consensus_report),
}}))
"""


def _run(arch, shape_name, shape, axes, multi):
    code = SCRIPT.format(
        arch=arch, shape_name=shape_name, shape=shape, axes=axes,
        n=len(axes), multi=multi,
    )
    p = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert p.returncode == 0, p.stderr[-3000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["ok"], out["error"]
    return out


@pytest.mark.slow
def test_single_pod_train_lowers_on_small_mesh():
    out = _run("smollm-135m", "train_4k", (2, 4), ("data", "model"), False)
    assert out["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_multi_pod_train_and_consensus_lower():
    out = _run("smollm-135m", "train_4k", (2, 2, 2), ("pod", "data", "model"), True)
    assert out["consensus"], "consensus step must lower on the pod axis"
    assert out["coll"] > 0


@pytest.mark.slow
def test_decode_lowers_on_small_mesh():
    out = _run("rwkv6-7b", "decode_32k", (2, 4), ("data", "model"), False)
    assert out["dominant"] in ("compute", "memory", "collective")
