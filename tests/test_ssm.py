"""SSM blocks: chunked forms == sequential oracles; decode state continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models import ssm

M_CFG = SSMConfig(kind="mamba2", state_dim=16, head_dim=32, expand=2, chunk=8)
R_CFG = SSMConfig(kind="rwkv6", head_dim=16, lora_rank=8, chunk=8)
D = 64


@pytest.fixture(scope="module")
def mamba_params():
    return ssm.mamba2_init(jax.random.PRNGKey(0), D, M_CFG, jnp.float32)


@pytest.fixture(scope="module")
def rwkv_params():
    return ssm.rwkv6_init(jax.random.PRNGKey(0), D, 2 * D, R_CFG, jnp.float32)


def test_mamba2_chunked_equals_scan(mamba_params):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, D), jnp.float32)
    o1, s1 = ssm.mamba2_apply_scan(mamba_params, M_CFG, x)
    o2, s2 = ssm.mamba2_apply_chunked(mamba_params, M_CFG, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1["ssm"]), np.asarray(s2["ssm"]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1["conv"]), np.asarray(s2["conv"]), atol=2e-5)


def test_mamba2_state_continuation(mamba_params):
    """prefill(2T) == prefill(T) then scan the second half with carried state."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, D), jnp.float32)
    o_full, s_full = ssm.mamba2_apply_chunked(mamba_params, M_CFG, x)
    o_a, s_a = ssm.mamba2_apply_chunked(mamba_params, M_CFG, x[:, :8])
    o_b, s_b = ssm.mamba2_apply_scan(mamba_params, M_CFG, x[:, 8:], s_a)
    np.testing.assert_allclose(np.asarray(o_full[:, 8:]), np.asarray(o_b), atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_full["ssm"]), np.asarray(s_b["ssm"]), atol=3e-5)


def test_mamba2_decode_one_token(mamba_params):
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 9, D), jnp.float32)
    o_full, _ = ssm.mamba2_apply_scan(mamba_params, M_CFG, x)
    _, s = ssm.mamba2_apply_scan(mamba_params, M_CFG, x[:, :8])
    o_step, _ = ssm.mamba2_apply_scan(mamba_params, M_CFG, x[:, 8:9], s)
    np.testing.assert_allclose(np.asarray(o_full[:, -1]), np.asarray(o_step[:, 0]), atol=3e-5)


def test_rwkv6_chunked_equals_scan(rwkv_params):
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, D), jnp.float32)
    st = ssm.rwkv6_state(D, R_CFG, 2, jnp.float32)
    tm = rwkv_params["time_mix"]
    o1, p1, w1 = ssm.rwkv6_time_mix_scan(tm, R_CFG, x, st["tm_prev"], st["wkv"])
    o2, p2, w2 = ssm.rwkv6_time_mix_chunked(tm, R_CFG, x, st["tm_prev"], st["wkv"])
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=3e-5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2))


def test_rwkv6_block_decode_continuation(rwkv_params):
    """Chunked prefill then one-token scan == full chunked run."""
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 17, D), jnp.float32)
    st0 = ssm.rwkv6_state(D, R_CFG, 1, jnp.float32)
    o_full, s_full = ssm.rwkv6_block_apply(rwkv_params, R_CFG, x[:, :16], st0, chunked=True)
    o_step, s_step = ssm.rwkv6_block_apply(rwkv_params, R_CFG, x[:, 16:17], s_full, chunked=False)
    # run full 17 via scan for ground truth (17 not divisible by chunk)
    o_ref, s_ref = ssm.rwkv6_block_apply(rwkv_params, R_CFG, x, st0, chunked=False)
    np.testing.assert_allclose(np.asarray(o_step[:, 0]), np.asarray(o_ref[:, -1]), atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_step["wkv"]), np.asarray(s_ref["wkv"]), atol=3e-5)


def test_rwkv6_decay_is_bounded(rwkv_params):
    """Data-dependent log-decay is always strictly negative (stable state)."""
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, D), jnp.float32) * 5
    prev = jnp.zeros((2, D), jnp.float32)
    *_, logd, _ = ssm._tm_projections(rwkv_params["time_mix"], x, prev)
    assert (np.asarray(logd) < 0).all()
