"""Dead-link check for the markdown documentation surface.

Scans ``[text](target)`` markdown links in the given files and fails if any
*relative* target does not exist on disk (resolved against the linking
file's directory, ``#fragment`` stripped).  External links (``http(s)://``,
``mailto:``) and pure in-page anchors (``#...``) are ignored — CI must not
flake on network reachability.

    python tools/check_links.py README.md docs/ARCHITECTURE.md benchmarks/README.md

Exit status 0 iff every relative link resolves; broken links are listed as
``file:line: target``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links only; reference-style ([text][ref]) is not used in this repo.
# The target group stops at the first ')' — none of our paths contain one.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def broken_links(md_path: Path) -> list[tuple[int, str]]:
    """Return (line_number, target) for every unresolvable relative link."""
    out = []
    in_fence = False
    for lineno, line in enumerate(md_path.read_text().splitlines(), start=1):
        # links inside fenced code blocks are example text, not navigation
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in _LINK.findall(line):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if rel and not (md_path.parent / rel).exists():
                out.append((lineno, target))
    return out


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python tools/check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    failures = 0
    for name in argv:
        path = Path(name)
        if not path.exists():
            print(f"{name}: file itself does not exist", file=sys.stderr)
            failures += 1
            continue
        for lineno, target in broken_links(path):
            print(f"{name}:{lineno}: broken link -> {target}", file=sys.stderr)
            failures += 1
    if failures:
        print(f"{failures} broken link(s)", file=sys.stderr)
        return 1
    print(f"OK: all relative links in {len(argv)} file(s) resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
