"""Check (or regenerate) the README's generated feature-compatibility table.

The cross-feature exclusion matrix in the README is GENERATED from the one
source of truth, ``repro.core.features.INCOMPATIBILITIES`` — the same table
every runtime layer raises from.  This script compares the block between the

    <!-- BEGIN GENERATED SUPPORT MATRIX (tools/check_support_matrix.py) -->
    <!-- END GENERATED SUPPORT MATRIX -->

markers against ``features.support_matrix_markdown()`` and fails on drift, so
documented compatibility and enforced compatibility cannot diverge.

    python tools/check_support_matrix.py README.md           # check (CI)
    python tools/check_support_matrix.py README.md --write   # regenerate

Exit status 0 iff the block matches (or was rewritten with ``--write``).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import features  # noqa: E402

BEGIN = "<!-- BEGIN GENERATED SUPPORT MATRIX (tools/check_support_matrix.py) -->"
END = "<!-- END GENERATED SUPPORT MATRIX -->"


def split_block(text: str, path: str) -> tuple[str, str, str]:
    """(before, inside, after) around the marker pair; errors are fatal."""
    try:
        head, rest = text.split(BEGIN, 1)
        inside, tail = rest.split(END, 1)
    except ValueError:
        sys.exit(f"{path}: marker pair not found (need both {BEGIN!r} and {END!r})")
    return head, inside, tail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("readme", help="markdown file holding the generated block")
    ap.add_argument("--write", action="store_true",
                    help="rewrite the block instead of checking it")
    args = ap.parse_args(argv)

    path = Path(args.readme)
    text = path.read_text()
    head, inside, tail = split_block(text, args.readme)
    want = "\n" + features.support_matrix_markdown()

    if inside == want:
        print(f"OK: {args.readme} support matrix matches "
              f"core/features.py ({len(features.INCOMPATIBILITIES)} rows)")
        return 0
    if args.write:
        path.write_text(head + BEGIN + want + END + tail)
        print(f"rewrote {args.readme} support matrix "
              f"({len(features.INCOMPATIBILITIES)} rows)")
        return 0
    print(f"{args.readme}: support matrix is out of date with "
          "core/features.py — run:\n"
          f"    python tools/check_support_matrix.py {args.readme} --write",
          file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
