"""The scenario axis: static vs time-varying communication graphs.

One row per (schedule, metric):
- mean per-round spectral gap of W_t (connectivity actually available),
- consensus error after one schedule period of pure gossip from a common
  random start (how much mixing the schedule delivers),
- unseen-class oscillation amplitude of a short K=2 non-IID training run
  (the paper's sawtooth, now under link churn).

`full=True` scales data/rounds up; the derived numbers are what
EXPERIMENTS.md quotes for the schedule comparison.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.configs.p2pl_mnist import timevarying_k2
from repro.core import consensus as consensus_lib
from repro.core import graph as graph_lib
from repro.core import p2p
from repro.data import synthetic
from repro.launch.train import run_paper_experiment

K_GOSSIP = 16  # peers for the pure-gossip metrics


def _schedules(rounds: int, seed: int = 0) -> dict[str, graph_lib.GraphSchedule]:
    base = graph_lib.build_graph("ring", K_GOSSIP)
    return {
        "static_ring": graph_lib.static_schedule(base),
        "link_dropout": graph_lib.link_dropout_schedule(base, 0.7, rounds, seed=seed),
        "random_matching": graph_lib.random_matching_schedule(K_GOSSIP, rounds, seed=seed),
        "peer_churn": graph_lib.peer_churn_schedule(base, 0.8, rounds, seed=seed),
    }


def _gossip_metrics(
    sched: graph_lib.GraphSchedule, rounds: int
) -> tuple[float, float, float]:
    w, _ = graph_lib.schedule_matrices(sched, "metropolis")
    gaps = [graph_lib.spectral_gap(w[t % sched.period]) for t in range(rounds)]
    # A single time-varying round is often disconnected (gap 0); what governs
    # convergence is the product of the round matrices over one period.
    prod = np.linalg.multi_dot(list(w)) if sched.period > 1 else w[0]
    period_gap = graph_lib.spectral_gap(prod)
    x = {"x": jnp.asarray(np.random.default_rng(0).normal(size=(K_GOSSIP, 64)), jnp.float32)}
    for t in range(rounds):
        x = consensus_lib.mix_stacked(jnp.asarray(w[t % sched.period], jnp.float32), x)
    return float(np.mean(gaps)), period_gap, float(consensus_lib.consensus_error(x))


def schedule_gossip(full=False):
    """Pure-gossip comparison: spectral gaps + consensus error per schedule."""
    rounds = 64 if full else 16
    out = []
    for name, sched in _schedules(rounds).items():
        t0 = time.time()
        gap, period_gap, err = _gossip_metrics(sched, rounds)
        us = (time.time() - t0) / rounds * 1e6
        out.append((f"sched_{name}_mean_spectral_gap", us, gap))
        out.append((f"sched_{name}_period_product_gap", us, period_gap))
        out.append((f"sched_{name}_consensus_error_{rounds}r", us, err))
    return out


def schedule_training(full=False):
    """K=2 non-IID training under static vs time-varying links: oscillation."""
    rounds = 40 if full else 10
    data = synthetic.mnist_like(20000 if full else 6000, 4000 if full else 1500)
    out = []
    for schedule in ("static", "link_dropout", "random_matching"):
        exp = timevarying_k2(schedule=schedule, algorithm="local_dsgd",
                             local_steps=10, link_survival_prob=0.7)
        t0 = time.time()
        log = run_paper_experiment(exp, rounds=rounds, data=data)
        us = (time.time() - t0) / rounds * 1e6
        sched = p2p.build_schedule(exp.p2p)
        out.append((f"sched_train_{schedule}_unseen_osc", us,
                    log.mean_oscillation("peer1_seen")))
        out.append((f"sched_train_{schedule}_final_all_acc", us,
                    log.final_accuracy("all")))
        out.append((f"sched_train_{schedule}_union_connected", us,
                    float(sched.union_is_connected())))
    return out


ALL_SCHEDULES = {
    "sched_gossip": schedule_gossip,
    "sched_train": schedule_training,
}
