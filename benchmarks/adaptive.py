"""The state-dependent topology axis: adaptive loss-driven partner selection.

Onoszko et al. (2107.08517) select gossip partners by training-loss proximity;
the repo's ``schedule="adaptive"`` runs that selection ON DEVICE inside the
one jitted round function.  This benchmark trains the non-IID
``timevarying_k8``-class workload (8 peers, 2 classes each) under each partner
rule and the static baselines, and measures what the paper cares about:

    adaptive_{variant}_osc            us col = wall-clock us/round,
                                      derived = post-consensus oscillation
                                      amplitude (mean |acc_cons - acc_local|)
    adaptive_{variant}_consensus_err  derived = mean consensus error
    adaptive_{variant}_final_acc      derived = final all-class accuracy

plus the CI-gated *damping booleans* — the claim the adaptive subsystem
exists to deliver:

    adaptive_lossprox_damps_vs_random   us col = oscillation ratio
                                        (random / loss_proximity),
                                        derived = 1.0 iff loss-proximity
                                        oscillates LESS than random matching
    adaptive_eps_greedy_damps_vs_random same for the eps-greedy rule

Loss-proximal peers tend to hold similar data, so averaging with them costs
less local progress: the sawtooth shrinks.  (Consensus error moves the other
way — proximity pairing mixes within loss clusters first — which is why the
booleans gate oscillation, not error.)  All runs are seeded and deterministic;
``benchmarks/compare.py`` gates every ``derived`` against the committed
``BENCH_adaptive.json``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.p2pl_mnist import timevarying_k8
from repro.data import synthetic
from repro.launch.train import run_paper_experiment

# (variant label, schedule, partner_rule) — adaptive rules vs the static
# matched-communication baselines (same one-partner-per-round budget)
VARIANTS = (
    ("lossprox", "adaptive", "loss_proximity"),
    ("eps_greedy", "adaptive", "eps_greedy"),
    ("random", "adaptive", "random"),
    ("static_matching", "random_matching", "loss_proximity"),
    ("round_robin", "round_robin", "loss_proximity"),
)


def adaptive(full=False):
    """Oscillation/consensus-error grid: adaptive rules vs static schedules."""
    rounds = 40 if full else 16
    data = synthetic.mnist_like(20000 if full else 6000, 5000 if full else 1500)
    out = []
    osc = {}
    for name, schedule, rule in VARIANTS:
        exp = timevarying_k8(schedule=schedule, algorithm="p2pl_affinity",
                             local_steps=10, partner_rule=rule)
        t0 = time.time()
        log = run_paper_experiment(exp, rounds=rounds, data=data)
        us = (time.time() - t0) / rounds * 1e6
        osc[name] = log.mean_oscillation("all")
        out.append((f"adaptive_{name}_osc", us, osc[name]))
        out.append((
            f"adaptive_{name}_consensus_err", us,
            float(np.mean(log.consensus_error)),
        ))
        out.append((f"adaptive_{name}_final_acc", us, log.final_accuracy("all")))
    # the CI-gated claim: loss-driven selection damps the sawtooth relative to
    # random matching at IDENTICAL communication cost (both are one-partner
    # matchings; only who gets matched differs)
    for name in ("lossprox", "eps_greedy"):
        out.append((
            f"adaptive_{name}_damps_vs_random",
            osc["random"] / osc[name],  # us col carries the damping ratio
            1.0 if osc[name] < osc["random"] else 0.0,
        ))
    return out


ALL_ADAPTIVE = {
    "adaptive": adaptive,
}
