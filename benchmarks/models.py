"""The TrainTask axis: real-model P2P training vs the paper's 2NN MLP.

Two registered tasks run the SAME jitted round through ``run_paper_experiment``
(`core/task.py` selects the bundle by ``P2PConfig.model``):

* ``mnist_mlp`` — the paper's 2NN, routed through the task layer.  The task
  layer's contract is that this path is STRUCTURALLY the legacy trainer
  (identity callables, not wrappers), so the benchmark re-derives the legacy
  final state from primitives and gates bit parity as a boolean.
* ``rwkv6_seqmnist`` — RWKV6 in RNN mode on 196-token pixel-stream MNIST, a
  real multi-layer parameter tree (embeddings, layernorms, time/channel
  mixes, LoRA decay projections) under gossip on non-IID label shards.

Rows (``name, us_per_call, derived`` — us measured, derived deterministic):

    models_mnist_mlp_round         us col = wall-clock us/round (vmap),
                                   derived = final mean train loss
    models_mnist_mlp_bit_parity    us col = 0, derived = 1.0 iff the
                                   task-routed trainer's final params are
                                   bit-identical leaf-for-leaf to a
                                   hand-built legacy (bare-callable) driver
    models_rwkv6_vmap_round        us col = wall-clock us/round (K=2 vmap),
                                   derived = final mean train loss
    models_rwkv6_pod_round         us col = wall-clock us/round (K=8 pod,
                                   needs 8 devices), derived = final loss

plus the CI-gated boolean — the claim the task layer exists to deliver:

    models_rwkv6_loss_decreases    us col = first/final loss ratio,
                                   derived = 1.0 iff the rwkv6 fleet's train
                                   loss strictly decreases over the run

All runs are seeded and deterministic; ``benchmarks/compare.py`` gates every
``derived`` against the committed ``BENCH_models.json``.  The pod row needs
the 8 forced host devices — a smaller run emits a SKIPPED row and ``run.py``
refuses to write the file.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.p2pl_mnist import PaperExperiment, noniid_k2, seqmnist_k8
from repro.core import p2p
from repro.data import partition, pipeline, synthetic
from repro.launch.train import run_paper_experiment
from repro.models import mlp


def _legacy_mlp_final_state(exp, data, rounds, *, seed=0):
    """The pre-TrainTask trainer, from primitives: bare ``mlp.*`` callables
    and ``pipeline.PeerBatcher`` under the scan driver."""
    import jax.numpy as jnp

    x_tr, y_tr, _, _ = data
    parts = partition.pathological_partition(
        x_tr, y_tr, list(exp.peer_classes),
        samples_per_class=exp.samples_per_class,
    )
    sizes = partition.data_sizes(parts)
    cfg = exp.p2p
    batcher = pipeline.PeerBatcher(parts, exp.batch_size, seed=seed)
    state = p2p.init_state(
        jax.random.PRNGKey(seed), mlp.init_2nn, cfg, data_sizes=sizes
    )
    drive = p2p.make_scan_driver(mlp.loss_2nn, cfg, data_sizes=sizes)
    for _ in range(rounds):
        bx, by = batcher.round_batches(cfg.local_steps)
        bx = bx.reshape((1, cfg.local_steps) + bx.shape[1:])
        by = by.reshape((1, cfg.local_steps) + by.shape[1:])
        _, state, _ = drive(state, (jnp.asarray(bx), jnp.asarray(by)))
    return state


def _bit_identical(want, got) -> bool:
    wl = jax.tree_util.tree_leaves(want)
    gl = jax.tree_util.tree_leaves(got)
    return len(wl) == len(gl) and all(
        np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(wl, gl)
    )


def _rwkv6_k2(protocol: str = "gossip") -> PaperExperiment:
    """CI-scale rwkv6 fleet: K=2 disjoint 2-class shards, complete graph."""
    return PaperExperiment(
        name=f"models_rwkv6_k2_{protocol}",
        p2p=p2p.P2PConfig(
            algorithm="p2pl",
            num_peers=2,
            local_steps=2,
            consensus_steps=1,
            lr=0.05,
            topology="complete",
            mixing="data_weighted",
            protocol=protocol,
            model="rwkv6_seqmnist",
        ),
        batch_size=8,
        samples_per_class=20,
        peer_classes=((0, 1), (2, 3)),
    )


def models(full=False):
    """Per-round wall-clock + loss trajectory for each registered task."""
    out = []

    # --- mnist_mlp through the task layer, plus the bit-parity boolean -----
    mlp_rounds = 12 if full else 4
    mlp_data = synthetic.mnist_like(4000, 1000)
    exp = noniid_k2(algorithm="p2pl_affinity", local_steps=4)
    t0 = time.time()
    log, state = run_paper_experiment(
        exp, rounds=mlp_rounds, data=mlp_data, return_state=True
    )
    us = (time.time() - t0) / mlp_rounds * 1e6
    out.append((
        "models_mnist_mlp_round", us, float(np.mean(log.train_loss[-1]))
    ))
    legacy = _legacy_mlp_final_state(exp, mlp_data, mlp_rounds)
    out.append((
        "models_mnist_mlp_bit_parity", 0.0,
        1.0 if _bit_identical(legacy.params, state.params) else 0.0,
    ))

    # --- rwkv6_seqmnist, vmap, K=2 at CI scale -----------------------------
    rwkv_rounds = 6 if full else 3
    rwkv_data = synthetic.mnist_like(2000, 300)
    t0 = time.time()
    log = run_paper_experiment(_rwkv6_k2(), rounds=rwkv_rounds, data=rwkv_data)
    us = (time.time() - t0) / rwkv_rounds * 1e6
    losses = np.asarray(log.train_loss, np.float64)
    first, final = float(np.mean(losses[0])), float(np.mean(losses[-1]))
    out.append(("models_rwkv6_vmap_round", us, final))
    out.append((
        "models_rwkv6_loss_decreases",
        first / final,  # us col carries the improvement ratio
        1.0 if final < first else 0.0,
    ))

    # --- rwkv6_seqmnist, pod, K=8 (one device per peer) --------------------
    if jax.device_count() < 8:
        out.append(("models_rwkv6_pod_round_SKIPPED_need_8_devices", 0.0, 0.0))
        return out
    pod_rounds = 4 if full else 2
    exp = seqmnist_k8(local_steps=2)
    t0 = time.time()
    log = run_paper_experiment(
        exp, rounds=pod_rounds, data=rwkv_data, peer_axis="pod",
        eval_every=pod_rounds,
    )
    us = (time.time() - t0) / pod_rounds * 1e6
    out.append((
        "models_rwkv6_pod_round", us, float(np.mean(log.train_loss[-1]))
    ))
    return out


ALL_MODELS = {
    "models": models,
}
