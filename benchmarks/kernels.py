"""Kernel micro-benchmarks: fused vs unfused / chunked vs sequential.

On CPU these time the *interpret-mode* kernels (functional check + rough
op-count proxy) and the pure-jnp fallbacks (the actual CPU execution path);
the structural claim (bytes touched per consensus step) is verified in the
dry-run HLO instead — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6, out  # us


def bench_consensus(n=1 << 20, d=3):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    nbrs = jnp.asarray(rng.normal(size=(d, n)), jnp.float32)
    w_nbr = jnp.full((d,), 0.2, jnp.float32)
    w_self = jnp.asarray(0.4, jnp.float32)
    beta = jnp.full((d,), 1.0 / d, jnp.float32)

    from repro.kernels.consensus_mix import ref as cm_ref

    fused = jax.jit(lambda *a: cm_ref.consensus_mix_ref(*a, 10))

    def unfused(x, nbrs, w_self, w_nbr, beta):
        # two separate passes over the neighbor tensors (what the kernel fuses)
        mixed = w_self * x + jnp.einsum("d,dn->n", w_nbr, nbrs)
        nbr_avg = jnp.einsum("d,dn->n", beta, nbrs)
        return mixed, (nbr_avg - x) / 10

    t_fused, _ = _bench(fused, x, nbrs, w_self, w_nbr, beta)
    t_unfused, _ = _bench(jax.jit(unfused), x, nbrs, w_self, w_nbr, beta)
    return [
        ("consensus_mix_fused_16M", t_fused, t_unfused / max(t_fused, 1e-9)),
        ("consensus_mix_unfused_16M", t_unfused, 1.0),
    ]


def bench_attention(s=512, d=64, h=4):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, h, s, d)), jnp.float32)
    from repro.kernels.flash_attention.ref import attention_ref

    ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t_ref, _ = _bench(ref, q, q, q)
    return [("attention_ref_s512", t_ref, 4 * s * s * d * h / 1e6)]


def bench_wkv6(t=256, h=8, dk=64):
    rng = np.random.default_rng(0)
    shape = (1, t, h, dk)
    r, k, v = (jnp.asarray(rng.normal(size=shape), jnp.float32) for _ in range(3))
    ld = -jnp.asarray(rng.uniform(0.01, 2.0, size=shape), jnp.float32)
    u = jnp.zeros((h, dk), jnp.float32)

    from repro.kernels.rwkv6.ref import wkv6_ref

    seq = jax.jit(lambda *a: wkv6_ref(*a)[0])
    t_seq, _ = _bench(seq, r, k, v, ld, u)
    return [("wkv6_sequential_t256", t_seq, t * h * dk * dk * 2 / 1e6)]


def bench_ssd(t=256, h=8, p=64, n=64):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, t, h, p)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, t, h, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(1, t, h, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 1.0, size=(1, t, h)), jnp.float32)
    a = -jnp.ones((h,), jnp.float32)

    from repro.kernels.mamba2.ref import ssd_ref

    seq = jax.jit(lambda *args: ssd_ref(*args)[0])
    t_seq, _ = _bench(seq, x, b, c, dt, a)
    return [("ssd_sequential_t256", t_seq, t * h * p * n * 2 / 1e6)]


def bench_p2p_round(k=16):
    """Wall time of one full P2PL round, K=16 MLP peers (vmap runtime)."""
    import jax.random as jr

    from repro.core import p2p
    from repro.models import mlp

    cfg = p2p.P2PConfig(algorithm="p2pl_affinity", num_peers=k, local_steps=10,
                        consensus_steps=1, lr=0.01, momentum=0.5, topology="ring")
    state = p2p.init_state(jr.PRNGKey(0), mlp.init_2nn, cfg)
    fn = p2p.make_round_fn(mlp.loss_2nn, cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(10, k, 10, 784)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, size=(10, k, 10)), jnp.int32)
    t_round, _ = _bench(lambda s: fn(s, (x, y))[1].params, state, iters=3)
    return [("p2pl_round_k16_T10", t_round, k * 10)]


ALL_KERNELS = {
    "consensus": bench_consensus,
    "attention": bench_attention,
    "wkv6": bench_wkv6,
    "ssd": bench_ssd,
    "p2p_round": bench_p2p_round,
}
