"""Benchmark orchestrator.  One function per paper figure + kernel micro-
benches.  Prints ``name,us_per_call,derived`` CSV (see figures.py/kernels.py).

    PYTHONPATH=src python -m benchmarks.run              # reduced (CI) scale
    PYTHONPATH=src python -m benchmarks.run --full       # paper scale
    PYTHONPATH=src python -m benchmarks.run --only fig3,consensus
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds/data")
    ap.add_argument("--only", default="", help="comma-separated subset")
    args = ap.parse_args(argv)

    from benchmarks.figures import ALL_FIGURES
    from benchmarks.kernels import ALL_KERNELS
    from benchmarks.schedules import ALL_SCHEDULES

    only = set(args.only.split(",")) if args.only else None
    failures = 0
    print("name,us_per_call,derived")
    for name, fn in {**ALL_KERNELS, **ALL_FIGURES, **ALL_SCHEDULES}.items():
        if only and name not in only:
            continue
        try:
            out = fn(args.full) if name not in ALL_KERNELS else fn()
            for row_name, us, derived in out:
                print(f"{row_name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,0", flush=True)
            traceback.print_exc(limit=5, file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
