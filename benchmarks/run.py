"""Benchmark orchestrator.  One function per paper figure + kernel micro-
benches.  Prints ``name,us_per_call,derived`` CSV (see figures.py/kernels.py)
and serializes the consensus-protocol rows to ``BENCH_protocols.json`` so the
per-protocol perf trajectory (spectral gap, consensus error, wall-clock per
round) accumulates across PRs.

    PYTHONPATH=src python -m benchmarks.run              # reduced (CI) scale
    PYTHONPATH=src python -m benchmarks.run --full       # paper scale
    PYTHONPATH=src python -m benchmarks.run --only fig3,consensus
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds/data")
    ap.add_argument("--only", default="", help="comma-separated subset")
    ap.add_argument("--json-out", default="BENCH_protocols.json",
                    help="where to write the protocol benchmark rows "
                         "('' disables)")
    args = ap.parse_args(argv)

    from benchmarks.figures import ALL_FIGURES
    from benchmarks.kernels import ALL_KERNELS
    from benchmarks.peer_axis import ALL_PEER_AXIS
    from benchmarks.protocols import ALL_PROTOCOLS
    from benchmarks.schedules import ALL_SCHEDULES

    only = set(args.only.split(",")) if args.only else None
    failures = 0
    protocol_rows = []
    print("name,us_per_call,derived")
    for name, fn in {**ALL_KERNELS, **ALL_FIGURES, **ALL_SCHEDULES,
                     **ALL_PROTOCOLS, **ALL_PEER_AXIS}.items():
        if only and name not in only:
            continue
        try:
            out = fn(args.full) if name not in ALL_KERNELS else fn()
            for row_name, us, derived in out:
                print(f"{row_name},{us:.1f},{derived}", flush=True)
            if name in ALL_PROTOCOLS:
                protocol_rows += [
                    {"name": row_name, "us_per_call": round(us, 1), "derived": derived}
                    for row_name, us, derived in out
                ]
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,0", flush=True)
            traceback.print_exc(limit=5, file=sys.stderr)
    if args.json_out:
        if protocol_rows:
            with open(args.json_out, "w") as f:
                json.dump({"rows": protocol_rows}, f, indent=2)
            print(f"wrote {args.json_out} ({len(protocol_rows)} rows)", file=sys.stderr)
        else:
            print(f"NOT writing {args.json_out}: only proto_* benchmarks "
                  "serialize rows and none were selected", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
