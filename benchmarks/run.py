"""Benchmark orchestrator.  One function per paper figure + kernel micro-
benches.  Prints ``name,us_per_call,derived`` CSV (see figures.py/kernels.py)
and serializes the consensus-protocol rows to ``BENCH_protocols.json``, the
round-loop driver rows to ``BENCH_roundloop.json``, the adaptive
partner-selection rows to ``BENCH_adaptive.json``, the K-scaling rows to
``BENCH_scaling.json``, the compression Pareto rows to
``BENCH_compression.json``, the sync-vs-async straggler rows to
``BENCH_straggler.json``, the stacked-fleet serving rows to
``BENCH_serving.json``, and the TrainTask real-model rows to
``BENCH_models.json`` so the perf trajectories (spectral gap, consensus
error, wall-clock per round, scan-vs-python speedup, oscillation damping,
sub-quadratic K-scaling, bytes-vs-accuracy compression, async
wall-clock-to-accuracy, stacked-vs-sequential serving throughput, the
personalized-vs-consensus accuracy A/B, and the real-model per-round cost
and loss trajectory) accumulate across PRs.  See benchmarks/README.md for the
file contract.  ``--only`` with an unknown name errors out listing the
registry (a typo used to silently run nothing).

    PYTHONPATH=src python -m benchmarks.run              # reduced (CI) scale
    PYTHONPATH=src python -m benchmarks.run --full       # paper scale
    PYTHONPATH=src python -m benchmarks.run --only fig3,consensus
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def _write_rows(path: str, rows: list[dict], what: str) -> None:
    if rows:
        with open(path, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"wrote {path} ({len(rows)} rows)", file=sys.stderr)
    else:
        print(f"NOT writing {path}: only {what} benchmarks serialize these "
              "rows and none were selected", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds/data")
    ap.add_argument("--only", default="", help="comma-separated subset")
    ap.add_argument("--json-out", default="BENCH_protocols.json",
                    help="where to write the protocol benchmark rows "
                         "('' disables)")
    ap.add_argument("--roundloop-json-out", default="BENCH_roundloop.json",
                    help="where to write the round-loop driver benchmark rows "
                         "('' disables)")
    ap.add_argument("--adaptive-json-out", default="BENCH_adaptive.json",
                    help="where to write the adaptive partner-selection "
                         "benchmark rows ('' disables)")
    ap.add_argument("--scaling-json-out", default="BENCH_scaling.json",
                    help="where to write the K-scaling benchmark rows "
                         "('' disables)")
    ap.add_argument("--compression-json-out", default="BENCH_compression.json",
                    help="where to write the compression Pareto benchmark "
                         "rows ('' disables)")
    ap.add_argument("--straggler-json-out", default="BENCH_straggler.json",
                    help="where to write the sync-vs-async straggler "
                         "benchmark rows ('' disables)")
    ap.add_argument("--serving-json-out", default="BENCH_serving.json",
                    help="where to write the stacked-fleet serving "
                         "benchmark rows ('' disables)")
    ap.add_argument("--models-json-out", default="BENCH_models.json",
                    help="where to write the TrainTask real-model "
                         "benchmark rows ('' disables)")
    args = ap.parse_args(argv)

    from benchmarks.adaptive import ALL_ADAPTIVE
    from benchmarks.figures import ALL_FIGURES
    from benchmarks.kernels import ALL_KERNELS
    from benchmarks.models import ALL_MODELS
    from benchmarks.peer_axis import ALL_PEER_AXIS
    from benchmarks.protocols import ALL_COMPRESSION, ALL_PROTOCOLS
    from benchmarks.roundloop import ALL_ROUNDLOOP, ALL_SCALING
    from benchmarks.schedules import ALL_SCHEDULES
    from benchmarks.serving import ALL_SERVING
    from benchmarks.straggler import ALL_STRAGGLER

    benches = {**ALL_KERNELS, **ALL_FIGURES, **ALL_SCHEDULES, **ALL_PROTOCOLS,
               **ALL_PEER_AXIS, **ALL_ROUNDLOOP, **ALL_ADAPTIVE,
               **ALL_SCALING, **ALL_COMPRESSION, **ALL_STRAGGLER,
               **ALL_SERVING, **ALL_MODELS}
    only = set(args.only.split(",")) if args.only else None
    if only:
        # a typo'd --only used to silently run NOTHING (and exit 0) — fail
        # loudly with the registry instead
        unknown = sorted(only - set(benches))
        if unknown:
            ap.error(
                f"unknown benchmark name(s) {', '.join(unknown)}; "
                f"known: {', '.join(sorted(benches))}"
            )
    failures = 0
    protocol_rows = []
    roundloop_rows = []
    adaptive_rows = []
    scaling_rows = []
    compression_rows = []
    straggler_rows = []
    serving_rows = []
    models_rows = []
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            out = fn(args.full) if name not in ALL_KERNELS else fn()
            for row_name, us, derived in out:
                print(f"{row_name},{us:.1f},{derived}", flush=True)
            rows = [
                {"name": row_name, "us_per_call": round(us, 1), "derived": derived}
                for row_name, us, derived in out
            ]
            if name in ALL_PROTOCOLS:
                protocol_rows += rows
            if name in ALL_ROUNDLOOP:
                roundloop_rows += rows
            if name in ALL_ADAPTIVE:
                adaptive_rows += rows
            if name in ALL_SCALING:
                scaling_rows += rows
            if name in ALL_COMPRESSION:
                compression_rows += rows
            if name in ALL_STRAGGLER:
                straggler_rows += rows
            if name in ALL_SERVING:
                serving_rows += rows
            if name in ALL_MODELS:
                models_rows += rows
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,0", flush=True)
            traceback.print_exc(limit=5, file=sys.stderr)
    if args.json_out:
        _write_rows(args.json_out, protocol_rows, "proto_*")
    if args.roundloop_json_out:
        if any("SKIPPED" in row["name"] for row in roundloop_rows):
            # a <8-device run has no pod rows: writing it would clobber a
            # committed baseline with a file the CI gate can never match
            print(f"NOT writing {args.roundloop_json_out}: pod rows were "
                  "SKIPPED (need 8 devices — set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=8)", file=sys.stderr)
        else:
            _write_rows(args.roundloop_json_out, roundloop_rows, "roundloop")
    if args.adaptive_json_out:
        _write_rows(args.adaptive_json_out, adaptive_rows, "adaptive")
    if args.scaling_json_out:
        if any("SKIPPED" in row["name"] for row in scaling_rows):
            # a <8-device run has no scaling cells: writing it would clobber
            # a committed baseline with a file the CI gate can never match
            print(f"NOT writing {args.scaling_json_out}: scaling rows were "
                  "SKIPPED (need 8 devices — set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=8)", file=sys.stderr)
        else:
            _write_rows(args.scaling_json_out, scaling_rows, "scaling")
    if args.compression_json_out:
        _write_rows(args.compression_json_out, compression_rows, "compression")
    if args.straggler_json_out:
        _write_rows(args.straggler_json_out, straggler_rows, "straggler")
    if args.serving_json_out:
        if any("SKIPPED" in row["name"] for row in serving_rows):
            # a <8-device run has no pod rows: writing it would clobber a
            # committed baseline with a file the CI gate can never match
            print(f"NOT writing {args.serving_json_out}: pod rows were "
                  "SKIPPED (need 8 devices — set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=8)", file=sys.stderr)
        else:
            _write_rows(args.serving_json_out, serving_rows, "serving")
    if args.models_json_out:
        if any("SKIPPED" in row["name"] for row in models_rows):
            # a <8-device run has no pod rows: writing it would clobber a
            # committed baseline with a file the CI gate can never match
            print(f"NOT writing {args.models_json_out}: the rwkv6 pod row was "
                  "SKIPPED (need 8 devices — set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=8)", file=sys.stderr)
        else:
            _write_rows(args.models_json_out, models_rows, "models")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
