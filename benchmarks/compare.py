"""Compare a fresh BENCH_protocols.json against the committed baseline.

CI's bench-smoke job runs ``benchmarks.run`` at CI scale, then gates on this
script: the ``derived`` metrics (spectral gap, consensus error, bias — all
seeded and deterministic up to platform ulp noise) must match the committed
baseline within tolerance.  ``us_per_call`` is machine-dependent and is never
compared; it is carried in the uploaded artifact for the perf trajectory.

    python -m benchmarks.compare BENCH_protocols.json fresh.json
    python -m benchmarks.compare baseline.json fresh.json --rtol 0.05 --atol 1e-4

Exit 0 when every shared row agrees and no baseline row is missing; exit 1
otherwise, listing each offender.  Rows only present in the fresh file (new
benchmarks landing in this PR) are reported but do not fail the gate — they
become baseline when the fresh JSON is committed.
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    return {row["name"]: row for row in data["rows"]}


def compare(
    baseline: dict[str, dict],
    fresh: dict[str, dict],
    *,
    rtol: float,
    atol: float,
) -> list[str]:
    problems = []
    for name, want in sorted(baseline.items()):
        if name not in fresh:
            problems.append(f"MISSING row {name!r} (in baseline, not in fresh run)")
            continue
        a, b = float(want["derived"]), float(fresh[name]["derived"])
        if not math.isclose(b, a, rel_tol=rtol, abs_tol=atol):
            problems.append(
                f"DRIFT {name}: derived {b:.6g} vs baseline {a:.6g} "
                f"(|diff| {abs(b - a):.3g} > rtol={rtol} / atol={atol})"
            )
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_protocols.json")
    ap.add_argument("fresh", help="freshly produced JSON to check")
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="relative tolerance on 'derived' (default 5%%: covers "
                         "cross-platform f32 reduction noise, catches real "
                         "regressions in gap/error/bias)")
    ap.add_argument("--atol", type=float, default=1e-4,
                    help="absolute floor for derived values near zero "
                         "(push-sum biases are ~1e-7)")
    args = ap.parse_args(argv)

    baseline = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    problems = compare(baseline, fresh, rtol=args.rtol, atol=args.atol)
    new_rows = sorted(set(fresh) - set(baseline))
    for name in new_rows:
        print(f"NEW row {name} (not in baseline — will gate once committed)")
    if problems:
        print(f"{len(problems)} problem(s) vs {args.baseline}:")
        for p in problems:
            print(" ", p)
        return 1
    print(f"OK: {len(baseline)} baseline rows matched within "
          f"rtol={args.rtol}, atol={args.atol} ({len(new_rows)} new)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
