"""The serving axis: one stacked K-model decode vs K sequential calls.

Two claims, both CI-gated:

* **Throughput** — serving the fleet's K personalized models through ONE
  stacked vmap call (traced ``peer_ids`` routing + fused prefill/scan decode,
  ``repro/launch/serve.py``) beats the naive baseline of K separate
  ``serve_batch``-style serves — per-peer prefill dispatch plus the
  per-token python decode loop, i.e. the serving path as it existed before
  the scanned/stacked rewrite — on the same {K models x B requests x gen
  tokens} workload.  Both sides reuse their compiled steps across peers and
  calls, so the gated win is dispatch fusion + fleet batching, not a
  compile-count artifact.  ``serving_fused_seq_k8`` decomposes the win: K
  *sequential* calls of the fused prefill+scan generate, isolating how much
  the scan fusion alone buys before stacking (on a single-core host the
  sequential fused path can even edge out the stacked call — batching only
  pays where there is parallel hardware — which is why the gate compares
  against the real pre-rewrite baseline, and why the fused row is
  informational rather than gated).
* **Personalization** — the K divergent models are worth serving: per-peer
  test accuracy on held-out non-IID shards (``data/partition.py``
  class-partitioned TEST split) of the trained personalized stack beats the
  consensus-averaged single model routed through the identical serving path.

Rows (``name, us_per_call, derived`` — us measured, derived deterministic):

    serving_naive_seq_k8       us col = us per generated token (K sequential
                               legacy per-token-loop serves), derived = mean
    serving_fused_seq_k8       token id over the (K, B, gen) output —
    serving_stacked_vmap_k8    identical for all four variants by
    serving_stacked_pod_k8     construction; pod = the same stacked call with
                               one model replica per device (needs 8 devices;
                               a smaller run emits a SKIPPED row and no JSON
                               is written)
    serving_personalized_acc   us col = training us/round of the CI-scale
    serving_consensus_acc      straggler_k8 run, derived = mean per-peer
                               held-out-shard accuracy

plus the CI-gated booleans — the claims this subsystem exists to deliver:

    serving_stacked_speedup            us col = naive/stacked us ratio,
                                       derived = 1.0 iff stacked strictly
                                       faster per token
    serving_stacked_matches_naive      derived = 1.0 iff stacked tokens ==
                                       naive tokens, bit for bit
    serving_pod_matches_vmap           derived = 1.0 iff pod tokens == vmap
                                       tokens, bit for bit (8-device runs)
    personalized_beats_consensus_acc   us col = personalized/consensus
                                       accuracy ratio, derived = 1.0 iff
                                       personalized strictly higher

All derived values are seeded and deterministic; ``benchmarks/compare.py``
gates them against the committed ``BENCH_serving.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import median_us
from repro.configs import get_config, reduced
from repro.configs.p2pl_mnist import straggler_k8
from repro.core import p2p
from repro.data import partition, synthetic
from repro.launch import serve as serve_lib
from repro.launch import steps as steps_lib
from repro.launch.train import run_paper_experiment
from repro.models import build_model, mlp

ARCH = "smollm-135m"
K = 8


def _mean_token(tokens) -> float:
    """Deterministic check value: mean token id of the greedy output."""
    return float(np.asarray(tokens, np.float64).mean())


def _throughput_rows(full: bool) -> list:
    batch = 8 if full else 4
    prompt_len = 16
    gen_tokens = 16 if full else 8
    trials = 5 if full else 3
    model = build_model(reduced(get_config(ARCH)))
    max_len = prompt_len + gen_tokens

    stacked_params = jax.vmap(model.init)(
        jax.random.split(jax.random.PRNGKey(0), K)
    )
    prompts = jax.vmap(lambda k: model.make_batch(k, batch, prompt_len))(
        jax.random.split(jax.random.PRNGKey(1), K)
    )
    peer_ids = jnp.arange(K, dtype=jnp.int32)
    params_rows = [jax.tree.map(lambda p, k=k: p[k], stacked_params) for k in range(K)]
    prompt_rows = [jax.tree.map(lambda p, k=k: p[k], prompts) for k in range(K)]

    prefill = jax.jit(steps_lib.make_prefill_step(model))
    serve = jax.jit(steps_lib.make_serve_step(model))
    single = jax.jit(steps_lib.make_generate_fn(model, gen_tokens))
    fleet = jax.jit(
        serve_lib.make_fleet_generate_fn(model, gen_tokens), donate_argnums=(2,)
    )
    tokens_per_call = K * batch * gen_tokens

    # fresh caches are built INSIDE the timed region on all sides — cache
    # setup is part of serving a request batch, and the donated fleet cache
    # is consumed per call anyway
    def naive_step(_):
        # the pre-rewrite serving path: K separate serve_batch-style serves,
        # each a prefill dispatch + one python-loop dispatch per token
        out = []
        for k in range(K):
            cache = model.init_cache(batch, max_len)
            tok, cache = prefill(params_rows[k], prompt_rows[k], cache)
            pos = jnp.full(
                (batch,), steps_lib.prompt_dec_len(prompt_rows[k]), jnp.int32
            )
            toks = [tok]
            for _ in range(gen_tokens - 1):
                tok, pos, cache = serve(params_rows[k], cache, tok, pos)
                toks.append(tok)
            out.append(jnp.stack(toks, axis=1))
        return jnp.stack(out)

    def fused_seq_step(_):
        out = []
        for k in range(K):
            toks, _ = single(
                params_rows[k], prompt_rows[k], model.init_cache(batch, max_len)
            )
            out.append(toks)
        return jnp.stack(out)

    def stacked_step(_):
        toks, _ = fleet(
            stacked_params,
            prompts,
            serve_lib.stack_request_caches(model.init_cache(batch, max_len), K),
            peer_ids,
        )
        return toks

    naive_us, naive_toks = median_us(naive_step, None, calls=2, trials=trials)
    fused_us, fused_toks = median_us(fused_seq_step, None, calls=2, trials=trials)
    stacked_us, stacked_toks = median_us(stacked_step, None, calls=2, trials=trials)
    naive_us /= tokens_per_call
    fused_us /= tokens_per_call
    stacked_us /= tokens_per_call

    match = bool(
        np.array_equal(np.asarray(naive_toks), np.asarray(stacked_toks))
        and np.array_equal(np.asarray(fused_toks), np.asarray(stacked_toks))
    )
    out = [
        ("serving_naive_seq_k8", naive_us, _mean_token(naive_toks)),
        ("serving_fused_seq_k8", fused_us, _mean_token(fused_toks)),
        ("serving_stacked_vmap_k8", stacked_us, _mean_token(stacked_toks)),
        ("serving_stacked_matches_naive", 1.0 if match else 0.0, 1.0 if match else 0.0),
        (
            "serving_stacked_speedup",
            naive_us / stacked_us,  # us col carries the speedup ratio
            1.0 if stacked_us < naive_us else 0.0,
        ),
    ]

    if jax.device_count() >= K:
        from repro.launch import mesh as mesh_lib
        from repro.sharding import specs as specs_lib

        mesh = mesh_lib.make_peer_mesh(K)
        params_pod = specs_lib.shard_peer_tree(stacked_params, mesh)
        prompts_pod = specs_lib.shard_peer_tree(prompts, mesh)
        ids_pod = specs_lib.shard_peer_tree(peer_ids, mesh)

        def pod_step(_):
            caches = specs_lib.shard_peer_tree(
                serve_lib.stack_request_caches(model.init_cache(batch, max_len), K),
                mesh,
            )
            toks, _ = fleet(params_pod, prompts_pod, caches, ids_pod)
            return toks

        pod_us, pod_toks = median_us(pod_step, None, calls=2, trials=trials)
        pod_us /= tokens_per_call
        pod_match = bool(
            np.array_equal(np.asarray(pod_toks), np.asarray(stacked_toks))
        )
        out.append(("serving_stacked_pod_k8", pod_us, _mean_token(pod_toks)))
        out.append((
            "serving_pod_matches_vmap",
            1.0 if pod_match else 0.0,
            1.0 if pod_match else 0.0,
        ))
    else:
        # the run.py guard refuses to write a baseline missing the pod rows
        out.append(("serving_pod_SKIPPED_need_8_devices", 0.0, 0.0))
    return out


def _personalization_rows(full: bool) -> list:
    rounds = 40 if full else 12
    data = synthetic.mnist_like(20000 if full else 6000, 5000 if full else 1500)
    exp = straggler_k8()
    t0 = time.time()
    _, state = run_paper_experiment(exp, rounds=rounds, data=data, return_state=True)
    train_us = (time.time() - t0) / rounds * 1e6

    # held-out per-peer shards: the TEST split class-partitioned exactly like
    # each peer's training data (all test samples of its classes), truncated
    # to the smallest shard so the groups stack into one fleet call
    x_tr, y_tr, x_te, y_te = data
    shards = partition.pathological_partition(x_te, y_te, list(exp.peer_classes))
    n_min = min(len(sx) for sx, _ in shards)
    images = jnp.stack([sx[:n_min] for sx, _ in shards])  # (K, n, 784)
    labels = np.stack([sy[:n_min] for _, sy in shards])

    personalized = p2p.serving_params(state)
    sizes = partition.data_sizes(
        partition.pathological_partition(
            x_tr, y_tr, list(exp.peer_classes),
            samples_per_class=exp.samples_per_class,
        )
    )
    averaged = p2p.consensus_averaged_params(personalized, data_sizes=sizes)

    classify = jax.jit(serve_lib.make_fleet_classify_fn(mlp.apply_2nn))
    peer_ids = jnp.arange(exp.p2p.num_peers, dtype=jnp.int32)

    def fleet_acc(params) -> float:
        pred = np.asarray(jnp.argmax(classify(params, images, peer_ids), -1))
        return float((pred == labels).mean())

    acc_pers = fleet_acc(personalized)
    acc_cons = fleet_acc(averaged)
    return [
        ("serving_personalized_acc", train_us, acc_pers),
        ("serving_consensus_acc", train_us, acc_cons),
        (
            "personalized_beats_consensus_acc",
            acc_pers / max(acc_cons, 1e-9),  # us col carries the acc ratio
            1.0 if acc_pers > acc_cons else 0.0,
        ),
    ]


def serving(full=False):
    """Stacked-fleet throughput + personalized-vs-consensus accuracy A/B."""
    return _throughput_rows(full) + _personalization_rows(full)


ALL_SERVING = {
    "serving": serving,
}
