"""One benchmark per paper figure (Figs. 2-6).

Each returns (name, seconds_per_round, derived) where `derived` is the
figure's headline quantity.  `full=False` runs a reduced-round version for
the CI-style `python -m benchmarks.run`; EXPERIMENTS.md uses `full=True`.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs.p2pl_mnist import PaperExperiment, iid_k100, noniid_k2
from repro.core.p2p import P2PConfig
from repro.data import synthetic
from repro.launch.train import run_paper_experiment

_DATA = {}


def _data(full):
    key = bool(full)
    if key not in _DATA:
        _DATA[key] = synthetic.mnist_like(60000 if full else 8000, 10000 if full else 2000)
    return _DATA[key]


def _timed(exp, rounds, data, eval_every=1):
    t0 = time.time()
    log = run_paper_experiment(exp, rounds=rounds, data=data, eval_every=eval_every)
    return log, (time.time() - t0) / rounds * 1e6  # us per round


def _dev0(log, group, phase="consensus"):
    """Device A's series for a class group (peers are task-symmetric; the
    paper plots device A)."""
    src = log.after_consensus if phase == "consensus" else log.after_local
    return np.stack(src[group])[:, 0]


def _dev0_osc(log, group):
    return float(np.abs(_dev0(log, group, "consensus") - _dev0(log, group, "local")).mean())


def fig2_iid_convergence(full=False, topology="ring"):
    """Fig. 2: K=100 IID P2PL — accuracy after both phases; rounds to 90%."""
    exp = iid_k100(topology=topology)
    if not full:
        exp = dataclasses.replace(
            exp,
            p2p=dataclasses.replace(exp.p2p, num_peers=16, local_steps=20),
            rounds=10,
        )
    rounds = exp.rounds if full else 10
    # K=100 evals are the bottleneck on CPU: evaluate every 5th round at
    # full scale (the paper's curves are smooth at this resolution)
    log, spr = _timed(exp, rounds, _data(full), eval_every=5 if full else 1)
    acc = log.final_accuracy("all")
    osc = log.mean_oscillation("all")
    return [
        (f"fig2_iid_{topology}_final_acc", spr, acc),
        (f"fig2_iid_{topology}_oscillation", spr, osc),
        (f"fig2_iid_{topology}_rounds_to_90", spr, log.rounds_to_accuracy("all", 0.90)),
    ]


def fig3_noniid_oscillation(full=False):
    """Fig. 3cd: K=2 pathological non-IID — forgetting + consensus recovery."""
    rounds = 60 if full else 12
    log, spr = _timed(noniid_k2(algorithm="local_dsgd", local_steps=10),
                      rounds, _data(full))
    unseen_osc = _dev0_osc(log, "peer1_seen")  # device A's unseen classes
    seen_osc = _dev0_osc(log, "peer0_seen")
    worst = float(
        np.abs(_dev0(log, "peer1_seen", "consensus") - _dev0(log, "peer1_seen", "local")).max()
    )
    return [
        ("fig3_unseen_oscillation", spr, unseen_osc),
        ("fig3_seen_oscillation", spr, seen_osc),
        ("fig3_worst_unseen_swing", spr, worst),
        ("fig3_min_unseen_after_local", spr, float(_dev0(log, "peer1_seen", "local").min())),
    ]


def fig4_local_steps(full=False):
    """Fig. 4: oscillation amplitude vs. number of local steps T."""
    rounds = 60 if full else 12
    out = []
    for t in (1, 5, 10):
        algo = "dsgd" if t == 1 else "local_dsgd"
        # equal GRADIENT ITERATIONS across T (the paper's x-axis), so DSGD
        # runs rounds*10 single-step rounds
        r = rounds * (10 // t)
        log, spr = _timed(noniid_k2(algorithm=algo, local_steps=t), r, _data(full))
        out.append((f"fig4_T{t}_unseen_oscillation", spr, _dev0_osc(log, "peer1_seen")))
        out.append((f"fig4_T{t}_final_all_acc", spr, log.final_accuracy("all")))
    return out


def fig5_task_complexity(full=False):
    """Fig. 5: 4-class vs 10-class task — harder tasks oscillate more."""
    rounds = 60 if full else 12
    out = []
    for name, classes_a, classes_b in (
        ("4class", (0, 1), (7, 8)),
        ("10class", (0, 1, 2, 3, 4), (5, 6, 7, 8, 9)),
    ):
        exp = noniid_k2(algorithm="local_dsgd", local_steps=10)
        exp = dataclasses.replace(
            exp, peer_classes=(classes_a, classes_b), samples_per_class=None if full else 100
        )
        if full:
            # the paper's Fig. 5 convention: batch size such that T=10
            # iterations = one epoch (B = n_k / 10)
            n_k = 6000 * len(classes_a)
            exp = dataclasses.replace(exp, batch_size=n_k // 10)
        log, spr = _timed(exp, rounds, _data(full))
        out.append((f"fig5_{name}_unseen_oscillation", spr, _dev0_osc(log, "peer1_seen")))
        out.append((f"fig5_{name}_unseen_final", spr,
                    float(_dev0(log, "peer1_seen", "consensus")[-5:].mean())))
    return out


def fig6_affinity_damping(full=False):
    """Fig. 6: P2PL with Affinity vs local DSGD vs DSGD vs isolated."""
    rounds = 60 if full else 12
    data = _data(full)
    out = []
    logs = {}
    for algo, t in (("local_dsgd", 10), ("p2pl_affinity", 10), ("dsgd", 1), ("isolated", 10)):
        exp = noniid_k2(algorithm=algo, local_steps=t)
        exp = dataclasses.replace(
            exp,
            peer_classes=((0, 1, 2, 3, 4), (5, 6, 7, 8, 9)),
            samples_per_class=None if full else 100,
        )
        if algo == "p2pl_affinity":
            # eta_d = 0.5, not the paper's 1.0: with K=2 fully-averaging
            # consensus, eta_d=1 re-injects the entire pre-consensus drift
            # each round and diverges (observation O1 in EXPERIMENTS.md)
            exp = dataclasses.replace(exp, p2p=dataclasses.replace(exp.p2p, eta_d=0.5))
        if full:
            exp = dataclasses.replace(exp, batch_size=3000)  # n_k/10, Fig. 5/6 convention
        r = rounds * (10 // t)  # equal gradient iterations
        log, spr = _timed(exp, r, data)
        logs[algo] = log
        if algo == "isolated":
            # device A never sees classes 5-9: unseen accuracy stays ~0
            out.append((f"fig6_{algo}_unseen_acc", spr,
                        float(_dev0(log, "peer1_seen", "local")[-5:].mean())))
        else:
            out.append((f"fig6_{algo}_unseen_oscillation", spr,
                        _dev0_osc(log, "peer1_seen")))
            out.append((f"fig6_{algo}_unseen_final_acc", spr,
                        float(_dev0(log, "peer1_seen", "consensus")[-5:].mean())))
    damp = (_dev0_osc(logs["local_dsgd"], "peer1_seen")
            - _dev0_osc(logs["p2pl_affinity"], "peer1_seen"))
    out.append(("fig6_affinity_damping_delta", 0.0, damp))
    return out


ALL_FIGURES = {
    "fig2": fig2_iid_convergence,
    "fig3": fig3_noniid_oscillation,
    "fig4": fig4_local_steps,
    "fig5": fig5_task_complexity,
    "fig6": fig6_affinity_damping,
}
