"""Shared wall-clock measurement discipline for the benchmark suite.

Two historical bugs this module exists to prevent:

* **Async dispatch skew** — jax dispatches asynchronously, so a timestamp
  taken without a ``block_until_ready()`` immediately before it measures
  enqueue time, not execution time; worse, work left in flight from warmup
  (or a previous trial) bleeds into the timed region.  ``median_us`` blocks
  on the carried value before BOTH the start and the stop timestamp.
* **Single-trial noise** — one trial on a shared CI runner is dominated by
  scheduler jitter; a median over several trials is stable enough to commit
  to a BENCH_*.json and diff across PRs.
"""
from __future__ import annotations

import time
from typing import Any, Callable, TypeVar

import jax
import numpy as np

T = TypeVar("T")


def median_us(
    step: Callable[[T], T],
    carry: T,
    *,
    calls: int,
    trials: int,
    warmup: int = 1,
) -> tuple[float, T]:
    """Median-of-``trials`` microseconds per ``step`` call.

    ``step`` maps a carried value (e.g. a training state) to its successor;
    each trial times ``calls`` sequential steps.  The carry is blocked on
    before the start timestamp (so no earlier work bleeds in) and before the
    stop timestamp (so the timed work has actually finished).  Returns
    ``(us_per_call, final_carry)`` — the carry keeps evolving across trials,
    which is fine for steady-state timing and lets callers derive check
    values from a deterministic total call count.
    """
    for _ in range(warmup):
        carry = step(carry)
    samples = []
    for _ in range(trials):
        carry = jax.block_until_ready(carry)
        t0 = time.perf_counter()
        for _ in range(calls):
            carry = step(carry)
        carry = jax.block_until_ready(carry)
        samples.append((time.perf_counter() - t0) / calls * 1e6)
    return float(np.median(samples)), carry
