"""The asynchrony axis: bounded-staleness gossip vs slowest-peer-bound sync.

The straggler_k8 fleet (8 non-IID peers, 2 classes each, ring) has a
heterogeneous compute profile: the last quarter of the peers is
``straggler_period`` (=4) times slower.  A synchronous round cannot finish
before its slowest member, so its wall-clock is slowest-peer-bound; the
bounded-staleness async round lets fast peers proceed on the stragglers'
last *published* snapshots (age-decayed, renormalized — ``core/p2p.py``),
overlapping the stragglers' compute with the fleet's progress.

Wall-clock model (dimensionless units; one unit = one fast-peer local step):

    sync  round = T * max_k(period_k)        every peer runs all T steps,
                                             the fleet waits for the slowest
    async round = T * max(1, p / (bound+1))  fast peers never wait while the
                                             bound covers the straggler
                                             period; a too-tight bound stalls
                                             the fleet at forced delivery

Both variants get the SAME total wall-clock budget — the async variant runs
``max_k(period_k)`` times more rounds because its rounds are that much
cheaper.  That is the comparison the async subsystem exists to win: more
(slightly degraded) rounds per unit time beat fewer slowest-peer-bound ones.

Rows (``name, us_per_call, derived`` — us measured, derived deterministic):

    straggler_{sync,async}_final_acc       us col = wall-clock us/round,
                                           derived = final all-class accuracy
                                           at the SHARED wall-clock budget
    straggler_{sync,async}_round_units     derived = modeled units per round
    straggler_{sync,async}_wall_to_target  derived = modeled units until
                                           min-over-peers accuracy crosses
                                           the target (0.9 x the sync
                                           baseline's final floor accuracy)

plus the CI-gated boolean — the claim the async subsystem exists to deliver:

    straggler_async_beats_sync   us col = wall-clock ratio (sync / async),
                                 derived = 1.0 iff async reaches the target
                                 accuracy in LESS modeled wall-clock than
                                 the synchronous baseline

All runs are seeded and deterministic; ``benchmarks/compare.py`` gates every
``derived`` against the committed ``BENCH_straggler.json``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.p2pl_mnist import straggler_k8
from repro.core.p2p import compute_profile
from repro.data import synthetic
from repro.launch.train import run_paper_experiment

# (variant label, steps_profile, staleness_bound)
VARIANTS = (
    ("sync", "uniform", 0),
    ("async", "straggler", 3),
)


def _floor_acc(log):
    """Min-over-peers final accuracy (the metric rounds_to_accuracy floors)."""
    s = log.series("all")
    s = s.min(axis=tuple(range(1, s.ndim))) if s.ndim > 1 else s
    return float(s[-5:].mean())


def straggler(full=False):
    """Sync-vs-async wall-clock-to-accuracy on the heterogeneous fleet."""
    sync_rounds = 40 if full else 16
    data = synthetic.mnist_like(20000 if full else 6000, 5000 if full else 1500)
    # the fleet's PHYSICAL heterogeneity is the same in both variants (same
    # hardware, different scheduling): read it off the straggler profile
    _, period = compute_profile(straggler_k8().p2p)
    max_p = int(period.max())
    runs = {}
    out = []
    for name, profile, bound in VARIANTS:
        exp = straggler_k8(steps_profile=profile, staleness_bound=bound)
        cfg = exp.p2p
        if profile == "uniform":
            # synchronous: every peer runs all T steps at its own speed, the
            # round closes when the slowest (1/max_p speed) peer finishes
            round_units = float(cfg.local_steps * max_p)
            rounds = sync_rounds
        else:
            round_units = cfg.local_steps * max(1.0, max_p / (bound + 1))
            # same total wall-clock budget as the sync baseline
            rounds = int(round(sync_rounds * cfg.local_steps * max_p / round_units))
        t0 = time.time()
        log = run_paper_experiment(exp, rounds=rounds, data=data)
        us = (time.time() - t0) / rounds * 1e6
        runs[name] = (log, round_units, rounds, us)
        out.append((f"straggler_{name}_final_acc", us, log.final_accuracy("all")))
        out.append((f"straggler_{name}_round_units", us, round_units))

    # target: 90% of the SYNC baseline's final floor accuracy — a level the
    # stronger-per-round variant certifiably reaches, so the boolean measures
    # wall-clock, not reachability
    target = 0.9 * _floor_acc(runs["sync"][0])
    walls = {}
    for name, (log, round_units, rounds, us) in runs.items():
        r = log.rounds_to_accuracy("all", target)
        # -1 = never reached inside the budget: charge the full budget (the
        # gate then fails loudly instead of dividing by a fictitious win)
        walls[name] = ((r if r >= 0 else rounds - 1) + 1) * round_units
        out.append((f"straggler_{name}_wall_to_target", us, float(walls[name])))
    out.append((
        "straggler_async_beats_sync",
        walls["sync"] / walls["async"],  # us col carries the speedup ratio
        1.0 if walls["async"] < walls["sync"] else 0.0,
    ))
    return out


ALL_STRAGGLER = {
    "straggler": straggler,
}
