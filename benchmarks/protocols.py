"""The protocol axis: gossip vs push-sum on symmetric and directed graphs.

One row per (protocol/topology, metric):
- spectral gap of the per-round mixing matrix (row-stochastic W for gossip,
  column-stochastic A for push-sum) — the consensus rate actually available,
- consensus error of the DE-BIASED estimates after one period of pure mixing
  from a common random start (push-sum divides by the carried mass; gossip's
  estimates are its raw parameters),
- bias of the consensus point vs the data-weighted average — the number that
  indicts row-stochastic gossip on directed graphs and exonerates push-sum,
- wall-clock per mix step (us).

``benchmarks/run.py`` additionally serializes these rows to
``BENCH_protocols.json`` so the per-protocol perf trajectory accumulates
across PRs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import median_us
from repro.core import consensus as consensus_lib
from repro.core import graph as graph_lib
from repro.core import protocols as protocols_lib

K_GOSSIP = 16  # peers for the pure-mix metrics
DIM = 64
TRIALS = 5


def _setups(rounds: int, seed: int = 0) -> dict[str, tuple[str, graph_lib.GraphSchedule]]:
    """name -> (protocol, schedule): the scenario grid."""
    ring = graph_lib.build_graph("ring", K_GOSSIP)
    d_ring = graph_lib.build_graph("directed_ring", K_GOSSIP)
    return {
        "gossip_ring": ("gossip", graph_lib.static_schedule(ring)),
        "push_sum_ring": ("push_sum", graph_lib.static_schedule(ring)),
        "push_sum_directed_ring": ("push_sum", graph_lib.static_schedule(d_ring)),
        "gossip_directed_ring": ("gossip", graph_lib.static_schedule(d_ring)),
        "push_sum_one_way_matching": (
            "push_sum",
            graph_lib.one_way_matching_schedule(K_GOSSIP, rounds, seed=seed),
        ),
        "push_sum_directed_dropout": (
            "push_sum",
            graph_lib.link_dropout_schedule(d_ring, 0.7, rounds, seed=seed),
        ),
    }


def _pure_mix_metrics(
    protocol: str, sched: graph_lib.GraphSchedule, rounds: int, *, seed: int = 0
) -> tuple[float, float, float, float]:
    """(mean spectral gap, consensus error, bias vs weighted avg, us/step)."""
    rng = np.random.default_rng(seed)
    data_sizes = rng.integers(1, 50, sched.num_peers)
    proto = protocols_lib.get_protocol(protocol)
    consts_np = proto.constants(sched, "data_weighted", data_sizes=data_sizes)
    # rounds is a multiple of the period, so the per-period mean == per-round mean
    gaps = [graph_lib.spectral_gap(consts_np.w[r]) for r in range(sched.period)]

    x0 = rng.normal(size=(sched.num_peers, DIM))
    target = (data_sizes[:, None] * x0).sum(0) / data_sizes.sum()
    x = {"x": jnp.asarray(x0, jnp.float32)}
    proto_state0 = proto.init_state(x, data_sizes)
    stacked = protocols_lib.ProtocolConstants(
        jnp.asarray(consts_np.w, jnp.float32),
        jnp.asarray(consts_np.beta, jnp.float32),
    )

    def step(carry):
        t, proto_state, z = carry
        consts = protocols_lib.round_constants(stacked, t % sched.period)
        proto_state, z = proto.mix(proto_state, z, consts)
        return (t + 1, proto_state, z)

    # derived metrics from ONE canonical `rounds`-step run (deterministic,
    # gate-comparable); wall-clock from a separate median-of-TRIALS timing
    # pass with block_until_ready on both sides of each trial
    carry = (0, proto_state0, x)
    for _ in range(rounds):
        carry = step(carry)
    _, _, x_final = jax.block_until_ready(carry)
    err = float(consensus_lib.consensus_error(x_final))
    bias = float(np.abs(np.asarray(x_final["x"]).mean(0) - target).max())
    us, _ = median_us(step, (0, proto_state0, x), calls=rounds, trials=TRIALS)
    return float(np.mean(gaps)), err, bias, us


def protocol_mixing(full=False):
    """Pure-mix comparison: per-protocol gap, consensus error, bias, wall-clock."""
    rounds = 256 if full else 64
    out = []
    for name, (protocol, sched) in _setups(min(rounds, 16)).items():
        gap, err, bias, us = _pure_mix_metrics(protocol, sched, rounds)
        out.append((f"proto_{name}_mean_spectral_gap", us, gap))
        out.append((f"proto_{name}_consensus_error_{rounds}r", us, err))
        out.append((f"proto_{name}_bias_vs_weighted_avg", us, bias))
    return out


def protocol_training(full=False):
    """Wall-clock per training round, gossip vs push-sum, one jitted round fn."""
    from repro.core import p2p

    rounds = 30 if full else 10
    k, t_steps = 8, 4
    targets = np.random.default_rng(0).normal(size=(k, 4))
    batches = jnp.broadcast_to(jnp.asarray(targets, jnp.float32), (t_steps, k, 4))

    def quad_loss(params, batch):
        return jnp.sum(jnp.square(params["w"] - batch))

    def init_fn(key):
        return {"w": jax.random.normal(key, (4,))}

    out = []
    for name, protocol, topology in (
        ("gossip_ring", "gossip", "ring"),
        ("push_sum_directed_ring", "push_sum", "directed_ring"),
    ):
        cfg = p2p.P2PConfig(
            algorithm="p2pl_affinity", num_peers=k, local_steps=t_steps,
            consensus_steps=1, lr=0.05, eta_d=0.5, topology=topology,
            protocol=protocol,
        )
        state0 = p2p.init_state(jax.random.PRNGKey(0), init_fn, cfg)
        fn = p2p.make_round_fn(quad_loss, cfg)
        # CI-gated derived value from ONE canonical `rounds`-round run, so it
        # cannot drift when timing knobs (TRIALS, warmup) change; the timing
        # pass below runs on a separate state
        state = state0
        for _ in range(rounds):
            _, state, _ = fn(state, batches)
        err = float(consensus_lib.consensus_error(state.params))
        us, _ = median_us(
            lambda s: fn(s, batches)[1], state0, calls=rounds, trials=TRIALS
        )
        out.append((f"proto_train_{name}_round", us, err))
    return out


ALL_PROTOCOLS = {
    "proto_mixing": protocol_mixing,
    "proto_train": protocol_training,
}


# ---------------------------------------------------------------------------
# Compression Pareto: bytes per round x final accuracy
# ---------------------------------------------------------------------------
#
# The claim the compression subsystem (repro/compression) exists to deliver:
# error-feedback top-k cuts consensus traffic by an order of magnitude on the
# paper's non-IID k8 workload without giving up the accuracy the consensus
# phase buys.  Each variant trains the SAME seeded timevarying_k8 run under
# one compressor; bytes are analytic (benchmarks.wire — the audited formulas
# shared with the scaling rows), accuracy is the paper's own instrument.
#
# Row layout (serialized to ``BENCH_compression.json`` by ``benchmarks/run.py``):
#
#     compression_{name}_final_acc     us col = wall-clock us/round,
#                                      derived = final all-class accuracy
#     compression_{name}_bytes_round   us col = bytes ONE peer sends per edge,
#                                      derived = analytic fleet bytes/round
#     compression_bytes_reduction      us col = none/topk bytes ratio,
#                                      derived = 1.0 iff ratio >= 10
#     compression_accuracy_delta       us col = max(0, acc_none - acc_topk),
#                                      derived = 1.0 iff delta <= 0.01
#
# Traffic is priced honestly per delivery model: the raw baseline pays the
# round's ACTIVE directed edges (a message is only needed where the mixing
# weight is nonzero), while compressed variants pay every UNION edge of the
# schedule every step (estimate tracking needs sender/receiver copies of x̂
# to advance in lockstep, so payloads flow on all lanes — see
# ``benchmarks.wire``).  At frac=0.025 that is still a 11.5x reduction.

TOPK_FRAC = 0.025
_BYTES_REDUCTION_GATE = 10.0
_ACCURACY_DELTA_GATE = 0.01

# (variant label, compressor name) — 'none' is the fp32 bit-identical baseline
COMPRESSION_VARIANTS = (
    ("none", "none"),
    ("topk", "topk"),
    ("qint8", "qint8"),
)


def compression_pareto(full=False):
    """Bytes-per-round x final-accuracy Pareto of the compressed-gossip grid."""
    import time

    from benchmarks import wire
    from repro import compression as compression_lib
    from repro.configs.p2pl_mnist import timevarying_k8
    from repro.core import p2p
    from repro.data import synthetic
    from repro.launch.train import run_paper_experiment
    from repro.models import mlp

    # error feedback needs a horizon: the estimates converge onto the
    # parameters over rounds, so short runs understate compressed accuracy
    rounds = 96 if full else 48
    data = synthetic.mnist_like(20000 if full else 6000, 5000 if full else 1500)

    out = []
    acc = {}
    bytes_round = {}
    for name, compressor in COMPRESSION_VARIANTS:
        exp = timevarying_k8(
            schedule="round_robin", algorithm="p2pl_affinity", local_steps=10,
            compressor=compressor, topk_frac=TOPK_FRAC,
        )
        cfg = exp.p2p

        # analytic traffic: the average round graph's directed edges, each
        # carrying one compressed message per consensus step
        sched = p2p.build_schedule(cfg)
        proto = protocols_lib.get_protocol(cfg.protocol)
        consts = proto.constants(
            sched, cfg.mixing,
            data_sizes=np.full(cfg.num_peers, 100),
        )
        params = jax.eval_shape(
            jax.vmap(mlp.init_2nn),
            jax.ShapeDtypeStruct((cfg.num_peers, 2), jnp.uint32),
        )
        comp = compression_lib.from_config(cfg)
        msg = wire.message_nbytes(comp, params)
        # raw gossip pays only the round's active edges; estimate-tracking
        # payloads ride every union lane every step (see benchmarks.wire)
        if comp.identity:
            bytes_round[name] = wire.gossip_bytes_per_round(
                consts.w, msg, cfg.consensus_steps
            )
        else:
            bytes_round[name] = wire.estimate_gossip_bytes_per_round(
                consts.w, msg, cfg.consensus_steps
            )

        t0 = time.time()
        log = run_paper_experiment(exp, rounds=rounds, data=data)
        us = (time.time() - t0) / rounds * 1e6
        acc[name] = log.final_accuracy("all")
        out.append((f"compression_{name}_final_acc", us, acc[name]))
        out.append((f"compression_{name}_bytes_round", msg, bytes_round[name]))

    # the CI-gated claim: >= 10x fewer bytes on the wire at <= 1% accuracy
    # cost (error feedback re-injects what top-k drops, so the sparsified run
    # tracks the fp32 baseline)
    ratio = bytes_round["none"] / bytes_round["topk"]
    delta = max(0.0, acc["none"] - acc["topk"])
    out.append((
        "compression_bytes_reduction",
        ratio,  # us column carries the reduction ratio
        1.0 if ratio >= _BYTES_REDUCTION_GATE else 0.0,
    ))
    out.append((
        "compression_accuracy_delta",
        delta,  # us column carries the accuracy delta
        1.0 if delta <= _ACCURACY_DELTA_GATE else 0.0,
    ))
    return out


ALL_COMPRESSION = {
    "compression": compression_pareto,
}
