"""The protocol axis: gossip vs push-sum on symmetric and directed graphs.

One row per (protocol/topology, metric):
- spectral gap of the per-round mixing matrix (row-stochastic W for gossip,
  column-stochastic A for push-sum) — the consensus rate actually available,
- consensus error of the DE-BIASED estimates after one period of pure mixing
  from a common random start (push-sum divides by the carried mass; gossip's
  estimates are its raw parameters),
- bias of the consensus point vs the data-weighted average — the number that
  indicts row-stochastic gossip on directed graphs and exonerates push-sum,
- wall-clock per mix step (us).

``benchmarks/run.py`` additionally serializes these rows to
``BENCH_protocols.json`` so the per-protocol perf trajectory accumulates
across PRs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import median_us
from repro.core import consensus as consensus_lib
from repro.core import graph as graph_lib
from repro.core import protocols as protocols_lib

K_GOSSIP = 16  # peers for the pure-mix metrics
DIM = 64
TRIALS = 5


def _setups(rounds: int, seed: int = 0) -> dict[str, tuple[str, graph_lib.GraphSchedule]]:
    """name -> (protocol, schedule): the scenario grid."""
    ring = graph_lib.build_graph("ring", K_GOSSIP)
    d_ring = graph_lib.build_graph("directed_ring", K_GOSSIP)
    return {
        "gossip_ring": ("gossip", graph_lib.static_schedule(ring)),
        "push_sum_ring": ("push_sum", graph_lib.static_schedule(ring)),
        "push_sum_directed_ring": ("push_sum", graph_lib.static_schedule(d_ring)),
        "gossip_directed_ring": ("gossip", graph_lib.static_schedule(d_ring)),
        "push_sum_one_way_matching": (
            "push_sum",
            graph_lib.one_way_matching_schedule(K_GOSSIP, rounds, seed=seed),
        ),
        "push_sum_directed_dropout": (
            "push_sum",
            graph_lib.link_dropout_schedule(d_ring, 0.7, rounds, seed=seed),
        ),
    }


def _pure_mix_metrics(
    protocol: str, sched: graph_lib.GraphSchedule, rounds: int, *, seed: int = 0
) -> tuple[float, float, float, float]:
    """(mean spectral gap, consensus error, bias vs weighted avg, us/step)."""
    rng = np.random.default_rng(seed)
    data_sizes = rng.integers(1, 50, sched.num_peers)
    proto = protocols_lib.get_protocol(protocol)
    consts_np = proto.constants(sched, "data_weighted", data_sizes=data_sizes)
    # rounds is a multiple of the period, so the per-period mean == per-round mean
    gaps = [graph_lib.spectral_gap(consts_np.w[r]) for r in range(sched.period)]

    x0 = rng.normal(size=(sched.num_peers, DIM))
    target = (data_sizes[:, None] * x0).sum(0) / data_sizes.sum()
    x = {"x": jnp.asarray(x0, jnp.float32)}
    proto_state0 = proto.init_state(x, data_sizes)
    stacked = protocols_lib.ProtocolConstants(
        jnp.asarray(consts_np.w, jnp.float32),
        jnp.asarray(consts_np.beta, jnp.float32),
    )

    def step(carry):
        t, proto_state, z = carry
        consts = protocols_lib.round_constants(stacked, t % sched.period)
        proto_state, z = proto.mix(proto_state, z, consts)
        return (t + 1, proto_state, z)

    # derived metrics from ONE canonical `rounds`-step run (deterministic,
    # gate-comparable); wall-clock from a separate median-of-TRIALS timing
    # pass with block_until_ready on both sides of each trial
    carry = (0, proto_state0, x)
    for _ in range(rounds):
        carry = step(carry)
    _, _, x_final = jax.block_until_ready(carry)
    err = float(consensus_lib.consensus_error(x_final))
    bias = float(np.abs(np.asarray(x_final["x"]).mean(0) - target).max())
    us, _ = median_us(step, (0, proto_state0, x), calls=rounds, trials=TRIALS)
    return float(np.mean(gaps)), err, bias, us


def protocol_mixing(full=False):
    """Pure-mix comparison: per-protocol gap, consensus error, bias, wall-clock."""
    rounds = 256 if full else 64
    out = []
    for name, (protocol, sched) in _setups(min(rounds, 16)).items():
        gap, err, bias, us = _pure_mix_metrics(protocol, sched, rounds)
        out.append((f"proto_{name}_mean_spectral_gap", us, gap))
        out.append((f"proto_{name}_consensus_error_{rounds}r", us, err))
        out.append((f"proto_{name}_bias_vs_weighted_avg", us, bias))
    return out


def protocol_training(full=False):
    """Wall-clock per training round, gossip vs push-sum, one jitted round fn."""
    from repro.core import p2p

    rounds = 30 if full else 10
    k, t_steps = 8, 4
    targets = np.random.default_rng(0).normal(size=(k, 4))
    batches = jnp.broadcast_to(jnp.asarray(targets, jnp.float32), (t_steps, k, 4))

    def quad_loss(params, batch):
        return jnp.sum(jnp.square(params["w"] - batch))

    def init_fn(key):
        return {"w": jax.random.normal(key, (4,))}

    out = []
    for name, protocol, topology in (
        ("gossip_ring", "gossip", "ring"),
        ("push_sum_directed_ring", "push_sum", "directed_ring"),
    ):
        cfg = p2p.P2PConfig(
            algorithm="p2pl_affinity", num_peers=k, local_steps=t_steps,
            consensus_steps=1, lr=0.05, eta_d=0.5, topology=topology,
            protocol=protocol,
        )
        state0 = p2p.init_state(jax.random.PRNGKey(0), init_fn, cfg)
        fn = p2p.make_round_fn(quad_loss, cfg)
        # CI-gated derived value from ONE canonical `rounds`-round run, so it
        # cannot drift when timing knobs (TRIALS, warmup) change; the timing
        # pass below runs on a separate state
        state = state0
        for _ in range(rounds):
            _, state, _ = fn(state, batches)
        err = float(consensus_lib.consensus_error(state.params))
        us, _ = median_us(
            lambda s: fn(s, batches)[1], state0, calls=rounds, trials=TRIALS
        )
        out.append((f"proto_train_{name}_round", us, err))
    return out


ALL_PROTOCOLS = {
    "proto_mixing": protocol_mixing,
    "proto_train": protocol_training,
}
