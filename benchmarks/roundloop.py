"""The round-loop axis: python-loop vs scan-driver execution of the SAME rounds.

The paper's central measurement needs many rounds end to end, so the per-round
dispatch overhead IS the budget on edge-class hardware.  This benchmark times
the full {driver} x {runtime} x {protocol} grid:

    driver:   python (one jitted dispatch per round — the pre-PR-4 hot path)
              vs scan (``p2p.make_scan_driver``: an eval-period chunk of
              rounds inside ONE ``lax.scan`` with the input state donated)
    runtime:  vmap (stacked) vs pod (shard_map over a real mesh; rows are
              skipped with an explanatory name when devices < K)
    protocol: gossip vs push_sum

Row layout (serialized to ``BENCH_roundloop.json`` by ``benchmarks/run.py``):

    roundloop_python_{rt}_{proto}_round   us/round, derived = consensus error
    roundloop_scan_{rt}_{proto}_round     us/round, derived = consensus error
    roundloop_scan_faster_{rt}_{proto}    us col = SPEEDUP RATIO (python/scan),
                                          derived = 1.0 iff scan is strictly
                                          faster (0.0 otherwise)

The consensus error is measured on a fixed-length parity run from one seeded
init, so it is deterministic — the python and scan rows must agree bit for bit
(asserted here), and ``benchmarks/compare.py`` can gate all derived values
against the committed baseline.  The ``scan_faster`` boolean rows make the CI
gate fail loudly if the scan driver ever stops beating the python loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import wire
from benchmarks.timing import median_us
from repro.core import consensus as consensus_lib
from repro.core import p2p

K = 8
DIM = 64  # small on purpose: the grid isolates dispatch/loop overhead
T_STEPS = 4
CHUNK = 8  # rounds per scan chunk (one "eval period")


def _quad_loss(params, batch):
    return jnp.sum(jnp.square(params["w"] - batch))


def _init_fn(key):
    return {"w": jax.random.normal(key, (DIM,))}


def _cfg(protocol: str, topology: str, schedule: str) -> p2p.P2PConfig:
    return p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=K, local_steps=T_STEPS,
        consensus_steps=1, lr=0.05, eta_d=0.5, topology=topology,
        protocol=protocol, schedule=schedule, schedule_rounds=8,
    )


def _consensus_err(state: p2p.P2PState) -> float:
    # on HOST params: the pod runtime's params live across devices, and an
    # on-device reduction would compile a different program than the vmap
    # run's — hiding the drivers' actual bit-equality
    return float(consensus_lib.consensus_error(jax.device_get(state.params)))


def _bench_cell(cfg, mesh, batches_round, batches_chunk, rounds, trials):
    """(python_us, scan_us, err_python, err_scan) for one (runtime, protocol)."""
    from repro.sharding import specs as specs_lib

    def fresh_state():
        s = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg)
        return specs_lib.shard_peer_tree(s, mesh) if mesh is not None else s

    round_fn = (
        p2p.make_sharded_round_fn(_quad_loss, cfg, mesh)
        if mesh is not None else p2p.make_round_fn(_quad_loss, cfg)
    )
    drive_fn = p2p.make_scan_driver(_quad_loss, cfg, mesh=mesh)

    # -- parity/check run first: CHUNK rounds from the same seeded init ------
    s = fresh_state()
    for _ in range(CHUNK):
        _, s, _ = round_fn(s, batches_round)
    err_python = _consensus_err(s)
    _, s, _ = drive_fn(fresh_state(), batches_chunk)
    err_scan = _consensus_err(s)
    assert err_python == err_scan, (
        f"drivers diverged: python {err_python} scan {err_scan}"
    )

    # -- timing: median over trials, blocked on both sides of each trial ----
    def measure():
        python_us, _ = median_us(
            lambda st: round_fn(st, batches_round)[1],
            fresh_state(), calls=rounds, trials=trials,
        )
        scan_us_chunk, _ = median_us(
            # the scan driver DONATES its input: feed the returned state back in
            lambda st: drive_fn(st, batches_chunk)[1],
            fresh_state(), calls=max(rounds // CHUNK, 1), trials=trials,
        )
        return python_us, scan_us_chunk / CHUNK

    python_us, scan_us = measure()
    if scan_us >= python_us:
        # the scan_faster rows are CI-gated booleans: guard them against a
        # one-off scheduler-jitter loss on an oversubscribed runner with ONE
        # re-measurement (a persistent regression still fails both passes)
        py2, sc2 = measure()
        python_us, scan_us = min(python_us, py2), min(scan_us, sc2)
    return python_us, scan_us, err_python, err_scan


def roundloop(full=False):
    """us/round for {python-loop, scan-driver} x {vmap, pod} x {gossip, push_sum}."""
    rounds = 64 if full else 24  # per timing trial; CHUNK divides both
    trials = 7 if full else 5
    rng = np.random.default_rng(0)
    base = jnp.asarray(rng.normal(size=(K, DIM)), jnp.float32)
    batches_round = jnp.broadcast_to(base, (T_STEPS, K, DIM))
    batches_chunk = jnp.broadcast_to(base, (CHUNK, T_STEPS, K, DIM))

    out = []
    for protocol, topology, schedule in (
        ("gossip", "ring", "link_dropout"),
        ("push_sum", "directed_ring", "static"),
    ):
        cfg = _cfg(protocol, topology, schedule)
        for runtime in ("vmap", "pod"):
            if runtime == "pod" and jax.device_count() < K:
                out.append((
                    f"roundloop_pod_{protocol}_SKIPPED_need_{K}_devices", 0.0, 0,
                ))
                continue
            mesh = None
            if runtime == "pod":
                from repro.launch import mesh as mesh_lib

                mesh = mesh_lib.make_peer_mesh(K)
            py_us, scan_us, err_py, err_scan = _bench_cell(
                cfg, mesh, batches_round, batches_chunk, rounds, trials
            )
            out.append((f"roundloop_python_{runtime}_{protocol}_round", py_us, err_py))
            out.append((f"roundloop_scan_{runtime}_{protocol}_round", scan_us, err_scan))
            out.append((
                f"roundloop_scan_faster_{runtime}_{protocol}",
                py_us / scan_us,  # us column carries the speedup ratio
                1.0 if scan_us < py_us else 0.0,
            ))
    return out


ALL_ROUNDLOOP = {
    "roundloop": roundloop,
}


# ---------------------------------------------------------------------------
# K-scaling: the sparse segment runtime vs fleet size
# ---------------------------------------------------------------------------
#
# The large-K claim the sparse peer axis exists to deliver: on a fixed-degree
# topology (ring, in-degree 2 at every K), cost per round must grow
# sub-quadratically in K — the dense (K, K) runtime is Theta(K^2) by
# construction.  Every cell runs the SAME hierarchical segment runtime
# (``peers_per_device`` peers vmapped inside each mesh slice, consensus over
# the degree-bounded sparse schedule), so the fitted log-log slope measures
# the sparse path itself, not a runtime switch.
#
# Row layout (serialized to ``BENCH_scaling.json`` by ``benchmarks/run.py``):
#
#     scaling_k{K}_segment_round   us/round; derived = ANALYTIC consensus
#                                  bytes/round (deterministic, so the compare
#                                  gate pins the payload model per K)
#     scaling_subquadratic         us col = fitted d log(us) / d log(K) slope,
#                                  derived = 1.0 iff slope < 2.0

SCALING_KS = (8, 64, 512, 4096)
SCALING_DIM = 32  # tiny model on purpose: K is the axis under test
_SUBQUADRATIC_SLOPE = 2.0


def _scaling_devices(k: int) -> int:
    # 8 mesh slices when the fleet is large enough; K = 8 drops to 4 so the
    # hierarchical layout (>= 2 peers per device) still holds
    return min(8, k // 2)


def _scaling_bytes(k: int) -> float:
    """Analytic consensus payload per round, fleet-total, in bytes.

    The segment mix ring-streams every device's (peers_per_device, DIM) fp32
    block through the other ``devices - 1`` slices once per consensus step:
    S * (devices - 1) * K * DIM * 4 bytes — linear in K at fixed degree,
    against the dense runtime's K^2 weight traffic.  The formula itself lives
    in ``benchmarks.wire`` so the compression Pareto rows share the audit.
    """
    return wire.ring_stream_bytes(_scaling_devices(k), k * SCALING_DIM)


def _scaling_cell(k: int, full: bool) -> float:
    """Median us/round of the hierarchical segment runtime at fleet size k."""
    from repro.launch import mesh as mesh_lib
    from repro.sharding import specs as specs_lib

    devices = _scaling_devices(k)
    cfg = p2p.P2PConfig(
        algorithm="p2pl_affinity", num_peers=k, local_steps=1,
        consensus_steps=1, lr=0.05, eta_d=0.5, topology="ring",
        protocol="gossip", schedule="static",
    )
    mesh = mesh_lib.make_peer_mesh(devices)
    round_fn = p2p.make_sharded_round_fn(
        _quad_loss, cfg, mesh, peers_per_device=k // devices,
        mix_mode="segment",
    )

    def init_fn(key):
        return {"w": jax.random.normal(key, (SCALING_DIM,))}

    state = specs_lib.shard_peer_tree(
        p2p.init_state(jax.random.PRNGKey(0), init_fn, cfg), mesh
    )
    rng = np.random.default_rng(k)
    batches = jnp.asarray(rng.normal(size=(1, k, SCALING_DIM)), jnp.float32)
    us, _ = median_us(
        lambda st: round_fn(st, batches)[1],
        state, calls=4 if full else 2, trials=5 if full else 3,
    )
    return us


def scaling(full=False):
    """us/round + analytic bytes/round of the segment runtime vs K."""
    if jax.device_count() < 8:
        return [("scaling_SKIPPED_need_8_devices", 0.0, 0)]

    def measure():
        us = [_scaling_cell(k, full) for k in SCALING_KS]
        return us, float(np.polyfit(np.log(SCALING_KS), np.log(us), 1)[0])

    us_per_k, slope = measure()
    if slope >= _SUBQUADRATIC_SLOPE:
        # the subquadratic row is a CI-gated boolean: guard it against a
        # one-off scheduler-jitter outlier on an oversubscribed runner with
        # ONE re-measurement (a persistent regression still fails both)
        us2, slope2 = measure()
        if slope2 < slope:
            us_per_k, slope = us2, slope2

    out = [
        (f"scaling_k{k}_segment_round", us, _scaling_bytes(k))
        for k, us in zip(SCALING_KS, us_per_k)
    ]
    out.append((
        "scaling_subquadratic",
        slope,  # us column carries the fitted log-log slope
        1.0 if slope < _SUBQUADRATIC_SLOPE else 0.0,
    ))
    return out


ALL_SCALING = {
    "scaling": scaling,
}
