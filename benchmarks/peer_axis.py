"""The runtime axis: vmap vs shard_map execution of the SAME jitted round.

One row per (runtime, protocol) cell: wall-clock per round (us) with the
final consensus error as the derived check value — the two runtimes are
bit-identical, so matched derived values double as a cheap parity probe.
A closing ``peer_axis_speedup_*`` row reports vmap_us / shard_map_us.

The shard_map rows need one device per peer; on a single-device host (the
default CI bench job) they are skipped with an explanatory row so the CSV
stays self-describing:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.run --only peer_axis
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import median_us
from repro.core import consensus as consensus_lib
from repro.core import p2p

K = 8
DIM = 256  # per-leaf width: big enough that mixing cost is visible
T_STEPS = 4
ROUNDS = 20
TRIALS = 5


def _quad_loss(params, batch):
    return jnp.sum(jnp.square(params["w"] - batch))


def _init_fn(key):
    return {"w": jax.random.normal(key, (DIM,))}


def _bench_round_fn(fn, state, batches, rounds):
    # median-of-TRIALS with block_until_ready before BOTH timestamps of every
    # trial (see benchmarks.timing) — single-trial timing on a shared runner
    # is dominated by scheduler jitter
    us, state = median_us(
        lambda s: fn(s, batches)[1], state, calls=rounds, trials=TRIALS
    )
    # consensus error on HOST params: the sharded run's params live across
    # devices, and an on-device reduction would compile a different program
    # than the vmap run's — hiding the runtimes' actual bit-equality
    return us, float(consensus_lib.consensus_error(jax.device_get(state.params)))


def peer_axis_round(full=False):
    """Wall-clock per round, vmap vs shard_map, gossip and push-sum."""
    rounds = 60 if full else ROUNDS
    batches = jnp.broadcast_to(
        jnp.asarray(np.random.default_rng(0).normal(size=(K, DIM)), jnp.float32),
        (T_STEPS, K, DIM),
    )
    out = []
    for protocol, topology, schedule in (
        ("gossip", "ring", "link_dropout"),
        ("push_sum", "directed_ring", "static"),
    ):
        cfg = p2p.P2PConfig(
            algorithm="p2pl_affinity", num_peers=K, local_steps=T_STEPS,
            consensus_steps=1, lr=0.05, eta_d=0.5, topology=topology,
            protocol=protocol, schedule=schedule, schedule_rounds=8,
        )
        state = p2p.init_state(jax.random.PRNGKey(0), _init_fn, cfg)
        vmap_us, vmap_err = _bench_round_fn(
            p2p.make_round_fn(_quad_loss, cfg), state, batches, rounds
        )
        out.append((f"peer_axis_vmap_{protocol}_round", vmap_us, vmap_err))
        if jax.device_count() < K:
            out.append((
                f"peer_axis_shard_map_{protocol}_round_SKIPPED_need_{K}_devices",
                0.0, 0,
            ))
            continue
        from repro.launch import mesh as mesh_lib
        from repro.sharding import specs as specs_lib

        mesh = mesh_lib.make_peer_mesh(K)
        shard_us, shard_err = _bench_round_fn(
            p2p.make_sharded_round_fn(_quad_loss, cfg, mesh),
            specs_lib.shard_peer_tree(state, mesh), batches, rounds,
        )
        out.append((f"peer_axis_shard_map_{protocol}_round", shard_us, shard_err))
        assert shard_err == vmap_err, (
            f"runtimes diverged ({protocol}): vmap {vmap_err} shard {shard_err}"
        )
        out.append((f"peer_axis_speedup_{protocol}", shard_us, vmap_us / shard_us))
    return out


ALL_PEER_AXIS = {
    "peer_axis": peer_axis_round,
}
