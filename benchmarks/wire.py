"""Shared wire-bytes accounting for the benchmark surfaces.

One audited formula per traffic pattern, so the analytic bytes column of
``BENCH_scaling.json`` (ring-streamed segment mix) and the bytes-per-round
Pareto rows of ``BENCH_compression.json`` (compressed gossip payloads) can
never drift apart from hand-copied arithmetic.  Everything here is analytic —
shapes and graph structure only, no device transfers are measured.
"""
from __future__ import annotations

import jax
import numpy as np


def ring_stream_bytes(
    num_devices: int, num_values: int, itemsize: int = 4, steps: int = 1
) -> float:
    """Fleet-total bytes of ring-streaming ``num_values`` scalars once around
    a ``num_devices`` ring, ``steps`` times.

    Every device's block visits the other ``num_devices - 1`` slices exactly
    once per step, so the whole fleet moves
    ``steps * (num_devices - 1) * num_values * itemsize`` bytes.  This is the
    segment-mix payload model behind ``scaling_k*``'s derived column.
    """
    return float(steps * (num_devices - 1) * num_values * itemsize)


def message_nbytes(comp, params) -> float:
    """Bytes ONE peer sends per directed edge per consensus step under
    compressor ``comp``: the summed payload-array bytes of every leaf,
    divided by the leading peer axis.

    Uses ``jax.eval_shape`` so the accounting reads the compressor's actual
    payload shapes/dtypes (values + indices + scales, whatever it ships)
    instead of re-deriving them by hand.
    """
    total = 0.0
    peers = None
    for leaf in jax.tree.leaves(params):
        payload = jax.eval_shape(comp.compress, jax.ShapeDtypeStruct(leaf.shape, leaf.dtype))
        for arr in jax.tree.leaves(payload):
            if peers is None:
                peers = arr.shape[0]
            total += float(np.prod(arr.shape)) * arr.dtype.itemsize
    return total / max(peers or 1, 1)


def mean_directed_edges(w_stack) -> float:
    """Average number of directed off-diagonal nonzero edges per round of a
    stacked ``(R, K, K)`` mixing schedule (a single ``(K, K)`` matrix counts
    as one round).  Each nonzero ``W[k, j], k != j`` is one message ``j -> k``
    on the wire.
    """
    w = np.asarray(jax.device_get(w_stack))
    if w.ndim == 2:
        w = w[None]
    k = w.shape[-1]
    off = w * (1.0 - np.eye(k))
    return float(np.mean(np.sum(off != 0.0, axis=(-2, -1))))


def gossip_bytes_per_round(w_stack, msg_bytes: float, consensus_steps: int = 1) -> float:
    """Fleet-total gossip traffic per round: every directed edge of the
    (average) round graph carries one ``msg_bytes`` message per consensus
    step.  Push-sum adds its fp32 mass scalar on the same edges — callers
    fold that into ``msg_bytes`` if they account for it.

    This is the RAW (uncompressed) delivery model: a peer's message is only
    needed where its mixing weight is nonzero, so inactive edges of a
    time-varying schedule carry nothing that round.
    """
    return mean_directed_edges(w_stack) * msg_bytes * consensus_steps


def union_directed_edges(w_stack) -> float:
    """Directed off-diagonal edges active in ANY round of a stacked
    ``(R, K, K)`` mixing schedule — the static lane set of the time-varying
    graph (for round_robin(ring, star) at K=8: 26 vs a 15-edge round mean).
    """
    w = np.asarray(jax.device_get(w_stack))
    if w.ndim == 2:
        w = w[None]
    k = w.shape[-1]
    off = np.any(w * (1.0 - np.eye(k)) != 0.0, axis=0)
    return float(np.sum(off))


def estimate_gossip_bytes_per_round(
    w_stack, msg_bytes: float, consensus_steps: int = 1
) -> float:
    """Fleet-total traffic per round for ESTIMATE-TRACKING (compressed)
    gossip: one ``msg_bytes`` payload per consensus step on every UNION
    edge of the schedule, active or not.

    Compressed mixing runs against persistent public estimates ``x̂`` of
    each in-neighbor, advanced by every payload the sender emits.  The
    sender's own copy of ``x̂`` (the error-feedback reference) advances every
    step, so a receiver that skipped the inactive rounds would hold a stale,
    DIVERGENT estimate — sender and receiver copies must advance in
    lockstep.  Payloads therefore flow on all union lanes every step, and
    compression is charged for that standing traffic while the raw baseline
    (``gossip_bytes_per_round``) pays only the round's active edges.  This
    prices compression conservatively; the >= 10x gate holds anyway.
    """
    return union_directed_edges(w_stack) * msg_bytes * consensus_steps
