"""Checkpointing: pytree <-> .npz with path-flattened keys + JSON metadata.

Works on sharded arrays (device_get gathers to host).  Restore rebuilds the
exact pytree structure from the flattened key paths; dtype/shape mismatches
raise rather than silently reinterpreting.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: PyTree, *, step: int | None = None, extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = {"step": step, "extra": extra or {}, "keys": sorted(flat)}
    with open(_meta_path(path), "w") as f:
        json.dump(meta, f)


def _meta_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(_path_str(x) for x in p)
        if key not in npz:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = npz[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: shape {arr.shape} != expected {want_shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(_meta_path(path)) as f:
        return json.load(f)
