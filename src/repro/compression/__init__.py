"""Consensus-payload compression (top-k + int8 with error feedback)."""

from repro.compression.compressors import (
    Compressor,
    NoneCompressor,
    QInt8Compressor,
    QInt8Payload,
    RawPayload,
    TopKCompressor,
    TopKPayload,
    compressor_names,
    ef_compress_leaf,
    ef_compress_tree,
    from_config,
    get_compressor,
    register_compressor,
)

__all__ = [
    "Compressor",
    "NoneCompressor",
    "QInt8Compressor",
    "QInt8Payload",
    "RawPayload",
    "TopKCompressor",
    "TopKPayload",
    "compressor_names",
    "ef_compress_leaf",
    "ef_compress_tree",
    "from_config",
    "get_compressor",
    "register_compressor",
]
