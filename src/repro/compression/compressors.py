"""Communication compression for the consensus phase (Sparse-Push's headline).

A ``Compressor`` shrinks the per-edge consensus message: instead of gossiping
raw fp32 parameter leaves, each peer broadcasts a compressed payload that the
receivers apply to a persistent *public estimate* of the sender's parameters
(CHOCO-SGD 1902.00340, Sparse-Push 2102.05715).  Every node — the sender
included — carries the same estimate stack ``x̂`` (the
``P2PState.compression`` tree, one dense copy per peer, warm-started at the
common initialization — see ``Compressor.init_estimate``); each
consensus step the sender ships ``C(x - x̂)`` and everyone advances
``x̂ <- x̂ + D(C(x - x̂))``.  The un-shipped part ``x - x̂`` IS the
error-feedback residual: it stays in the next difference and is re-compressed
every step, so the estimate converges to the parameters and the long-run
signal is conserved.  Mixing then runs on the dense estimates — this is what
makes top-k viable: decompressing a sparse payload *directly* as the
neighbor value zeroes most coordinates and shrinks every mix toward the
origin, while applying it as a sparse *update* to a dense running estimate
loses only the (fed-back) compression error.

Three implementations, in one registry mirroring ``core/protocols.py``:

    none  — the identity: runtimes detect ``identity = True`` and take the
            EXACT pre-compression code path (fp32 bit-identical by
            construction, zero overhead, no estimate state).  Its
            ``compress`` still exists so bytes accounting can price the
            uncompressed message.
    topk  — per-leaf top-k magnitude sparsification: keep the ``frac``
            largest-|value| coordinates of each (flattened) difference;
            payload = (values f32, indices int32) with leading peer axis.
            Decompress scatters into zeros, so kept slots round-trip EXACTLY
            and the estimate picks up the difference's largest coordinates
            bit for bit.
    qint8 — symmetric per-leaf int8 quantization of the difference: one fp32
            scale per peer row (``max|diff| / 127``) plus an int8 tensor; 4x
            fewer payload bytes, per-coordinate error bounded by
            ``scale / 2`` — and the difference (hence the scale) shrinks as
            the estimate converges.

Payloads are NamedTuples of arrays whose LEADING axis is the peer axis, so
the pod runtime can ppermute each payload array over the same ``PermLane``
structure it uses for raw leaves (``consensus.gather_peer_leaf``) — values,
indices, and scale ride the lanes instead of the fp32 tensor.  The push-sum
mass never rides here: it is a (K,) scalar lane, exchanged UNCOMPRESSED, so
mass conservation (sum y == K) is exact under any compressor.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class RawPayload(NamedTuple):
    """The uncompressed message (compressor="none"): the leaf itself, flat."""

    values: jax.Array  # (K, N) f32


class TopKPayload(NamedTuple):
    """Top-k sparsification: the kept coordinates of each flattened leaf."""

    values: jax.Array  # (K, M) f32 — signed values at the kept slots
    indices: jax.Array  # (K, M) int32 — flat coordinate of each kept slot


class QInt8Payload(NamedTuple):
    """Symmetric int8 quantization with one fp32 scale lane per peer row."""

    q: jax.Array  # (K, N) int8
    scale: jax.Array  # (K, 1) f32 — max|h| / 127 per row


def _flat(leaf: jax.Array) -> jax.Array:
    """(K, ...) leaf -> (K, N) f32 working view."""
    return leaf.astype(jnp.float32).reshape(leaf.shape[0], -1)


def _feat_size(like: jax.Array) -> int:
    return int(np.prod(like.shape[1:])) if like.ndim > 1 else 1


class Compressor:
    """One leaf-compression rule; stateless apart from the carried estimate."""

    name: str = "base"
    # identity compressors make the runtimes take the EXACT uncompressed code
    # path (the fp32 bit-parity guarantee is structural, not numerical)
    identity: bool = False

    def init_estimate(self, params: PyTree) -> PyTree:
        """The public-estimate stack carried in ``P2PState.compression``.

        WARM-STARTED at the initial (peer-stacked) parameters: the stack is
        built once on the host before any sharding, so every node holds the
        same deterministic estimate of every peer — the setup handshake every
        decentralized run already performs (with common-seed initialization
        it costs nothing on the wire).  Compressed payloads then only ever
        carry TRAINING DRIFT ``x - x̂``, which starts at zero instead of at
        the full parameter magnitude — a cold (zeros) start spends the first
        many rounds shipping the initialization itself through the sparsified
        wire, injecting estimate noise exactly when the non-IID peers most
        need consensus.  The error-feedback residual is implicit:
        ``params - estimate``.  ``()`` for the identity (no estimate to
        carry, no state-leaf overhead).
        """
        if self.identity:
            return ()
        return jax.tree.map(lambda x: jnp.array(x, copy=True), params)

    def compress(self, leaf: jax.Array) -> NamedTuple:
        """(K, ...) leaf -> payload NamedTuple of arrays with leading K axis."""
        raise NotImplementedError

    def decompress(self, payload: NamedTuple, like: jax.Array) -> jax.Array:
        """Payload -> the receivers' estimate, shaped ``(K_payload,) + like.shape[1:]``.

        ``like`` supplies the feature shape and dtype only; the leading axis
        comes from the payload (the pod runtime decompresses a gathered (K,
        ...) payload against its local (1, ...) block).  All-zero payload rows
        (peers this shard never heard from) decompress to zero rows, which
        meet zero mixing weights downstream.
        """
        raise NotImplementedError


class NoneCompressor(Compressor):
    """Identity: runtimes bypass compression entirely (``identity = True``).

    ``compress``/``decompress`` are still real (the flat fp32 leaf as payload)
    so bytes accounting and property tests can treat every compressor
    uniformly — the runtimes just never call them.
    """

    name = "none"
    identity = True

    def compress(self, leaf: jax.Array) -> RawPayload:
        return RawPayload(values=_flat(leaf))

    def decompress(self, payload: RawPayload, like: jax.Array) -> jax.Array:
        k = payload.values.shape[0]
        return payload.values.reshape((k,) + like.shape[1:]).astype(like.dtype)


class TopKCompressor(Compressor):
    """Per-leaf top-k magnitude sparsification (Sparse-Push / CHOCO style)."""

    name = "topk"

    def __init__(self, frac: float = 0.01):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk frac must be in (0, 1], got {frac}")
        self.frac = float(frac)

    def keep(self, n: int) -> int:
        """Kept coordinates for a leaf of N features (static, >= 1)."""
        return max(1, int(round(self.frac * n)))

    def compress(self, leaf: jax.Array) -> TopKPayload:
        flat = _flat(leaf)
        m = self.keep(flat.shape[1])
        # top-k by magnitude, payload carries the SIGNED values at those slots
        _, idx = jax.lax.top_k(jnp.abs(flat), m)
        vals = jnp.take_along_axis(flat, idx, axis=1)
        return TopKPayload(values=vals, indices=idx.astype(jnp.int32))

    def decompress(self, payload: TopKPayload, like: jax.Array) -> jax.Array:
        k = payload.values.shape[0]
        n = _feat_size(like)
        rows = jnp.arange(k, dtype=jnp.int32)[:, None]
        # top_k indices are distinct per row, so .set is scatter-safe; all-zero
        # payload rows write 0.0 at slot 0 repeatedly — still exactly zero
        out = jnp.zeros((k, n), jnp.float32)
        out = out.at[rows, payload.indices].set(payload.values)
        return out.reshape((k,) + like.shape[1:]).astype(like.dtype)


class QInt8Compressor(Compressor):
    """Symmetric per-leaf int8 quantization with an fp32 scale lane."""

    name = "qint8"

    def compress(self, leaf: jax.Array) -> QInt8Payload:
        flat = _flat(leaf)
        amax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)  # (K, 1)
        scale = amax / 127.0
        safe = jnp.where(scale > 0.0, scale, 1.0)  # all-zero row -> q = 0
        q = jnp.clip(jnp.round(flat / safe), -127.0, 127.0).astype(jnp.int8)
        return QInt8Payload(q=q, scale=scale)

    def decompress(self, payload: QInt8Payload, like: jax.Array) -> jax.Array:
        k = payload.q.shape[0]
        out = payload.q.astype(jnp.float32) * payload.scale
        return out.reshape((k,) + like.shape[1:]).astype(like.dtype)


# ---------------------------------------------------------------------------
# Error feedback (estimate tracking)
# ---------------------------------------------------------------------------


def ef_compress_leaf(
    comp: Compressor, x: jax.Array, est: jax.Array
) -> tuple[NamedTuple, jax.Array]:
    """One estimate-tracking compression of a leaf: the payload is the
    compressed difference ``C(x - est)``; everyone (sender and receivers
    alike) advances the public estimate by its decompression.

    Returns ``(payload, est_new)`` with ``est_new = est + D(payload)`` — the
    new ``P2PState.compression`` leaf AND the dense value mixing uses for
    this sender.  The error-feedback residual ``x - est_new`` needs no
    separate state: it stays inside the next difference and is re-compressed
    every step (for top-k the payload picks the difference's largest-|.|
    coordinates exactly, so for a static ``x`` the estimate converges).
    """
    payload = comp.compress(x - est)
    return payload, est + comp.decompress(payload, x)


def ef_compress_tree(
    comp: Compressor, params: PyTree, est: PyTree
) -> tuple[list, PyTree]:
    """``ef_compress_leaf`` over a stacked parameter tree.

    Returns ``(payloads, est_new_tree)``; ``payloads`` is a list aligned with
    ``jax.tree.leaves(params)`` (payload NamedTuples are pytrees themselves,
    so they cannot ride inside a ``tree.map`` over params).
    """
    leaves, treedef = jax.tree.flatten(params)
    e_leaves = jax.tree.leaves(est)
    payloads, ests = [], []
    for x, e in zip(leaves, e_leaves):
        p, en = ef_compress_leaf(comp, x, e)
        payloads.append(p)
        ests.append(en)
    return payloads, jax.tree.unflatten(treedef, ests)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type[Compressor]] = {}


def register_compressor(cls: type[Compressor]) -> type[Compressor]:
    """Add a compressor class to the registry (name must be unique)."""
    if not cls.name or cls.name == "base":
        raise ValueError("compressor needs a distinct name")
    if cls.name in _REGISTRY:
        raise ValueError(f"compressor {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    return cls


def compressor_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_compressor(name: str, *, topk_frac: float = 0.01) -> Compressor:
    """Instantiate a registered compressor (``topk`` takes its kept fraction)."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown compressor {name!r}; one of {compressor_names()}"
        ) from None
    if cls is TopKCompressor:
        return cls(topk_frac)
    return cls()


def from_config(cfg) -> Compressor:
    """The config's compressor (duck-typed: needs ``.compressor``/``.topk_frac``,
    i.e. any ``repro.core.p2p.P2PConfig``)."""
    return get_compressor(cfg.compressor, topk_frac=cfg.topk_frac)


register_compressor(NoneCompressor)
register_compressor(TopKCompressor)
register_compressor(QInt8Compressor)
