"""Deterministic synthetic datasets (this container has no dataset downloads).

``mnist_like`` — a 10-class, 28x28, class-separable image dataset standing in
for MNIST: each class is a fixed smooth prototype (low-frequency random field,
seed-fixed) plus per-sample Gaussian noise and brightness jitter.  The paper's
phenomena — local overfitting / forgetting of unseen classes, consensus
recovery, oscillation damping — are properties of optimization under
class-partitioned data, not of MNIST pixels; EXPERIMENTS.md reports our
absolute numbers next to the paper's.

``token_stream`` — deterministic integer token batches for the LLM substrate.
"""
from __future__ import annotations

import numpy as np


def _smooth_field(rng: np.random.Generator, size: int = 28, cutoff: int = 6) -> np.ndarray:
    """Low-frequency random image in [0, 1] (smooth 'digit-like' blob)."""
    spec = np.zeros((size, size), np.complex128)
    spec[:cutoff, :cutoff] = rng.normal(size=(cutoff, cutoff)) + 1j * rng.normal(
        size=(cutoff, cutoff)
    )
    img = np.fft.ifft2(spec).real
    img = (img - img.min()) / (img.max() - img.min() + 1e-9)
    return img


def mnist_like(
    num_train: int = 60000,
    num_test: int = 10000,
    *,
    num_classes: int = 10,
    noise: float = 1.0,
    seed: int = 1234,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train (N,784) f32, y_train (N,) i32, x_test, y_test)."""
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth_field(rng) for _ in range(num_classes)])  # (C, 28, 28)

    def sample(n, rng):
        y = rng.integers(0, num_classes, size=n).astype(np.int32)
        base = protos[y]
        bright = rng.uniform(0.7, 1.3, size=(n, 1, 1))
        x = base * bright + rng.normal(scale=noise, size=base.shape)
        return x.reshape(n, -1).astype(np.float32), y

    x_tr, y_tr = sample(num_train, np.random.default_rng(seed + 1))
    x_te, y_te = sample(num_test, np.random.default_rng(seed + 2))
    return x_tr, y_tr, x_te, y_te


def token_stream(
    num_tokens: int, vocab_size: int, *, seed: int = 0, zipf_a: float = 1.2
) -> np.ndarray:
    """Zipf-ish token ids (more realistic softmax stats than uniform)."""
    rng = np.random.default_rng(seed)
    raw = rng.zipf(zipf_a, size=num_tokens)
    return np.minimum(raw - 1, vocab_size - 1).astype(np.int32)


def lm_batches(
    num_batches: int, batch: int, seq: int, vocab_size: int, *, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """(tokens, labels) of shape (num_batches, batch, seq): next-token LM."""
    stream = token_stream(num_batches * batch * (seq + 1), vocab_size, seed=seed)
    arr = stream.reshape(num_batches, batch, seq + 1)
    return arr[..., :-1].copy(), arr[..., 1:].copy()
