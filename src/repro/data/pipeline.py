"""Peer-stacked batch pipeline for the stacked P2P runtime.

Produces per-round batches of shape (T, K, B, ...) — step-major, then peer —
matching ``repro.core.p2p.local_phase``.  Each peer cycles through its own
local dataset with per-peer reshuffling at epoch boundaries (mini-batch SGD
as in the paper: B=10, one epoch = n_k/B iterations).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class PeerBatcher:
    """Cyclic per-peer mini-batch sampler over heterogeneous local datasets."""

    def __init__(
        self,
        parts: list[tuple[np.ndarray, np.ndarray]],
        batch_size: int,
        *,
        seed: int = 0,
        reshuffle: bool = True,
    ):
        self.parts = parts
        self.b = batch_size
        self.reshuffle = reshuffle
        self.rngs = [np.random.default_rng(seed + 7 * k) for k in range(len(parts))]
        self.orders = [rng.permutation(len(p[0])) for rng, p in zip(self.rngs, parts)]
        self.cursors = [0] * len(parts)

    @property
    def num_peers(self) -> int:
        return len(self.parts)

    def _next_indices(self, k: int) -> np.ndarray:
        n = len(self.parts[k][0])
        if n < self.b:
            # sample with replacement when the local set is tiny
            return self.rngs[k].integers(0, n, size=self.b)
        if self.cursors[k] + self.b > n:
            self.cursors[k] = 0
            if self.reshuffle:
                self.orders[k] = self.rngs[k].permutation(n)
        sel = self.orders[k][self.cursors[k] : self.cursors[k] + self.b]
        self.cursors[k] += self.b
        return sel

    def round_batches(self, local_steps: int) -> tuple[np.ndarray, np.ndarray]:
        """Batches for one round: (x (T,K,B,F), y (T,K,B))."""
        xs, ys = [], []
        for _t in range(local_steps):
            bx, by = [], []
            for k in range(self.num_peers):
                sel = self._next_indices(k)
                bx.append(self.parts[k][0][sel])
                by.append(self.parts[k][1][sel])
            xs.append(np.stack(bx))
            ys.append(np.stack(by))
        return np.stack(xs), np.stack(ys)

    def rounds(self, num_rounds: int, local_steps: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        for _ in range(num_rounds):
            yield self.round_batches(local_steps)


def global_to_peer_batch(x: np.ndarray, num_peers: int) -> np.ndarray:
    """Split a global batch along axis 0 into a leading peer axis."""
    b = x.shape[0]
    assert b % num_peers == 0, f"global batch {b} not divisible by {num_peers} peers"
    return x.reshape(num_peers, b // num_peers, *x.shape[1:])
