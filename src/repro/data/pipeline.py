"""Peer-stacked batch pipeline for the stacked P2P runtime.

Produces per-round batches of shape (T, K, B, ...) — step-major, then peer —
matching ``repro.core.p2p.local_phase``.  Each peer cycles through its own
local dataset with per-peer reshuffling at epoch boundaries (mini-batch SGD
as in the paper: B=10, one epoch = n_k/B iterations).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class PeerBatcher:
    """Cyclic per-peer mini-batch sampler over heterogeneous local datasets."""

    def __init__(
        self,
        parts: list[tuple[np.ndarray, np.ndarray]],
        batch_size: int,
        *,
        seed: int = 0,
        reshuffle: bool = True,
    ):
        self.parts = parts
        self.b = batch_size
        self.reshuffle = reshuffle
        self.rngs = [np.random.default_rng(seed + 7 * k) for k in range(len(parts))]
        self.orders = [rng.permutation(len(p[0])) for rng, p in zip(self.rngs, parts)]
        self.cursors = [0] * len(parts)

    @property
    def num_peers(self) -> int:
        return len(self.parts)

    def _next_indices(self, k: int) -> np.ndarray:
        n = len(self.parts[k][0])
        if n < self.b:
            # sample with replacement when the local set is tiny
            return self.rngs[k].integers(0, n, size=self.b)
        if self.cursors[k] + self.b > n:
            self.cursors[k] = 0
            if self.reshuffle:
                self.orders[k] = self.rngs[k].permutation(n)
        sel = self.orders[k][self.cursors[k] : self.cursors[k] + self.b]
        self.cursors[k] += self.b
        return sel

    def round_batches(self, local_steps: int) -> tuple[np.ndarray, np.ndarray]:
        """Batches for one round: (x (T,K,B,F), y (T,K,B))."""
        xs, ys = [], []
        for _t in range(local_steps):
            bx, by = [], []
            for k in range(self.num_peers):
                sel = self._next_indices(k)
                bx.append(self.parts[k][0][sel])
                by.append(self.parts[k][1][sel])
            xs.append(np.stack(bx))
            ys.append(np.stack(by))
        return np.stack(xs), np.stack(ys)

    def rounds(self, num_rounds: int, local_steps: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        for _ in range(num_rounds):
            yield self.round_batches(local_steps)


def images_to_tokens(
    x: np.ndarray,
    *,
    num_bins: int = 16,
    pool: int = 2,
    side: int = 28,
) -> np.ndarray:
    """Flat images (N, side*side) f32 -> pixel-stream tokens (N, L) int32.

    The sequential-MNIST transform: ``pool`` x ``pool`` average pooling
    (784 -> 196 positions at the default), then each pooled intensity is
    quantized into one of ``num_bins`` levels over a FIXED affine range — a
    dataset constant, not a per-batch statistic, so the same pixel always
    maps to the same token and train/eval tokenizations agree.  The range
    [-3, 4] covers ``synthetic.mnist_like``'s prototype * brightness + unit
    Gaussian noise; values outside clip into the edge bins.
    """
    if side % pool:
        raise ValueError(f"pool={pool} does not divide side={side}")
    n = x.shape[0]
    imgs = np.asarray(x, np.float32).reshape(n, side, side)
    if pool > 1:
        s = side // pool
        imgs = imgs.reshape(n, s, pool, s, pool).mean(axis=(2, 4))
    lo, hi = -3.0, 4.0
    u = np.clip((imgs - lo) / (hi - lo), 0.0, np.nextafter(1.0, 0.0))
    return np.floor(u * num_bins).astype(np.int32).reshape(n, -1)


class TokenSequenceBatcher:
    """``PeerBatcher`` for sequence models: image shards, token batches.

    Tokenizes each peer's shard ONCE up front (``images_to_tokens``), then
    delegates sampling to an inner ``PeerBatcher`` — identical cursor /
    reshuffle / seed behavior, so sequence tasks see the same epoch structure
    as the MLP.  ``round_batches(T)`` returns ``(tokens (T, K, B, L) int32,
    labels (T, K, B) int32)`` — the same two-leaf tuple contract, so the
    drivers' stacking and scan-chunk reshapes apply unchanged.
    """

    def __init__(
        self,
        parts: list[tuple[np.ndarray, np.ndarray]],
        batch_size: int,
        *,
        seed: int = 0,
        reshuffle: bool = True,
        num_bins: int = 16,
        pool: int = 2,
    ):
        tok_parts = [
            (images_to_tokens(px, num_bins=num_bins, pool=pool),
             np.asarray(py, np.int32))
            for px, py in parts
        ]
        self.inner = PeerBatcher(tok_parts, batch_size, seed=seed,
                                 reshuffle=reshuffle)

    @property
    def num_peers(self) -> int:
        return self.inner.num_peers

    def round_batches(self, local_steps: int) -> tuple[np.ndarray, np.ndarray]:
        return self.inner.round_batches(local_steps)

    def rounds(self, num_rounds: int, local_steps: int):
        return self.inner.rounds(num_rounds, local_steps)


def global_to_peer_batch(x: np.ndarray, num_peers: int) -> np.ndarray:
    """Split a global batch along axis 0 into a leading peer axis."""
    b = x.shape[0]
    assert b % num_peers == 0, f"global batch {b} not divisible by {num_peers} peers"
    return x.reshape(num_peers, b // num_peers, *x.shape[1:])
