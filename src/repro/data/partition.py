"""Dataset partitioning across peers: IID, pathological non-IID, Dirichlet.

The paper's settings:
- IID (Sec. V-A): "randomly shuffle and equally partition" into K local sets.
- Pathological non-IID (Sec. V-B): each device sees only a subset of classes
  ("device A trains on 50 samples from class 0 and 50 from class 1 while
  device B trains on 50 from class 7 and 50 from class 8").
Dirichlet(alpha) is the standard in-between used by the federated literature.
"""
from __future__ import annotations

import numpy as np


def iid_partition(
    x: np.ndarray, y: np.ndarray, num_peers: int, *, seed: int = 0
) -> list[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n_per = len(x) // num_peers
    return [
        (x[idx[k * n_per : (k + 1) * n_per]], y[idx[k * n_per : (k + 1) * n_per]])
        for k in range(num_peers)
    ]


def pathological_partition(
    x: np.ndarray,
    y: np.ndarray,
    peer_classes: list[tuple[int, ...]],
    *,
    samples_per_class: int | None = None,
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Each peer k gets samples only from peer_classes[k].

    samples_per_class=None takes *all* samples of that class (Figs. 4-6 use
    "all samples from classes ..."); an int takes that many (Fig. 3 uses 50).
    """
    rng = np.random.default_rng(seed)
    out = []
    for classes in peer_classes:
        xs, ys = [], []
        for c in classes:
            idx = np.nonzero(y == c)[0]
            idx = rng.permutation(idx)
            if samples_per_class is not None:
                idx = idx[:samples_per_class]
            xs.append(x[idx])
            ys.append(y[idx])
        xk, yk = np.concatenate(xs), np.concatenate(ys)
        perm = rng.permutation(len(xk))
        out.append((xk[perm], yk[perm]))
    return out


def dirichlet_partition(
    x: np.ndarray, y: np.ndarray, num_peers: int, *, alpha: float = 0.5, seed: int = 0
) -> list[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    peer_idx: list[list[int]] = [[] for _ in range(num_peers)]
    for c in classes:
        idx = rng.permutation(np.nonzero(y == c)[0])
        props = rng.dirichlet([alpha] * num_peers)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            peer_idx[k].extend(part.tolist())
    out = []
    for k in range(num_peers):
        sel = rng.permutation(np.asarray(peer_idx[k], dtype=int))
        out.append((x[sel], y[sel]))
    return out


def data_sizes(parts: list[tuple[np.ndarray, np.ndarray]]) -> np.ndarray:
    return np.asarray([len(p[0]) for p in parts], dtype=np.int64)
