"""Dataset partitioning across peers: IID, pathological non-IID, Dirichlet.

The paper's settings:
- IID (Sec. V-A): "randomly shuffle and equally partition" into K local sets.
- Pathological non-IID (Sec. V-B): each device sees only a subset of classes
  ("device A trains on 50 samples from class 0 and 50 from class 1 while
  device B trains on 50 from class 7 and 50 from class 8").
Dirichlet(alpha) is the standard in-between used by the federated literature.
"""
from __future__ import annotations

import numpy as np


def iid_partition(
    x: np.ndarray, y: np.ndarray, num_peers: int, *, seed: int = 0
) -> list[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    # len(x) % num_peers samples go one-each to the first peers, so the union
    # of the parts is the whole dataset (data-weighted mixing sums to N).
    n_per, extra = divmod(len(x), num_peers)
    out = []
    start = 0
    for k in range(num_peers):
        stop = start + n_per + (1 if k < extra else 0)
        out.append((x[idx[start:stop]], y[idx[start:stop]]))
        start = stop
    return out


def pathological_partition(
    x: np.ndarray,
    y: np.ndarray,
    peer_classes: list[tuple[int, ...]],
    *,
    samples_per_class: int | None = None,
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Each peer k gets samples only from peer_classes[k].

    samples_per_class=None takes *all* samples of that class (Figs. 4-6 use
    "all samples from classes ..."); an int takes that many (Fig. 3 uses 50).
    """
    rng = np.random.default_rng(seed)
    present = np.unique(y)
    for classes in peer_classes:
        for c in classes:
            if c not in present:
                raise ValueError(
                    f"peer_classes references class {c!r} which does not occur "
                    f"in y (present classes: {present.tolist()})"
                )
    out = []
    for classes in peer_classes:
        xs, ys = [], []
        for c in classes:
            idx = np.nonzero(y == c)[0]
            idx = rng.permutation(idx)
            if samples_per_class is not None:
                idx = idx[:samples_per_class]
            xs.append(x[idx])
            ys.append(y[idx])
        xk, yk = np.concatenate(xs), np.concatenate(ys)
        perm = rng.permutation(len(xk))
        out.append((xk[perm], yk[perm]))
    return out


def dirichlet_partition(
    x: np.ndarray, y: np.ndarray, num_peers: int, *, alpha: float = 0.5, seed: int = 0
) -> list[tuple[np.ndarray, np.ndarray]]:
    if len(x) < num_peers:
        raise ValueError(
            f"dirichlet_partition needs at least one sample per peer: "
            f"len(x)={len(x)} < num_peers={num_peers}"
        )
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    peer_idx: list[list[int]] = [[] for _ in range(num_peers)]
    for c in classes:
        idx = rng.permutation(np.nonzero(y == c)[0])
        props = rng.dirichlet([alpha] * num_peers)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            peer_idx[k].extend(part.tolist())
    # Small alpha concentrates each class on one peer, and the integer cuts
    # above can collide outright — either way a peer can end up empty.  An
    # empty peer is a zero row in the data-weighted mixing matrix and a NaN
    # factory in the n_p/(n_k+n_p) affinity terms, so rebalance: move one
    # sample from the currently-largest peer until every peer has >= 1.
    sizes = np.asarray([len(p) for p in peer_idx])
    while (sizes == 0).any():
        dst = int(np.argmin(sizes))
        src = int(np.argmax(sizes))
        peer_idx[dst].append(peer_idx[src].pop())
        sizes[dst] += 1
        sizes[src] -= 1
    out = []
    for k in range(num_peers):
        sel = rng.permutation(np.asarray(peer_idx[k], dtype=int))
        out.append((x[sel], y[sel]))
    return out


def data_sizes(parts: list[tuple[np.ndarray, np.ndarray]]) -> np.ndarray:
    return np.asarray([len(p[0]) for p in parts], dtype=np.int64)
