"""rwkv6-7b — Finch, data-dependent decay [arXiv:2404.05892].

[ssm] 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
Attention-free; long_500k runs natively (O(1) recurrent decode state).
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="rwkv6",
        num_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=65536,
        ssm=SSMConfig(kind="rwkv6", head_dim=64, lora_rank=64, chunk=16),
        tie_embeddings=False,
        citation="arXiv:2404.05892",
    )
