"""zamba2-2.7b — Mamba2 + shared attention blocks [arXiv:2411.15242].

[hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
54 Mamba2 layers; a single weight-shared attention+MLP block is applied every
6 layers (9 applications), consuming concat(x, embedding) per the Zamba design.
long_500k runs natively (SSM state decode; the shared block keeps a KV cache).
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        d_ff=10240,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=80),
        ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2, chunk=64),
        shared_block_period=6,
        tie_embeddings=True,
        citation="arXiv:2411.15242",
    )
