"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6 [arXiv:2405.04434].

[moe] 60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400, MoE 160e top-6.
MLA: kv_lora_rank=512, q_lora_rank=1536, qk_nope=128, qk_rope=64, v_head=128.
Layer 0 uses a dense MLP (d_ff 12288), layers 1..59 are MoE — per the model card.
Decode caches the latent (c_kv, k_rope); `mla_absorb=True` is the §Perf variant.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        d_ff=12288,  # dense-equivalent width (layer 0); experts use expert_ff
        vocab_size=102400,
        attention=AttentionConfig(
            num_heads=128,
            num_kv_heads=128,
            head_dim=128,
            kind="mla",
            kv_lora_rank=512,
            q_lora_rank=1536,
            qk_nope_dim=128,
            qk_rope_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            expert_ff=1536,
            num_shared=2,
            first_dense_layers=1,
            dense_ff=12288,
        ),
        tie_embeddings=False,
        citation="arXiv:2405.04434",
    )
