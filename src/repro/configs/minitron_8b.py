"""minitron-8b — pruned Nemotron [arXiv:2407.14679].

[dense] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
long_500k uses the sliding-window variant (window 4096) — see DESIGN.md.
"""
from repro.configs.base import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        d_ff=16384,
        vocab_size=256000,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
        tie_embeddings=False,
        citation="arXiv:2407.14679",
    )
