"""The paper's own workload: 2NN MLP on (synthetic-)MNIST under P2PL.

Sec. V hyperparameters: B=10, eta=0.01, mu=0.5 (IID) / 0 (non-IID),
T=60 gradient steps per round (IID, n_k=600) — one epoch per round,
data-size-weighted row-stochastic mixing, epsilon_k = 1.
"""
import dataclasses

from repro.core.p2p import P2PConfig


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    name: str
    p2p: P2PConfig
    batch_size: int = 10
    samples_per_class: int = 50
    rounds: int = 40
    seen_classes: tuple = ()
    peer_classes: tuple = ()  # tuple of per-peer class tuples (non-IID)


def iid_k100(topology: str = "complete") -> PaperExperiment:
    """Fig. 2: K=100, IID, 600 samples each, T=60, momentum 0.5."""
    return PaperExperiment(
        name=f"iid_k100_{topology}",
        p2p=P2PConfig(
            algorithm="p2pl",
            num_peers=100,
            local_steps=60,
            consensus_steps=1,
            lr=0.01,
            momentum=0.5,
            topology=topology,
            mixing="data_weighted",
        ),
        batch_size=10,
        rounds=100,
    )


def timevarying_k2(
    schedule: str = "link_dropout",
    algorithm: str = "p2pl_affinity",
    local_steps: int = 10,
    *,
    schedule_rounds: int = 16,
    link_survival_prob: float = 0.7,
    peer_online_prob: float = 0.8,
    schedule_seed: int = 0,
) -> PaperExperiment:
    """Beyond-paper: the K=2 non-IID workload over a churning link.

    With ``link_dropout`` the single A-B edge vanishes on ~(1-q) of rounds —
    those rounds behave like isolated training, so consensus (and the
    sawtooth) only happens when the link is up.  eta_d=0.5 for the affinity
    variant (observation O1: 1.0 is marginally stable at K=2 full averaging).
    """
    return PaperExperiment(
        name=f"timevarying_k2_{schedule}_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=2,
            local_steps=local_steps,
            consensus_steps=1,
            lr=0.01,
            momentum=0.0,
            eta_d=0.5,
            topology="complete",
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            link_survival_prob=link_survival_prob,
            peer_online_prob=peer_online_prob,
            schedule_seed=schedule_seed,
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=((0, 1), (7, 8)),
    )


def timevarying_k8(
    schedule: str = "random_matching",
    algorithm: str = "p2pl_affinity",
    local_steps: int = 10,
    *,
    schedule_rounds: int = 16,
    link_survival_prob: float = 0.7,
    peer_online_prob: float = 0.8,
    schedule_seed: int = 0,
) -> PaperExperiment:
    """Beyond-paper: 8 peers, 2 classes each, gossiping over a time-varying
    graph (pairwise random matchings, dropped links, or peer churn on a
    ring)."""
    peer_classes = tuple(((2 * k) % 10, (2 * k + 1) % 10) for k in range(8))
    return PaperExperiment(
        name=f"timevarying_k8_{schedule}_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=8,
            local_steps=local_steps,
            consensus_steps=1,
            lr=0.01,
            momentum=0.0,
            eta_d=0.5,
            topology="ring",
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            link_survival_prob=link_survival_prob,
            peer_online_prob=peer_online_prob,
            schedule_seed=schedule_seed,
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=peer_classes,
    )


def noniid_k2(algorithm: str = "local_dsgd", local_steps: int = 10) -> PaperExperiment:
    """Fig. 3cd/6: K=2, pathological non-IID (A: {0,1}, B: {7,8})."""
    return PaperExperiment(
        name=f"noniid_k2_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=2,
            local_steps=local_steps,
            consensus_steps=0 if algorithm == "isolated" else 1,
            lr=0.01,
            momentum=0.0,
            topology="disconnected" if algorithm == "isolated" else "complete",
            mixing="identity" if algorithm == "isolated" else "data_weighted",
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=((0, 1), (7, 8)),
    )
