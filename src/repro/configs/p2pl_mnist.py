"""The paper's own workload: 2NN MLP on (synthetic-)MNIST under P2PL.

Sec. V hyperparameters: B=10, eta=0.01, mu=0.5 (IID) / 0 (non-IID),
T=60 gradient steps per round (IID, n_k=600) — one epoch per round,
data-size-weighted row-stochastic mixing, epsilon_k = 1.
"""
import dataclasses

from repro.core.p2p import P2PConfig


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    name: str
    p2p: P2PConfig
    batch_size: int = 10
    samples_per_class: int = 50
    rounds: int = 40
    seen_classes: tuple = ()
    peer_classes: tuple = ()  # tuple of per-peer class tuples (non-IID)
    model: str = "mnist_mlp"  # one of core.task.task_names()

    def __post_init__(self):
        # the model is named in two places (the experiment, for the launcher
        # and data pipeline; the P2PConfig, for the feature table) — keep them
        # one value: a non-default on either side propagates to both, and two
        # CONFLICTING non-defaults are an error, not a silent pick
        if self.model != self.p2p.model:
            if self.model != "mnist_mlp" and self.p2p.model != "mnist_mlp":
                raise ValueError(
                    f"experiment model {self.model!r} conflicts with "
                    f"p2p.model {self.p2p.model!r}"
                )
            chosen = self.model if self.model != "mnist_mlp" else self.p2p.model
            object.__setattr__(self, "model", chosen)
            object.__setattr__(
                self, "p2p", dataclasses.replace(self.p2p, model=chosen)
            )


def iid_k100(*, topology: str = "complete") -> PaperExperiment:
    """Fig. 2: K=100, IID, 600 samples each, T=60, momentum 0.5."""
    return PaperExperiment(
        name=f"iid_k100_{topology}",
        p2p=P2PConfig(
            algorithm="p2pl",
            num_peers=100,
            local_steps=60,
            consensus_steps=1,
            lr=0.01,
            momentum=0.5,
            topology=topology,
            mixing="data_weighted",
        ),
        batch_size=10,
        rounds=100,
    )


def timevarying_k2(
    *,
    schedule: str = "link_dropout",
    algorithm: str = "p2pl_affinity",
    local_steps: int = 10,
    schedule_rounds: int = 16,
    link_survival_prob: float = 0.7,
    peer_online_prob: float = 0.8,
    schedule_seed: int = 0,
    protocol: str = "gossip",
    round_robin_topologies: tuple = ("complete", "disconnected"),
    partner_rule: str = "loss_proximity",
    adaptive_eps: float = 0.1,
    adaptive_seed: int = 0,
) -> PaperExperiment:
    """Beyond-paper: the K=2 non-IID workload over a churning link.

    With ``link_dropout`` the single A-B edge vanishes on ~(1-q) of rounds —
    those rounds behave like isolated training, so consensus (and the
    sawtooth) only happens when the link is up.  eta_d=0.5 for the affinity
    variant (observation O1: 1.0 is marginally stable at K=2 full averaging).
    """
    return PaperExperiment(
        name=f"timevarying_k2_{schedule}_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=2,
            local_steps=local_steps,
            consensus_steps=1,
            lr=0.01,
            momentum=0.0,
            eta_d=0.5,
            topology="complete",
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            link_survival_prob=link_survival_prob,
            peer_online_prob=peer_online_prob,
            schedule_seed=schedule_seed,
            protocol=protocol,
            round_robin_topologies=round_robin_topologies,
            partner_rule=partner_rule,
            adaptive_eps=adaptive_eps,
            adaptive_seed=adaptive_seed,
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=((0, 1), (7, 8)),
    )


def timevarying_k8(
    *,
    schedule: str = "random_matching",
    algorithm: str = "p2pl_affinity",
    local_steps: int = 10,
    schedule_rounds: int = 16,
    link_survival_prob: float = 0.7,
    peer_online_prob: float = 0.8,
    schedule_seed: int = 0,
    protocol: str = "gossip",
    round_robin_topologies: tuple = ("ring", "star"),
    partner_rule: str = "loss_proximity",
    adaptive_eps: float = 0.1,
    adaptive_seed: int = 0,
    compressor: str = "none",
    topk_frac: float = 0.01,
) -> PaperExperiment:
    """Beyond-paper: 8 peers, 2 classes each, gossiping over a time-varying
    graph (pairwise random matchings, dropped links, peer churn on a ring —
    or ``schedule="adaptive"``: pairwise matchings selected on device each
    round from the peers' own training losses)."""
    peer_classes = tuple(((2 * k) % 10, (2 * k + 1) % 10) for k in range(8))
    return PaperExperiment(
        name=f"timevarying_k8_{schedule}_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=8,
            local_steps=local_steps,
            consensus_steps=1,
            lr=0.01,
            momentum=0.0,
            eta_d=0.5,
            topology="ring",
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            link_survival_prob=link_survival_prob,
            peer_online_prob=peer_online_prob,
            schedule_seed=schedule_seed,
            protocol=protocol,
            round_robin_topologies=round_robin_topologies,
            partner_rule=partner_rule,
            adaptive_eps=adaptive_eps,
            adaptive_seed=adaptive_seed,
            compressor=compressor,
            topk_frac=topk_frac,
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=peer_classes,
    )


def directed_k8(
    *,
    schedule: str = "static",
    protocol: str = "push_sum",
    algorithm: str = "p2pl_affinity",
    local_steps: int = 10,
    schedule_rounds: int = 16,
    link_survival_prob: float = 0.7,
    schedule_seed: int = 0,
    partner_rule: str = "loss_proximity",
    adaptive_eps: float = 0.1,
    adaptive_seed: int = 0,
) -> PaperExperiment:
    """Beyond-paper: 8 non-IID peers on a DIRECTED ring — each peer only
    pushes forward (Sparse-Push-style one-way links).

    Row-stochastic gossip has no correct answer here (a directed round is not
    average-preserving); the default ``push_sum`` protocol carries a per-peer
    mass scalar whose ratio de-biases the estimates, so consensus still lands
    on the data-weighted average.  Schedules: ``static`` (the directed ring),
    ``link_dropout`` (each one-way link drops independently), or
    ``one_way_matching`` (random sender->receiver pairs each round).

    Shards are deliberately UNEQUAL and non-uniformly placed (the first half
    of the ring carries a third class: 150-sample peers feeding 100-sample
    peers): with uniform — or even alternating — sizes on a degree-regular
    directed ring the data-weighted row matrix is coincidentally unbiased
    (its stationary vector is exactly proportional to n) and push-sum
    degenerates to gossip; varying n_k + n_{k-1} around the ring is what
    makes the mass correction observable.
    """
    peer_classes = tuple(
        ((2 * k) % 10, (2 * k + 1) % 10, (2 * k + 2) % 10) if k < 4
        else ((2 * k) % 10, (2 * k + 1) % 10)
        for k in range(8)
    )
    return PaperExperiment(
        name=f"directed_k8_{schedule}_{protocol}_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=8,
            local_steps=local_steps,
            consensus_steps=1,
            lr=0.01,
            momentum=0.0,
            eta_d=0.5,
            topology="directed_ring",
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            link_survival_prob=link_survival_prob,
            schedule_seed=schedule_seed,
            protocol=protocol,
            partner_rule=partner_rule,
            adaptive_eps=adaptive_eps,
            adaptive_seed=adaptive_seed,
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=peer_classes,
    )


def sharded_k8(
    *,
    schedule: str = "static",
    protocol: str = "gossip",
    algorithm: str = "p2pl_affinity",
    local_steps: int = 10,
    topology: str = "ring",
    schedule_rounds: int = 16,
    link_survival_prob: float = 0.7,
    schedule_seed: int = 0,
    round_robin_topologies: tuple = ("ring", "star"),
    partner_rule: str = "loss_proximity",
    adaptive_eps: float = 0.1,
    adaptive_seed: int = 0,
) -> PaperExperiment:
    """The sharded peer-axis runtime's demo workload: 8 non-IID peers sized to
    CI's 8 forced host devices (``--peer-axis pod``).

    Same learning problem as ``timevarying_k8`` (2 classes per peer on a
    ring), but parameterized over protocol AND schedule so every runtime
    parity axis — gossip/push_sum x static/link_dropout/round_robin/
    one_way_matching — has a named entry point:

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            python -m repro.launch.train --experiment sharded_k8 --peer-axis pod
    """
    peer_classes = tuple(((2 * k) % 10, (2 * k + 1) % 10) for k in range(8))
    return PaperExperiment(
        name=f"sharded_k8_{schedule}_{protocol}_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=8,
            local_steps=local_steps,
            consensus_steps=1,
            lr=0.01,
            momentum=0.0,
            eta_d=0.5,
            topology=topology,
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            link_survival_prob=link_survival_prob,
            schedule_seed=schedule_seed,
            protocol=protocol,
            round_robin_topologies=round_robin_topologies,
            partner_rule=partner_rule,
            adaptive_eps=adaptive_eps,
            adaptive_seed=adaptive_seed,
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=peer_classes,
    )


def straggler_k8(
    *,
    schedule: str = "static",
    protocol: str = "gossip",
    algorithm: str = "p2pl_affinity",
    local_steps: int = 8,
    steps_profile: str = "straggler",
    staleness_bound: int = 3,
    staleness_decay: float = 0.5,
    straggler_frac: float = 0.25,
    straggler_period: int = 4,
    eta_d: float = 0.25,
    topology: str = "ring",
    schedule_rounds: int = 16,
    round_robin_topologies: tuple = ("ring", "star"),
) -> PaperExperiment:
    """Beyond-paper: 8 non-IID peers with heterogeneous compute (stragglers).

    Same learning problem as ``timevarying_k8`` (2 classes per peer on a
    ring), but the last quarter of the fleet is 4x slower: under the
    ``straggler`` compute profile they complete T/4 local steps per round and
    only publish every 4th round.  With ``staleness_bound=3`` their neighbors
    keep mixing the last *published* snapshot (age-decayed, renormalized per
    the active protocol) instead of blocking the fleet — the bounded-staleness
    async round of ``core/p2p.py``.  ``staleness_bound=0`` with a uniform
    profile recovers the synchronous round bit for bit.

    eta_d defaults to 0.25, HALF the sync experiments' 0.5: the affinity bias
    is a feedback loop through the neighbors' states, and snapshot delay eats
    its gain margin — at a 4-round staleness delay eta_d=0.5 diverges
    (exponential d growth, NaN by round ~50) while 0.25 stays stable, the
    same gain-margin arithmetic as observation O1's "eta_d=1.0 is marginally
    stable at K=2" but with the margin halved again by the delay.

        python -m repro.launch.train --experiment straggler_k8 \\
            --steps-profile straggler --staleness-bound 3 --rounds 8
    """
    peer_classes = tuple(((2 * k) % 10, (2 * k + 1) % 10) for k in range(8))
    return PaperExperiment(
        name=f"straggler_k8_{schedule}_{protocol}_{steps_profile}_b{staleness_bound}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=8,
            local_steps=local_steps,
            consensus_steps=1,
            lr=0.01,
            momentum=0.0,
            eta_d=eta_d,
            topology=topology,
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            round_robin_topologies=round_robin_topologies,
            protocol=protocol,
            steps_profile=steps_profile,
            staleness_bound=staleness_bound,
            staleness_decay=staleness_decay,
            straggler_frac=straggler_frac,
            straggler_period=straggler_period,
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=peer_classes,
    )


def noniid_k2(*, algorithm: str = "local_dsgd", local_steps: int = 10) -> PaperExperiment:
    """Fig. 3cd/6: K=2, pathological non-IID (A: {0,1}, B: {7,8})."""
    return PaperExperiment(
        name=f"noniid_k2_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=2,
            local_steps=local_steps,
            consensus_steps=0 if algorithm == "isolated" else 1,
            lr=0.01,
            momentum=0.0,
            topology="disconnected" if algorithm == "isolated" else "complete",
            mixing="identity" if algorithm == "isolated" else "data_weighted",
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=60,
        peer_classes=((0, 1), (7, 8)),
    )


def seqmnist_k8(
    *,
    schedule: str = "static",
    protocol: str = "gossip",
    algorithm: str = "p2pl",
    local_steps: int = 4,
    lr: float = 0.05,
    topology: str = "ring",
    rounds: int = 20,
    schedule_rounds: int = 16,
    round_robin_topologies: tuple = ("ring", "star"),
) -> PaperExperiment:
    """The first real-model workload: RWKV6 on sequential MNIST, 8 peers.

    Same non-IID shape as ``sharded_k8`` (2 classes per peer on a ring, sized
    to CI's 8 forced host devices) but the task is ``rwkv6_seqmnist``: each
    image becomes a 196-token pixel stream and every peer trains the reduced
    RWKV6 of ``core.task.seqmnist_model_config`` — so gossip and push_sum mix
    a real multi-layer parameter tree (embeddings, layernorms, time/channel
    mixes, LoRA decay projections), not the 2NN's four matrices.

    T=4 and lr=0.05: the recurrent trunk is ~50x the MLP's FLOPs per step,
    and plain SGD on the (max-norm-synced — algorithm="p2pl") init moves the
    cross-entropy reliably at 0.05 where 0.01 is visibly slow in 20 rounds.

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            python -m repro.launch.train --experiment seqmnist_k8 --rounds 4
    """
    peer_classes = tuple(((2 * k) % 10, (2 * k + 1) % 10) for k in range(8))
    return PaperExperiment(
        name=f"seqmnist_k8_{schedule}_{protocol}_{algorithm}_T{local_steps}",
        p2p=P2PConfig(
            algorithm=algorithm,
            num_peers=8,
            local_steps=local_steps,
            consensus_steps=1,
            lr=lr,
            momentum=0.0,
            topology=topology,
            mixing="data_weighted",
            schedule=schedule,
            schedule_rounds=schedule_rounds,
            round_robin_topologies=round_robin_topologies,
            protocol=protocol,
            model="rwkv6_seqmnist",
        ),
        batch_size=10,
        samples_per_class=50,
        rounds=rounds,
        peer_classes=peer_classes,
        model="rwkv6_seqmnist",
    )
