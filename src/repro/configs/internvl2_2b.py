"""internvl2-2b — InternViT + InternLM2 [arXiv:2404.16821].

[vlm] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The InternViT vision encoder + MLP projector is a stub per the carve-out:
input_specs() provides 256 precomputed patch embeddings (width 1024) per
sample, spliced as a prefix to the text tokens (text len = seq_len - 256).
"""
from repro.configs.base import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        d_ff=8192,
        vocab_size=92553,
        attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=128),
        num_prefix_embeddings=256,
        frontend_dim=1024,
        tie_embeddings=False,
        citation="arXiv:2404.16821",
    )
