"""Configuration dataclasses for models, input shapes, and runs."""
from __future__ import annotations

import dataclasses
from typing import Optional

FAMILIES = ("dense", "moe", "rwkv6", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    kind: str = "gqa"  # "gqa" | "mla"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # MLA (DeepSeek-V2) fields
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # long-context variant
    sliding_window: Optional[int] = None  # None = full causal
    # decode-path optimization (MLA only): weight-absorbed latent attention
    mla_absorb: bool = False
    # KV-cache storage: "model" dtype or "int8" (per-slot-per-head absmax
    # quantization; halves decode cache bytes, a §Perf serving feature)
    cache_quant: str = "model"

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.num_heads * self.head_dim

    @property
    def o_in_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * self.v_head_dim
        return self.num_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0
    first_dense_layers: int = 0  # leading layers use a dense MLP (DeepSeek-V2)
    dense_ff: int = 0  # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_groups: int = 1  # token groups for local routing (set to data-axis size at scale)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str  # "mamba2" | "rwkv6"
    state_dim: int = 64  # N (mamba2) / head dim of the WKV state (rwkv6)
    head_dim: int = 64  # P per head
    expand: int = 2  # d_inner = expand * d_model
    conv_dim: int = 4
    chunk: int = 64
    lora_rank: int = 32  # rwkv6 data-dependent decay / token-shift LoRA rank
    ngroups: int = 1  # mamba2 B/C groups


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a weight-shared attention block applied every N layers
    shared_block_period: int = 0
    # encoder-decoder (seamless-m4t)
    encoder_layers: int = 0
    # modality stubs: frontends provide precomputed embeddings of this width
    num_prefix_embeddings: int = 0  # VLM image patches / audio frames per sample
    frontend_dim: int = 0  # width of stub embeddings (projected to d_model)
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    act: str = "silu"
    dtype: str = "bfloat16"
    remat: bool = True
    citation: str = ""

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic total parameter count (used for roofline MODEL_FLOPS)."""
        d, l, v = self.d_model, self.num_layers, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        per_layer_attn = 0
        a = self.attention
        if a is not None:
            if a.kind == "mla":
                qd = a.q_lora_rank if a.q_lora_rank else 0
                if a.q_lora_rank:
                    per_layer_attn += d * a.q_lora_rank + a.q_lora_rank * a.q_dim
                else:
                    per_layer_attn += d * a.q_dim
                per_layer_attn += d * (a.kv_lora_rank + a.qk_rope_dim)
                per_layer_attn += a.kv_lora_rank * a.num_heads * (a.qk_nope_dim + a.v_head_dim)
                per_layer_attn += a.num_heads * a.v_head_dim * d
                del qd
            else:
                per_layer_attn += d * a.num_heads * a.head_dim  # q
                per_layer_attn += 2 * d * a.num_kv_heads * a.head_dim  # k, v
                per_layer_attn += a.num_heads * a.head_dim * d  # o
        if self.family == "rwkv6":
            s = self.ssm
            # time-mix: r,k,v,g,w projections + output + loras; channel-mix ~ d*d_ff*2
            per_layer = 5 * d * d + d * d + 6 * s.lora_rank * 2 * d + 2 * d * self.d_ff
            total += l * per_layer
            total += 2 * l * d  # norms
            return int(total)
        per_layer_mlp = 0
        if self.moe is not None:
            m = self.moe
            expert = 3 * d * m.expert_ff
            per_layer_mlp = m.num_experts * expert + m.num_shared * expert + d * m.num_experts
            moe_layers = l - m.first_dense_layers
            total += moe_layers * (per_layer_attn + per_layer_mlp + 2 * d)
            total += m.first_dense_layers * (per_layer_attn + 3 * d * m.dense_ff + 2 * d)
            return int(total)
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per_mamba = (
                d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
                + (d_in + 2 * s.ngroups * s.state_dim) * s.conv_dim
                + d_in * d
                + 2 * nheads
            )
            total += l * (per_mamba + 2 * d)
            if self.shared_block_period:
                # shared block (+concat proj)
                total += 2 * d * d + per_layer_attn + 3 * d * self.d_ff
            return int(total)
        per_layer_mlp = 3 * d * self.d_ff if self.act != "relu" else 2 * d * self.d_ff
        n_dec = l
        total += n_dec * (per_layer_attn + per_layer_mlp + 2 * d)
        if self.encoder_layers:
            # encoder layer = self-attn + mlp; decoder additionally has cross-attn
            total += self.encoder_layers * (per_layer_attn + per_layer_mlp + 2 * d)
            total += n_dec * (per_layer_attn + d)  # cross attention + norm
        if self.num_prefix_embeddings and self.frontend_dim:
            total += self.frontend_dim * d  # projector
        return int(total)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d, l = self.d_model, self.num_layers
        dense_like = self.replace(moe=None, family="dense")
        base = dense_like.param_count() - l * 3 * d * self.d_ff
        expert = 3 * d * m.expert_ff
        moe_layers = l - m.first_dense_layers
        active = base
        active += moe_layers * ((m.top_k + m.num_shared) * expert + d * m.num_experts)
        active += m.first_dense_layers * 3 * d * m.dense_ff
        return int(active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
