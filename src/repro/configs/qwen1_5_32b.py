"""qwen1.5-32b — QKV bias [hf:Qwen/Qwen1.5-0.5B scaled per assignment].

[dense] 64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
"""
from repro.configs.base import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        d_ff=27392,
        vocab_size=152064,
        attention=AttentionConfig(num_heads=40, num_kv_heads=40, head_dim=128, qkv_bias=True),
        tie_embeddings=False,
        citation="hf:Qwen/Qwen1.5-0.5B",
    )
