"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B scaled].

[moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        d_ff=1536,
        vocab_size=151936,
        attention=AttentionConfig(num_heads=64, num_kv_heads=4, head_dim=128),
        moe=MoEConfig(num_experts=128, top_k=8, expert_ff=1536),
        tie_embeddings=False,
        citation="hf:Qwen/Qwen3-30B-A3B",
    )
