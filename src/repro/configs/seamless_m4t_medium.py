"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596].

[audio] 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.
Backbone only: 12 encoder + 12 decoder layers; the mel-spectrogram + conv
feature extractor is a stub — input_specs() provides precomputed frame
embeddings (the one sanctioned carve-out).  Shape convention: for a
seq_len-S input shape, enc_len = S//4 frames and dec_len = S - S//4 tokens.
"""
from repro.configs.base import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        num_layers=12,
        encoder_layers=12,
        d_model=1024,
        d_ff=4096,
        vocab_size=256206,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64),
        frontend_dim=512,
        tie_embeddings=True,
        citation="arXiv:2308.11596",
    )
