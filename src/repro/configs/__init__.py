"""Config registry: ``get_config(name)``, ``reduced(cfg)``, input shapes."""
from __future__ import annotations

import dataclasses

from repro.configs import (
    deepseek_v2_236b,
    internvl2_2b,
    minitron_8b,
    phi4_mini_3_8b,
    qwen1_5_32b,
    qwen3_moe_235b_a22b,
    rwkv6_7b,
    seamless_m4t_medium,
    smollm_135m,
    zamba2_2_7b,
)
from repro.configs.base import (
    INPUT_SHAPES,
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
)

ARCHITECTURES = {
    "rwkv6-7b": rwkv6_7b.config,
    "minitron-8b": minitron_8b.config,
    "seamless-m4t-medium": seamless_m4t_medium.config,
    "deepseek-v2-236b": deepseek_v2_236b.config,
    "phi4-mini-3.8b": phi4_mini_3_8b.config,
    "zamba2-2.7b": zamba2_2_7b.config,
    "qwen1.5-32b": qwen1_5_32b.config,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b.config,
    "internvl2-2b": internvl2_2b.config,
    "smollm-135m": smollm_135m.config,
}

# Sliding-window size for the long_500k variant of attention-bearing archs.
LONG_CTX_WINDOW = 4096
# Families whose long_500k decode is natively sub-quadratic.
NATIVE_LONG_CTX_FAMILIES = ("rwkv6", "hybrid")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown architecture {name!r}; one of {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]()


def for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Adapt a config to an input shape (long-context window variant)."""
    if shape.name == "long_500k" and cfg.family not in NATIVE_LONG_CTX_FAMILIES:
        if cfg.attention is not None:
            att = dataclasses.replace(cfg.attention, sliding_window=LONG_CTX_WINDOW)
            cfg = cfg.replace(attention=att)
    return cfg


def reduced(cfg: ModelConfig) -> ModelConfig:
    """CPU-smoke variant of the same family: 2 layers, d_model<=256, <=4 experts."""
    kw: dict = dict(
        num_layers=2,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        remat=False,
        dtype="float32",
    )
    if cfg.attention is not None:
        if cfg.attention.kind == "mla":
            kw["attention"] = dataclasses.replace(
                cfg.attention,
                num_heads=4,
                num_kv_heads=4,
                head_dim=32,
                kv_lora_rank=32,
                q_lora_rank=48,
                qk_nope_dim=32,
                qk_rope_dim=16,
                v_head_dim=32,
            )
        else:
            kw["attention"] = dataclasses.replace(
                cfg.attention, num_heads=4, num_kv_heads=2, head_dim=32
            )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=2,
            expert_ff=64,
            num_shared=min(cfg.moe.num_shared, 1),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            dense_ff=128,
            # generous capacity: smoke tests check decode/prefill parity,
            # which capacity dropping would perturb
            capacity_factor=8.0,
        )
        kw["num_layers"] = 2 + kw["moe"].first_dense_layers
    if cfg.ssm is not None:
        if cfg.ssm.kind == "rwkv6":
            kw["ssm"] = dataclasses.replace(cfg.ssm, head_dim=32, lora_rank=8, chunk=4)
        else:
            kw["ssm"] = dataclasses.replace(
                cfg.ssm, state_dim=16, head_dim=32, expand=2, chunk=4
            )
    if cfg.family == "hybrid":
        kw["num_layers"] = 4
        kw["shared_block_period"] = 2
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    if cfg.num_prefix_embeddings:
        kw["num_prefix_embeddings"] = 4
        kw["frontend_dim"] = 32
    if cfg.frontend_dim and not cfg.num_prefix_embeddings:
        kw["frontend_dim"] = 32
    return cfg.replace(**kw)


__all__ = [
    "ARCHITECTURES",
    "AttentionConfig",
    "INPUT_SHAPES",
    "LONG_CTX_WINDOW",
    "ModelConfig",
    "MoEConfig",
    "NATIVE_LONG_CTX_FAMILIES",
    "SSMConfig",
    "ShapeConfig",
    "for_shape",
    "get_config",
    "reduced",
]
