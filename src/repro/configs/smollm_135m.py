"""smollm-135m — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

[dense] 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
The arch small enough to train for real on this CPU container.
"""
from repro.configs.base import AttentionConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        num_layers=30,
        d_model=576,
        d_ff=1536,
        vocab_size=49152,
        attention=AttentionConfig(num_heads=9, num_kv_heads=3, head_dim=64),
        tie_embeddings=True,
        citation="hf:HuggingFaceTB/SmolLM-135M",
    )
