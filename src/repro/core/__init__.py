"""Core: the paper's peer-to-peer learning + consensus algorithms."""
from repro.core.graph import CommGraph, build_graph, mixing_matrix, affinity_matrix, spectral_gap
from repro.core.p2p import (
    ALGORITHMS,
    P2PConfig,
    P2PState,
    init_state,
    local_phase,
    consensus_phase,
    run_round,
    make_round_fn,
    mixing_constants,
)
from repro.core import consensus
from repro.core.metrics import RoundLog

__all__ = [
    "ALGORITHMS",
    "CommGraph",
    "P2PConfig",
    "P2PState",
    "RoundLog",
    "affinity_matrix",
    "build_graph",
    "consensus",
    "consensus_phase",
    "init_state",
    "local_phase",
    "make_round_fn",
    "mixing_constants",
    "mixing_matrix",
    "run_round",
    "spectral_gap",
]
