"""Composable consensus protocols: how one gossip step moves parameters.

The paper hardwires Eq. 4 as a row-stochastic mix; this module turns that
choice into one instance of a ``ConsensusProtocol`` so the same runtime
(``repro.core.p2p``) can also run directed, Sparse-Push-style schedules where
a peer sends without receiving.  A protocol owns three things:

    init_state(params, data_sizes)  -> per-run protocol state (leading K axis)
    constants(schedule, mixing, ..) -> stacked (R, K, K) numpy round constants
    mix(proto_state, params, consts)-> one consensus step on the stacked params

``constants`` runs once on the host at setup; the jitted round function
closes over the stack and feeds ``mix`` one round's (K, K) slice selected by
``round_idx % R`` *inside* the traced program, preserving the
one-compile-per-run property for every protocol.

State layout per protocol (the ``P2PState.protocol`` leaf):

    gossip   — ``()``: stateless.  ``mix`` is the paper's row-stochastic
               einsum, bit-identical to the pre-protocol runtime.
    push_sum — ``PushSumState(mass=(K,) f32)``: each peer carries a scalar
               push-sum mass y_k.  ``mix`` re-biases the (always de-biased)
               parameters by y, pushes numerators and mass through the
               column-stochastic weights, and divides back:

                   num_k = sum_j A[k, j] * y_j * w_j
                   y_k'  = sum_j A[k, j] * y_j
                   w_k'  = num_k / y_k'

               Column-stochastic A conserves sum_k y_k == K on ANY directed
               or churning round, and w' converges to the mass-weighted
               average of the initial parameters wherever the schedule's
               union graph is strongly connected.  Data weighting enters
               through the mass init (y_k proportional to n_k), not through A —
               the push-sum limit depends only on the initial (numerator,
               mass) totals, never on the weights themselves.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus as consensus_lib
from repro.core import graph as graph_lib

PyTree = Any


class ProtocolConstants(NamedTuple):
    """Per-round mixing constants a protocol's ``mix`` consumes.

    ``w``/``beta`` are (R, K, K) stacks on the host (numpy) or device, or one
    round's (K, K) slice when already selected via ``round_constants``.  For
    gossip ``w`` is row-stochastic; for push_sum it is column-stochastic.
    """

    w: Any
    beta: Any


def round_constants(consts: ProtocolConstants, idx) -> ProtocolConstants:
    """Select round ``idx`` of a stacked (R, ...) constants tree (traceable)."""
    return ProtocolConstants(w=consts.w[idx], beta=consts.beta[idx])


def age_decayed_constants(
    consts: ProtocolConstants, decay: jax.Array, stochasticity: str
) -> ProtocolConstants:
    """One async round's renormalized age-decayed mixing constants.

    Bounded-staleness consensus (``p2p._consensus_phase_async``) mixes each
    SENDER j's last published snapshot with its weight scaled by ``decay[j]``
    (``staleness_decay ** age_j`` in (0, 1]; 1.0 = fresh).  Scaling alone
    would break stochasticity, so the freed mass is absorbed by the
    DIAGONAL — the one term that never rides the wire and is always fresh:

    * ``stochasticity="row"`` (gossip): off-diagonal entry (k, j) becomes
      ``w_kj * decay_j``; the diagonal is rebuilt as ``1 - sum_j'`` of the
      row's decayed off-diagonals, so every row still sums to 1 and the mix
      stays a convex combination (receivers lean toward their own live
      params when their in-neighbors are stale).
    * ``stochasticity="column"`` (push_sum): the same off-diagonal scaling,
      diagonal rebuilt from COLUMN sums — a stale sender keeps the mass it
      could not ship — so every column still sums to 1 and push-sum mass
      conservation (``sum_k y_k == K``) survives stale delivery exactly (up
      to one fp rounding of the ``1 - sum`` per column).

    ``beta`` (the affinity-average weights, rows summing to 1 over
    in-neighbors) is decayed per sender and then ROW-renormalized back to a
    distribution: the affinity average leans toward fresher neighbors but
    remains an average of received states.  Scaling without renormalizing
    would shrink ``nbr_avg`` toward the origin (rows summing to < 1), and
    the bias ``d = (nbr_avg - w) / T`` would then drag every parameter
    toward zero each local step — enough to stall learning outright on the
    straggler workload.  All-zero rows (isolated peers) stay zero.

    Args: ``consts`` — one round's (K, K) slice; ``decay`` — (K,) f32
    per-sender multipliers; ``stochasticity`` — the active protocol's
    declared normalization.  With ``decay == 1`` everywhere the result
    equals ``consts`` up to fp reassociation of the diagonal.
    """
    if stochasticity not in ("row", "column"):
        raise ValueError(f"unknown stochasticity {stochasticity!r}")
    w = consts.w.astype(jnp.float32)
    decay = decay.astype(jnp.float32)
    diag = jnp.diagonal(w)
    off = (w - jnp.diag(diag)) * decay[None, :]  # axis 1 indexes the sender
    axis = 1 if stochasticity == "row" else 0
    new_diag = 1.0 - jnp.sum(off, axis=axis)
    beta_d = consts.beta * decay[None, :]
    row_sums = jnp.sum(beta_d, axis=1, keepdims=True)
    beta = jnp.where(row_sums > 0, beta_d / jnp.where(row_sums > 0, row_sums, 1.0), 0.0)
    return ProtocolConstants(w=off + jnp.diag(new_diag), beta=beta)


class PushSumState(NamedTuple):
    """Per-peer push-sum mass y_k; sum_k y_k == K is conserved every round."""

    mass: jax.Array  # (K,) f32


class SparseRoundOps(NamedTuple):
    """One round of ``graph.SparseSchedule`` on device: the degree-bounded
    mixing operands the hierarchical runtime consumes.

    Full-K form (replicated across the mesh) or a device's row block — the
    leading axis is K or K/devices accordingly.  ``nbr_idx`` holds GLOBAL
    peer indices either way; padding slots point at the row's own index with
    weight 0.0.
    """

    self_w: jax.Array  # (K,) f32 — diagonal of W (row) / A (column)
    nbr_idx: jax.Array  # (K, D) int32 — in-neighbor global indices
    nbr_w: jax.Array  # (K, D) f32 — off-diagonal weights
    beta: jax.Array  # (K, D) f32 — affinity weights


class ConsensusProtocol:
    """Interface of one consensus-step rule over stacked (K, ...) parameters."""

    name: str = "base"
    # Whether the protocol's consensus point is unbiased on directed
    # (asymmetric-adjacency) schedules; the runtime warns when a
    # directed-incapable protocol is configured on a directed schedule.
    directed_capable: bool = False
    # Which stochasticity the protocol's ``w`` matrix obeys ("row" for
    # gossip-style averaging, "column" for push-sum mass splitting).  The
    # adaptive (state-dependent) schedule path reads this to build each
    # round's on-device matrices with the right normalization
    # (``graph.adaptive_round_matrices(..., stochasticity=...)``); the
    # pretraced path encodes the same choice inside ``constants``.
    stochasticity: str = "row"

    def init_state(self, params: PyTree, data_sizes: Sequence[int] | None = None) -> PyTree:
        """Per-run protocol state (a pytree carried in ``P2PState.protocol``)."""
        raise NotImplementedError

    def constants(
        self,
        schedule: graph_lib.GraphSchedule,
        mixing: str = "data_weighted",
        *,
        data_sizes: Sequence[int] | None = None,
        consensus_step_size: float | np.ndarray = 1.0,
    ) -> ProtocolConstants:
        """Stacked (R, K, K) numpy round constants for a whole schedule."""
        raise NotImplementedError

    def mix(
        self, proto_state: PyTree, params: PyTree, consts: ProtocolConstants
    ) -> tuple[PyTree, PyTree]:
        """One consensus step; returns (new proto_state, new params)."""
        raise NotImplementedError

    def mix_compressed(
        self,
        proto_state: PyTree,
        params: PyTree,
        params_hat: PyTree,
        consts: ProtocolConstants,
    ) -> tuple[PyTree, PyTree]:
        """``mix`` when receivers only see COMPRESSED neighbor payloads.

        ``params`` is the true stacked tree; ``params_hat`` the shared
        public-estimate stack every node reconstructs from the wire payloads
        (``repro.compression``, WARM-STARTED at the initial parameters).
        Implementations mix the CONVEX form: the self term — never on the
        wire — uses the TRUE parameters (diagonal weights x ``params``), the
        off-diagonal accumulation runs on the dense estimates.  This is a
        contraction of ``x`` toward values the estimates bound, so estimate
        lag cannot feed back into parameter growth; CHOCO's additive
        correction form ``x + (mix(x_hat) - x_hat_self)`` was tried here and
        diverges exponentially on the non-IID k8 workload at 1% top-k (the
        own-estimate error enters with a POSITIVE sign and compounds through
        local training).  Any protocol state (push-sum mass) rides
        UNCOMPRESSED — only parameter leaves are estimated.  Returns
        (new proto_state, new params).
        """
        raise NotImplementedError(
            f"protocol {self.name!r} does not implement the compressed mix"
        )

    def mix_sharded_begin(
        self,
        proto_state: PyTree,
        w_mat: jax.Array,
        *,
        axis_name: str,
        lanes,
    ) -> tuple[PyTree, Any]:
        """Per-consensus-step setup of the sharded mix, run ONCE per step.

        Everything that does not scale with the parameter leaves lives here:
        selecting this peer's weight row, and (for push_sum) ppermuting the
        scalar mass lane and computing the new mass.  Returns
        ``(new_proto_state, ctx)``; ``ctx`` is an opaque value consumed by
        ``mix_sharded_leaf`` for every parameter leaf of the step.  Splitting
        the step this way lets the runtime pipeline leaves — issue leaf
        ``i+1``'s ppermutes while leaf ``i``'s matvec is still running —
        without touching per-leaf arithmetic (the bit-parity contract).

        Protocols that predate this split (whole-tree ``mix_sharded``
        override only) need not implement it: the sharded runtime detects
        the base-class method and falls back to the unpipelined path.
        """
        raise NotImplementedError(
            f"protocol {self.name!r} implements neither mix_sharded_begin/"
            "mix_sharded_leaf (pipelined) nor a mix_sharded override (legacy)"
        )

    def mix_sharded_leaf(self, ctx, x_block: jax.Array, x_full: jax.Array) -> jax.Array:
        """One leaf of the sharded mix: this peer's row of ``mix``'s einsum.

        ``x_block`` is this peer's (1, ...) slice, ``x_full`` the (K, ...)
        reconstruction from ``consensus.gather_peer_leaf`` (zero rows for
        non-in-neighbors).  Must compute exactly the arithmetic of ``mix``
        restricted to this peer's row — the runtime's parity contract is fp32
        bit-identity with the vmap path.
        """
        raise NotImplementedError

    def mix_split_sharded_begin(
        self,
        proto_state: PyTree,
        w_mat: jax.Array,
        *,
        axis_name: str,
        lanes,
    ) -> tuple[PyTree, Any]:
        """Per-consensus-step setup of the sharded CONVEX-SPLIT mix.

        The sharded counterpart of ``mix_compressed``'s diagonal/off-diagonal
        split, used by bounded-staleness consensus
        (``p2p._consensus_phase_sharded_async``): the self term runs on this
        peer's TRUE (1, ...) block, the off-diagonal accumulation on a
        substitute (K, ...) stack (stale snapshots there; estimates would
        work the same way).  Implementations must mirror ``mix_compressed``
        operation for operation — this peer's row of the same off-diagonal
        einsum, the same elementwise self term, the same add order — so the
        pod async runtime stays fp32 bit-identical to the vmap async runtime
        (the ``mix``/``mix_sharded_leaf`` parity contract, restated for the
        split form).  Returns (new proto_state, ctx for
        ``mix_split_sharded_leaf``).
        """
        raise NotImplementedError(
            f"protocol {self.name!r} does not implement the sharded split mix"
        )

    def mix_split_sharded_leaf(
        self, ctx, x_block: jax.Array, sub_full: jax.Array
    ) -> jax.Array:
        """One leaf of the sharded convex-split mix.

        ``x_block`` is this peer's true (1, ...) slice; ``sub_full`` the
        (K, ...) substitute stack gathered over the schedule's lanes (zero
        rows for non-in-neighbors — they meet zero off-diagonal weights, so
        they contribute exactly +-0.0 like the dense form's absent edges).
        The own row of ``sub_full`` is never read: its weight lives on the
        diagonal, which multiplies ``x_block``.
        """
        raise NotImplementedError

    def mix_hier_begin(
        self,
        proto_state: PyTree,
        *,
        mode: str,
        axis_name: str,
        num_devices: int,
        dense_w: jax.Array | None = None,
        row0: jax.Array | None = None,
        block_size: int | None = None,
        ops_block: "SparseRoundOps | None" = None,
    ) -> tuple[PyTree, Any]:
        """Per-consensus-step setup of the HIERARCHICAL mix (vmap-within-
        device x shard_map), run once per step.

        ``mode`` selects the operand form and the neighbor-view convention
        that ``mix_hier_leaf`` will receive:

          "bridge"  — ``dense_w`` is the round's full (K, K) matrix
                      (losslessly densified from the sparse schedule),
                      ``row0``/``block_size`` this device's row window.
                      x_view is the all-gathered (K, ...) stack; the mix
                      replays the stacked runtime's FULL dense einsum and
                      slices this device's rows after the reduction — fp32
                      bit-identical to the stacked runtime (the K <= 64
                      lossless-conversion regime).
          "segment" — ``ops_block`` is this device's (K/devices)-row slice
                      of the round's ``SparseRoundOps``.  x_view is the
                      ring-gathered (p, D, ...) neighbor slots
                      (``consensus.ring_gather_slots``); the mix is a
                      weighted segment sum, O(K * D * feat / devices) memory
                      with no (K, K) or (K, feat) intermediate — the large-K
                      path (allclose to dense, not bitwise).
        """
        raise NotImplementedError(
            f"protocol {self.name!r} does not implement the hierarchical "
            "(peers_per_device > 1) mix"
        )

    def mix_hier_leaf(self, ctx, x_block: jax.Array, x_view: jax.Array) -> jax.Array:
        """One leaf of the hierarchical mix: this device's (p, ...) block of
        ``mix``'s output, from the block itself plus the mode's neighbor view
        (see ``mix_hier_begin``)."""
        raise NotImplementedError

    def mix_sharded(
        self,
        proto_state: PyTree,
        params: PyTree,
        params_full: PyTree,
        w_mat: jax.Array,
        *,
        axis_name: str,
        lanes,
    ) -> tuple[PyTree, PyTree]:
        """``mix`` inside a shard_map block of the sharded peer-axis runtime.

        ``params``/``proto_state`` leaves carry this peer's (1, ...) block of
        the stacked axis; ``params_full`` is the (K, ...) reconstruction from
        ``consensus.gather_peer_rows`` (zero rows for non-in-neighbors) and
        ``w_mat`` the round's full (K, K) protocol matrix (replicated — it is
        tiny next to the parameters).  Implemented via ``mix_sharded_begin`` +
        ``mix_sharded_leaf`` so the whole-tree and leaf-pipelined paths share
        one definition of the arithmetic.
        """
        proto_state, ctx = self.mix_sharded_begin(
            proto_state, w_mat, axis_name=axis_name, lanes=lanes
        )
        mixed = jax.tree.map(
            lambda b, f: self.mix_sharded_leaf(ctx, b, f), params, params_full
        )
        return proto_state, mixed


class GossipProtocol(ConsensusProtocol):
    """The paper's protocol: row-stochastic averaging (Eq. 4), stateless."""

    name = "gossip"

    def init_state(self, params: PyTree, data_sizes: Sequence[int] | None = None) -> PyTree:
        """Gossip carries no protocol state: always ``()``."""
        return ()

    def constants(
        self,
        schedule: graph_lib.GraphSchedule,
        mixing: str = "data_weighted",
        *,
        data_sizes: Sequence[int] | None = None,
        consensus_step_size: float | np.ndarray = 1.0,
    ) -> ProtocolConstants:
        """Row-stochastic (R, K, K) W/Beta stacks for the schedule."""
        w, beta = graph_lib.schedule_matrices(
            schedule, mixing, data_sizes=data_sizes,
            consensus_step_size=consensus_step_size,
        )
        return ProtocolConstants(w=w, beta=beta)

    def mix(
        self, proto_state: PyTree, params: PyTree, consts: ProtocolConstants
    ) -> tuple[PyTree, PyTree]:
        """One stacked mix step: ``W x`` per leaf (Eq. 4's averaging)."""
        return proto_state, consensus_lib.mix_stacked(consts.w, params)

    def mix_compressed(
        self,
        proto_state: PyTree,
        params: PyTree,
        params_hat: PyTree,
        consts: ProtocolConstants,
    ) -> tuple[PyTree, PyTree]:
        """Convex estimate-gossip: ``W_kk x_k + sum_{j != k} W_kj x_hat_j``.

        Row-stochastic W makes this a convex combination of the true own
        parameters and the (warm-started, payload-advanced) neighbor
        estimates — exactly ``W x`` once the estimates converge, and
        unconditionally bounded by them before that.
        """
        w = consts.w.astype(jnp.float32)
        diag = jnp.diagonal(w)  # (K,)
        w_off = w - jnp.diag(diag)

        def leaf(x, xh):
            feat = (1,) * (x.ndim - 1)
            own = diag.reshape((-1,) + feat) * x.astype(jnp.float32)
            nbr = jnp.einsum(
                "kj,j...->k...",
                w_off,
                xh.astype(jnp.float32),
                precision=jax.lax.Precision.HIGHEST,
            )
            return (own + nbr).astype(x.dtype)

        return proto_state, jax.tree.map(leaf, params, params_hat)

    def mix_sharded_begin(
        self,
        proto_state: PyTree,
        w_mat: jax.Array,
        *,
        axis_name: str,
        lanes,
    ) -> tuple[PyTree, Any]:
        """Per-round pod setup: this peer's (1, K) row of the mixing matrix."""
        my = jax.lax.axis_index(axis_name)
        w_row = jnp.take(w_mat, my, axis=0)[None]
        return proto_state, w_row

    def mix_sharded_leaf(self, ctx, x_block: jax.Array, x_full: jax.Array) -> jax.Array:
        """This peer's (1, K) x (K, ...) row of the stacked path's einsum."""
        return consensus_lib.mix_leaf(ctx, x_full)

    def mix_split_sharded_begin(
        self,
        proto_state: PyTree,
        w_mat: jax.Array,
        *,
        axis_name: str,
        lanes,
    ) -> tuple[PyTree, Any]:
        """Pod setup for the convex split mix: (off-diag row, own diagonal)."""
        my = jax.lax.axis_index(axis_name)
        w = w_mat.astype(jnp.float32)
        diag = jnp.diagonal(w)  # (K,)
        w_off = w - jnp.diag(diag)
        off_row = jnp.take(w_off, my, axis=0)[None]  # (1, K)
        diag_mine = jnp.take(diag, my)[None]  # (1,)
        return proto_state, (off_row, diag_mine)

    def mix_split_sharded_leaf(
        self, ctx, x_block: jax.Array, sub_full: jax.Array
    ) -> jax.Array:
        """``mix_compressed``'s leaf, operation for operation, on this row.

        ``own = diag * x_block`` (elementwise) plus the off-diagonal einsum's
        row over the substitute stack — bitwise the stacked path's row.
        """
        off_row, diag_mine = ctx
        feat = (1,) * (x_block.ndim - 1)
        own = diag_mine.reshape((-1,) + feat) * x_block.astype(jnp.float32)
        nbr = consensus_lib.mix_leaf(off_row, sub_full)
        return (own + nbr).astype(x_block.dtype)

    def mix_hier_begin(
        self,
        proto_state: PyTree,
        *,
        mode: str,
        axis_name: str,
        num_devices: int,
        dense_w: jax.Array | None = None,
        row0: jax.Array | None = None,
        block_size: int | None = None,
        ops_block: "SparseRoundOps | None" = None,
    ) -> tuple[PyTree, Any]:
        """Hierarchical-runtime setup: bridge (dense W) or segment weights."""
        if mode == "bridge":
            return proto_state, ("bridge", (dense_w, row0, block_size))
        return proto_state, ("segment", (ops_block.self_w, ops_block.nbr_w))

    def mix_hier_leaf(self, ctx, x_block: jax.Array, x_view: jax.Array) -> jax.Array:
        """Hierarchical mix per leaf: full-einsum-then-slice or slot sum."""
        tag, payload = ctx
        if tag == "bridge":
            # the stacked runtime's FULL (K, K) x (K, ...) einsum, then this
            # device's rows — slicing after the reduction keeps the bits
            w_mat, row0, p = payload
            full = consensus_lib.mix_leaf(w_mat, x_view)
            return jax.lax.dynamic_slice_in_dim(full, row0, p, axis=0)
        self_w, nbr_w = payload
        return consensus_lib.mix_slots(self_w, nbr_w, x_block, x_view)


class PushSumProtocol(ConsensusProtocol):
    """Directed push-sum gossip: column-stochastic weights + mass correction."""

    name = "push_sum"
    directed_capable = True
    stochasticity = "column"

    def init_state(
        self, params: PyTree, data_sizes: Sequence[int] | None = None
    ) -> PushSumState:
        """Initial (K,) mass: data-size-proportional, normalized to sum K."""
        k = jax.tree.leaves(params)[0].shape[0]
        if data_sizes is None:
            mass = np.ones(k)
        else:
            n = np.asarray(data_sizes, dtype=np.float64)
            if n.shape != (k,) or (n <= 0).any():
                raise ValueError("data_sizes must be positive, one per peer")
            # y_k proportional to n_k, normalized to sum K: the de-biased
            # estimates then converge to the data-weighted parameter average.
            mass = k * n / n.sum()
        return PushSumState(mass=jnp.asarray(mass, jnp.float32))

    def constants(
        self,
        schedule: graph_lib.GraphSchedule,
        mixing: str = "data_weighted",
        *,
        data_sizes: Sequence[int] | None = None,
        consensus_step_size: float | np.ndarray = 1.0,
    ) -> ProtocolConstants:
        """Column-stochastic (R, K, K) A/Beta stacks for the schedule."""
        w, beta = graph_lib.schedule_matrices(
            schedule, mixing, data_sizes=data_sizes,
            consensus_step_size=consensus_step_size, stochasticity="column",
        )
        return ProtocolConstants(w=w, beta=beta)

    def mix(
        self, proto_state: PushSumState, params: PyTree, consts: ProtocolConstants
    ) -> tuple[PushSumState, PyTree]:
        """One push-sum step: mass-biased averaging de-biased by ``y_new``."""
        a = consts.w.astype(jnp.float32)
        y = proto_state.mass.astype(jnp.float32)  # (K,)
        y_new = jnp.einsum("kj,j->k", a, y, precision=jax.lax.Precision.HIGHEST)

        def leaf(x):
            xf = x.astype(jnp.float32)
            biased = xf * y.reshape((-1,) + (1,) * (x.ndim - 1))
            num = jnp.einsum(
                "kj,j...->k...", a, biased, precision=jax.lax.Precision.HIGHEST
            )
            out = num / y_new.reshape((-1,) + (1,) * (x.ndim - 1))
            return out.astype(x.dtype)

        return PushSumState(mass=y_new), jax.tree.map(leaf, params)

    def mix_compressed(
        self,
        proto_state: PushSumState,
        params: PyTree,
        params_hat: PyTree,
        consts: ProtocolConstants,
    ) -> tuple[PushSumState, PyTree]:
        """Convex estimate-push-sum: the numerator's self term uses the true
        biased parameters, the off-diagonal terms the (warm-started) biased
        estimates; the (K,) mass and the resulting y' ride UNCOMPRESSED
        (mass conservation sum y == K stays exact)."""
        a = consts.w.astype(jnp.float32)
        diag = jnp.diagonal(a)  # (K,)
        a_off = a - jnp.diag(diag)
        y = proto_state.mass.astype(jnp.float32)  # (K,)
        y_new = jnp.einsum("kj,j->k", a, y, precision=jax.lax.Precision.HIGHEST)

        def leaf(x, xh):
            feat = (1,) * (x.ndim - 1)
            yf = y.reshape((-1,) + feat)
            own = diag.reshape((-1,) + feat) * (x.astype(jnp.float32) * yf)
            nbr = jnp.einsum(
                "kj,j...->k...",
                a_off,
                xh.astype(jnp.float32) * yf,
                precision=jax.lax.Precision.HIGHEST,
            )
            out = (own + nbr) / y_new.reshape((-1,) + feat)
            return out.astype(x.dtype)

        return PushSumState(mass=y_new), jax.tree.map(leaf, params, params_hat)

    def mix_sharded_begin(
        self,
        proto_state: PushSumState,
        w_mat: jax.Array,
        *,
        axis_name: str,
        lanes,
    ) -> tuple[PushSumState, Any]:
        """Row-restricted ``mix``, scalar part: the (K,) mass rides the same
        ppermute lanes as the parameters, once per consensus step.

        The scalar mass update runs the FULL (K, K) x (K,) matvec and keeps
        one row: a (1, K) x (K,) dot is too narrow for XLA to reduce in the
        same order as the stacked matvec, while the full product — on
        zero-padded masses whose foreign rows are discarded — shares its
        primitive shape and therefore its bits.
        """
        k = w_mat.shape[-1]
        my = jax.lax.axis_index(axis_name)
        a = w_mat.astype(jnp.float32)  # (K, K)
        a_row = jnp.take(a, my, axis=0)[None]  # (1, K)
        y = proto_state.mass.astype(jnp.float32)  # (1,)
        y_full = consensus_lib.gather_peer_rows(y, axis_name, lanes, k)  # (K,)
        y_new_all = jnp.einsum("kj,j->k", a, y_full, precision=jax.lax.Precision.HIGHEST)
        y_new = jnp.take(y_new_all, my)[None]  # (1,) — only our row is meaningful
        return PushSumState(mass=y_new), (a_row, y_full, y_new)

    def mix_sharded_leaf(self, ctx, x_block: jax.Array, x_full: jax.Array) -> jax.Array:
        """Row-restricted ``mix``, one parameter leaf.

        Mirrors ``mix`` operation for operation (f32 bias multiply, HIGHEST-
        precision einsums, divide, cast back) so the sharded runtime stays
        bit-identical to the stacked one.
        """
        a_row, y_full, y_new = ctx
        xf = x_full.astype(jnp.float32)
        # zero rows (non-in-neighbors) stay zero after the bias multiply,
        # and meet zero weights in a_row — contributing exactly +-0.0,
        # as in the dense einsum where the zero lives in A instead.
        biased = xf * y_full.reshape((-1,) + (1,) * (x_full.ndim - 1))
        num = jnp.einsum(
            "kj,j...->k...", a_row, biased, precision=jax.lax.Precision.HIGHEST
        )
        out = num / y_new.reshape((-1,) + (1,) * (x_full.ndim - 1))
        return out.astype(x_block.dtype)

    def mix_split_sharded_begin(
        self,
        proto_state: PushSumState,
        w_mat: jax.Array,
        *,
        axis_name: str,
        lanes,
    ) -> tuple[PushSumState, Any]:
        """Sharded split mix, scalar part: ``mix_compressed``'s mass update.

        The (K,) mass rides the schedule's lanes and the FULL (K, K) x (K,)
        matvec keeps one row — exactly ``mix_sharded_begin`` (the mass is
        never substituted) — plus this peer's slice of the numerator's
        diagonal/off-diagonal decomposition.
        """
        k = w_mat.shape[-1]
        my = jax.lax.axis_index(axis_name)
        a = w_mat.astype(jnp.float32)  # (K, K)
        diag = jnp.diagonal(a)  # (K,)
        a_off = a - jnp.diag(diag)
        off_row = jnp.take(a_off, my, axis=0)[None]  # (1, K)
        diag_mine = jnp.take(diag, my)[None]  # (1,)
        y = proto_state.mass.astype(jnp.float32)  # (1,)
        y_full = consensus_lib.gather_peer_rows(y, axis_name, lanes, k)  # (K,)
        y_new_all = jnp.einsum("kj,j->k", a, y_full, precision=jax.lax.Precision.HIGHEST)
        y_new = jnp.take(y_new_all, my)[None]  # (1,)
        return PushSumState(mass=y_new), (off_row, diag_mine, y, y_full, y_new)

    def mix_split_sharded_leaf(
        self, ctx, x_block: jax.Array, sub_full: jax.Array
    ) -> jax.Array:
        """Sharded split mix, one leaf: ``mix_compressed``'s numerator row.

        Self term on the true biased block, off-diagonal einsum row on the
        sender-mass-biased substitute stack, divided by the row's new mass —
        operation for operation the vmap expression, for fp32 bit-parity.
        """
        off_row, diag_mine, y, y_full, y_new = ctx
        feat = (1,) * (x_block.ndim - 1)
        own = diag_mine.reshape((-1,) + feat) * (
            x_block.astype(jnp.float32) * y.reshape((-1,) + feat)
        )
        biased = sub_full.astype(jnp.float32) * y_full.reshape((-1,) + feat)
        nbr = jnp.einsum(
            "kj,j...->k...", off_row, biased, precision=jax.lax.Precision.HIGHEST
        )
        out = (own + nbr) / y_new.reshape((-1,) + feat)
        return out.astype(x_block.dtype)

    def mix_hier_begin(
        self,
        proto_state: PushSumState,
        *,
        mode: str,
        axis_name: str,
        num_devices: int,
        dense_w: jax.Array | None = None,
        row0: jax.Array | None = None,
        block_size: int | None = None,
        ops_block: "SparseRoundOps | None" = None,
    ) -> tuple[PushSumState, Any]:
        """Hierarchical setup: advance the mass lane (bridge or segment)."""
        y = proto_state.mass.astype(jnp.float32)  # (p,) this device's masses
        if mode == "bridge":
            # Replay ``mix``'s FULL (K, K) x (K,) mass matvec on the gathered
            # masses and keep this device's rows — same reason the pod
            # runtime does (see ``mix_sharded_begin``): any narrower dot
            # reduces in a different order than the stacked matvec.  Bridge
            # mode is the K <= 64 parity regime, where the full (K, K) A is
            # exactly the dense path's footprint.
            a = dense_w.astype(jnp.float32)  # (K, K)
            y_full = jax.lax.all_gather(y, axis_name, axis=0, tiled=True)  # (K,)
            y_new_all = jnp.einsum(
                "kj,j->k", a, y_full, precision=jax.lax.Precision.HIGHEST
            )
            y_new = jax.lax.dynamic_slice_in_dim(
                y_new_all, row0, block_size, axis=0
            )
            return (
                PushSumState(mass=y_new),
                ("bridge", (a, y_full, y_new_all, row0, block_size)),
            )
        # segment: the (p, D) sender masses ride the same ring as the
        # parameter slots; weights pre-scaled by the sender's mass turn the
        # leaf mix into the push-sum numerator sum (the mass-lane trick of
        # kernels/consensus_mix/ops.py, block-sharded)
        y_slots = consensus_lib.ring_gather_slots(
            y, ops_block.nbr_idx, axis_name, num_devices
        )  # (p, D)
        self_w_y = ops_block.self_w * y
        nbr_w_y = ops_block.nbr_w * y_slots
        y_new = self_w_y + jnp.sum(nbr_w_y, axis=1)
        return PushSumState(mass=y_new), ("segment", (self_w_y, nbr_w_y, y_new))

    def mix_hier_leaf(self, ctx, x_block: jax.Array, x_view: jax.Array) -> jax.Array:
        """Hierarchical push-sum leaf: numerator mix divided by new mass."""
        tag, payload = ctx
        feat = (1,) * (x_block.ndim - 1)
        if tag == "bridge":
            # ``mix``'s full-K expression, operation for operation, then this
            # device's rows (the divide is elementwise — slicing after it is
            # exact)
            a, y_full, y_new_all, row0, p = payload
            xf = x_view.astype(jnp.float32)
            biased = xf * y_full.reshape((-1,) + feat)
            num = jnp.einsum(
                "kj,j...->k...", a, biased, precision=jax.lax.Precision.HIGHEST
            )
            out = num / y_new_all.reshape((-1,) + feat)
            return jax.lax.dynamic_slice_in_dim(out, row0, p, axis=0).astype(
                x_block.dtype
            )
        self_w_y, nbr_w_y, y_new = payload
        xf = x_block.astype(jnp.float32)
        slots = x_view.astype(jnp.float32)  # (p, D, ...)
        num = self_w_y.reshape((-1,) + feat) * xf + jnp.sum(
            nbr_w_y.reshape(nbr_w_y.shape + feat) * slots, axis=1
        )
        return (num / y_new.reshape((-1,) + feat)).astype(x_block.dtype)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ConsensusProtocol] = {}


def register_protocol(protocol: ConsensusProtocol) -> ConsensusProtocol:
    """Add a protocol instance to the registry (name must be unique)."""
    if not protocol.name or protocol.name == "base":
        raise ValueError("protocol needs a distinct name")
    if protocol.name in _REGISTRY:
        raise ValueError(f"protocol {protocol.name!r} already registered")
    _REGISTRY[protocol.name] = protocol
    return protocol


def get_protocol(name: str) -> ConsensusProtocol:
    """Look up a registered protocol by name (ValueError on unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown protocol {name!r}; one of {protocol_names()}"
        ) from None


def protocol_names() -> tuple[str, ...]:
    """Registered protocol names, in registration order."""
    return tuple(_REGISTRY)


register_protocol(GossipProtocol())
register_protocol(PushSumProtocol())
