"""Round-by-round measurement of the paper's phenomena.

The paper's central instrument is test accuracy evaluated at *both* phase
boundaries of every round (after local training, after consensus).  The
RoundLog accumulates those series plus drift metrics, and derives the
oscillation statistics quoted in Figs. 2-6.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np


@dataclasses.dataclass
class RoundLog:
    """Accumulates per-round measurements; numpy-only, serializable."""

    after_local: dict[str, list] = dataclasses.field(default_factory=dict)
    after_consensus: dict[str, list] = dataclasses.field(default_factory=dict)
    drift: list = dataclasses.field(default_factory=list)
    consensus_error: list = dataclasses.field(default_factory=list)
    train_loss: list = dataclasses.field(default_factory=list)

    def record(
        self,
        *,
        local_acc: dict[str, Any],
        consensus_acc: dict[str, Any],
        drift: float | None = None,
        consensus_error: float | None = None,
        train_loss: float | None = None,
    ) -> None:
        """Append one round's per-group accuracies and optional scalars."""
        for k, v in local_acc.items():
            self.after_local.setdefault(k, []).append(np.asarray(v, np.float64))
        for k, v in consensus_acc.items():
            self.after_consensus.setdefault(k, []).append(np.asarray(v, np.float64))
        if drift is not None:
            self.drift.append(float(drift))
        if consensus_error is not None:
            self.consensus_error.append(float(consensus_error))
        if train_loss is not None:
            self.train_loss.append(float(train_loss))

    # -- derived statistics -------------------------------------------------

    def series(self, group: str, phase: str = "consensus") -> np.ndarray:
        """(rounds, ...) stacked accuracy series for a group and phase."""
        src = self.after_consensus if phase == "consensus" else self.after_local
        return np.stack(src[group])  # (rounds, ...) device-mean applied by caller

    def oscillation(self, group: str) -> np.ndarray:
        """Per-round |after_consensus - after_local|, averaged over peers."""
        a = np.stack(self.after_local[group])
        c = np.stack(self.after_consensus[group])
        d = np.abs(c - a)
        return d.mean(axis=tuple(range(1, d.ndim))) if d.ndim > 1 else d

    def mean_oscillation(self, group: str, first_n: int | None = None) -> float:
        """Mean per-round oscillation, optionally over the first N rounds."""
        o = self.oscillation(group)
        return float(o[:first_n].mean()) if first_n else float(o.mean())

    def peak_to_trough(self, group: str) -> float:
        """Worst single-round oscillation (the '0% on unseen classes' events)."""
        return float(self.oscillation(group).max())

    def final_accuracy(self, group: str, phase: str = "consensus", last_n: int = 5) -> float:
        """Mean accuracy over the last ``last_n`` rounds (peer-averaged)."""
        s = self.series(group, phase)
        s = s.mean(axis=tuple(range(1, s.ndim))) if s.ndim > 1 else s
        return float(s[-last_n:].mean())

    def rounds_to_accuracy(self, group: str, threshold: float, phase: str = "consensus") -> int:
        """First round where min-over-peers accuracy crosses threshold (-1 if never)."""
        s = self.series(group, phase)
        s = s.min(axis=tuple(range(1, s.ndim))) if s.ndim > 1 else s
        hits = np.nonzero(s >= threshold)[0]
        return int(hits[0]) if len(hits) else -1

    def to_json(self) -> str:
        """Serialize every recorded series to a JSON string."""
        def conv(d):
            return {k: np.stack(v).tolist() for k, v in d.items()}

        return json.dumps(
            {
                "after_local": conv(self.after_local),
                "after_consensus": conv(self.after_consensus),
                "drift": self.drift,
                "consensus_error": self.consensus_error,
                "train_loss": self.train_loss,
            }
        )

    @staticmethod
    def from_json(s: str) -> "RoundLog":
        """Inverse of ``to_json``: rebuild a RoundLog from its JSON string."""
        raw = json.loads(s)
        log = RoundLog()
        log.after_local = {k: [np.asarray(r) for r in v] for k, v in raw["after_local"].items()}
        log.after_consensus = {
            k: [np.asarray(r) for r in v] for k, v in raw["after_consensus"].items()
        }
        log.drift = raw["drift"]
        log.consensus_error = raw["consensus_error"]
        log.train_loss = raw["train_loss"]
        return log
