"""The paper's algorithm family, as one parameterized implementation.

P2PL with Affinity (Sec. IV-A) subsumes every baseline in the paper:

    algorithm          T      S    momentum  max-norm-sync  d bias  b bias
    -----------------  -----  ---  --------  -------------  ------  ------
    dsgd               1      1    optional  no             0       0
    local_dsgd         T > 1  1    optional  no             0       0
    p2pl               T > 1  S    yes       yes            0       0
    p2pl_affinity      T > 1  S    optional  yes            yes     optional
    isolated           T > 1  0    optional  no             0       0

Learning phase (Eq. 3):   w <- w - eta * grad F_k(w) + eta_d * d_k
Consensus phase (Eq. 4):  w_k <- sum_j alpha_kj w_j + eta_b * b_k
Affinity biases (Sec. IV-A, "one possible choice", which Sec. V-C uses):
    d_k <- (1/T) sum_j beta_kj (w_j - w_k)   (computed during consensus)
    b_k <- (1/S) w_k                         (computed during local phase)

This module is the *stacked* runtime: every state leaf carries a leading K
(peer) axis.  Two execution modes share the math bit for bit:

  * ``make_round_fn`` — the K axis is vmapped (CPU experiments); the mix is a
    dense (K, K) einsum.
  * ``make_sharded_round_fn`` — the K axis is ``shard_map``'d over a real mesh
    (``peer_axis="pod"``): each mesh slice holds ONE peer's replica, local
    phases run embarrassingly parallel, and the schedule-aware mix lowers to
    ``ppermute`` sends along the round's edges (``graph.schedule_lanes``),
    leaf-pipelined so the next leaf's sends overlap the current leaf's mix.
    See repro/launch/train.py (``--peer-axis pod``) for the production path
    and repro/kernels/consensus_mix for the fused TPU kernel.

Both modes dispatch one jitted round per call; ``make_scan_driver`` wraps
EITHER round step in a ``lax.scan`` over a whole eval-period chunk of rounds
(donated state buffers, stacked per-round metrics) — one dispatch and at most
one host transfer per chunk, bit-identical results.

The consensus step itself is pluggable (``P2PConfig.protocol``, see
repro/core/protocols.py): ``gossip`` is the paper's row-stochastic mix and
keeps ``P2PState.protocol == ()`` (stateless, bit-identical to the
pre-protocol runtime); ``push_sum`` carries a per-peer scalar mass in
``P2PState.protocol`` (a ``PushSumState``) and runs column-stochastic
push-sum so *directed* and churning ``GraphSchedule``s average correctly.
Either way every round indexes the protocol's stacked (R, K, K) constants
with ``round_idx % R`` inside one jitted program.

Topologies themselves may be *state-dependent* (``cfg.schedule ==
"adaptive"``): instead of indexing a pretraced stack, the round step computes
its (K, K) W/Beta on device from the previous round's per-peer losses and a
PRNG key carried in ``P2PState.adaptive`` (an ``AdaptiveState``) via
``graph.adaptive_round_matrices`` — loss-proximity / random / eps-greedy
partner matching à la Onoszko et al., preserving the one-compile property in
all four {vmap, pod} x {python, scan} driver cells.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compression as compression_lib
from repro.core import consensus as consensus_lib
from repro.core import features as features_lib
from repro.core import graph as graph_lib
from repro.core import protocols as protocols_lib

PyTree = Any
LossFn = Callable[[PyTree, Any], jax.Array]  # (per-peer params, per-peer batch) -> scalar

ALGORITHMS = ("dsgd", "local_dsgd", "p2pl", "p2pl_affinity", "isolated")


def resolve_loss_fn(task_or_loss) -> LossFn:
    """A ``core.task.TrainTask`` or a bare loss callable -> the loss callable.

    Every driver entry point (``local_phase``, ``run_round``, ``make_*``)
    accepts either form; a task contributes exactly its ``loss_fn``
    attribute — no wrapper — so passing ``get_task("mnist_mlp")`` traces the
    IDENTICAL program as passing ``models.mlp.loss_2nn`` directly (the
    bit-parity contract of the legacy task).
    """
    loss_fn = getattr(task_or_loss, "loss_fn", None)
    return task_or_loss if loss_fn is None else loss_fn


def resolve_init_fn(task_or_init) -> Callable[[jax.Array], PyTree]:
    """A ``core.task.TrainTask`` or a bare per-peer init callable -> the init."""
    init_fn = getattr(task_or_init, "init_params", None)
    return task_or_init if init_fn is None else init_fn

# Config-declared per-peer compute profiles (``P2PConfig.steps_profile``):
# "uniform" is the bulk-synchronous baseline (every peer runs the full T local
# steps and publishes every round — structurally the legacy code path);
# "straggler" slows the last ``round(K * straggler_frac)`` peers down by
# ``straggler_period`` (fewer local steps per round, one publication every
# ``straggler_period`` rounds); "linear" spreads compute speeds linearly from
# 1 down to ``1 / straggler_period`` with every peer still publishing every
# round (heterogeneous steps only, no staleness).
STEPS_PROFILES = ("uniform", "straggler", "linear")


@dataclasses.dataclass(frozen=True)
class P2PConfig:
    """Hyperparameters of the P2PL-with-Affinity family."""

    algorithm: str = "p2pl_affinity"
    num_peers: int = 2
    local_steps: int = 1  # T
    consensus_steps: int = 1  # S
    lr: float = 0.01  # eta
    momentum: float = 0.0  # mu (PyTorch-default Polyak: buf = mu*buf + g; w -= lr*buf)
    eta_d: float = 1.0  # learning-phase bias step size
    eta_b: float = 0.0  # consensus-phase bias step size (paper's experiments: b = 0)
    topology: str = "complete"
    mixing: str = "data_weighted"
    consensus_step_size: float = 1.0  # epsilon_k
    max_norm_init: bool = False
    erdos_renyi_p: float = 0.3
    graph_seed: int = 0
    protocol: str = "gossip"  # one of protocols_lib.protocol_names()
    # -- time-varying communication (GraphSchedule) -------------------------
    schedule: str = "static"  # one of graph_lib.SCHEDULES, or "adaptive"
    schedule_rounds: int = 16  # period R of a stochastic schedule (cycled)
    link_survival_prob: float = 0.8  # q for schedule="link_dropout"
    peer_online_prob: float = 0.8  # for schedule="peer_churn"
    schedule_seed: int = 0
    round_robin_topologies: tuple[str, ...] = ()  # named topologies for "round_robin"
    # -- adaptive (state-dependent) partner selection, schedule="adaptive" --
    partner_rule: str = "loss_proximity"  # one of graph_lib.ADAPTIVE_RULES
    adaptive_eps: float = 0.1  # exploration probability for "eps_greedy"
    adaptive_seed: int = 0  # seeds the PRNG key threaded through P2PState
    # -- consensus-payload compression (repro/compression) ------------------
    compressor: str = "none"  # one of compression_lib.compressor_names()
    topk_frac: float = 0.01  # kept fraction per leaf for compressor="topk"
    # -- asynchronous rounds: compute profile + bounded-staleness gossip ----
    steps_profile: str = "uniform"  # one of STEPS_PROFILES
    staleness_bound: int = 0  # max snapshot age in rounds; 0 = synchronous
    staleness_decay: float = 0.5  # weight decay base per round of staleness
    straggler_frac: float = 0.25  # slow-peer fraction ("straggler" profile)
    straggler_period: int = 4  # slowdown factor of the slowest peer
    # -- training task (core/task.py registry): what the peers train --------
    model: str = "mnist_mlp"  # one of task.task_names()

    def __post_init__(self):
        """Validate the config and reject unsupported feature compositions."""
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.algorithm == "dsgd" and (self.local_steps != 1 or self.consensus_steps != 1):
            raise ValueError("dsgd fixes T = S = 1")
        if self.algorithm == "isolated" and self.consensus_steps != 0:
            raise ValueError("isolated fixes S = 0")
        if self.local_steps < 1:
            raise ValueError("need at least one local step per round")
        if self.protocol not in protocols_lib.protocol_names():
            raise ValueError(
                f"unknown protocol {self.protocol!r}; one of "
                f"{protocols_lib.protocol_names()}"
            )
        if self.schedule not in graph_lib.SCHEDULES + ("adaptive",):
            raise ValueError(
                f"unknown schedule {self.schedule!r}; one of "
                f"{graph_lib.SCHEDULES + ('adaptive',)}"
            )
        if self.schedule_rounds < 1:
            raise ValueError("schedule_rounds must be >= 1")
        if self.partner_rule not in graph_lib.ADAPTIVE_RULES:
            raise ValueError(
                f"unknown partner_rule {self.partner_rule!r}; one of "
                f"{graph_lib.ADAPTIVE_RULES}"
            )
        if not 0.0 <= self.adaptive_eps <= 1.0:
            raise ValueError("adaptive_eps must be in [0, 1]")
        if self.schedule == "adaptive" and self.num_peers < 2:
            raise ValueError("adaptive partner selection needs at least two peers")
        if self.compressor not in compression_lib.compressor_names():
            raise ValueError(
                f"unknown compressor {self.compressor!r}; one of "
                f"{compression_lib.compressor_names()}"
            )
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError("topk_frac must be in (0, 1]")
        if self.steps_profile not in STEPS_PROFILES:
            raise ValueError(
                f"unknown steps_profile {self.steps_profile!r}; one of "
                f"{STEPS_PROFILES}"
            )
        if self.staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0 (0 = synchronous)")
        if not 0.0 < self.staleness_decay <= 1.0:
            raise ValueError("staleness_decay must be in (0, 1]")
        if not 0.0 < self.straggler_frac <= 1.0:
            raise ValueError("straggler_frac must be in (0, 1]")
        if self.straggler_period < 1:
            raise ValueError("straggler_period must be >= 1")
        from repro.core import task as task_lib  # lazy: avoids import weight

        if self.model not in task_lib.task_names():
            raise ValueError(
                f"unknown model {self.model!r}; one of {task_lib.task_names()}"
            )
        # every pairwise composition rule lives in the ONE declarative table
        # (core/features.py) — config-level pairs fire here, runtime-level
        # pairs (e.g. x hierarchical) fire where peers_per_device is known
        features_lib.check_config(self)
        if self.schedule == "round_robin" and not self.round_robin_topologies:
            raise ValueError("round_robin schedule needs round_robin_topologies")
        object.__setattr__(
            self, "round_robin_topologies", tuple(self.round_robin_topologies)
        )
        for topo in self.round_robin_topologies:
            if not isinstance(topo, str):
                raise ValueError(
                    f"round_robin_topologies must be topology names, got {topo!r}"
                )
            if topo not in graph_lib.TOPOLOGIES:
                raise ValueError(
                    f"unknown round_robin topology {topo!r}; one of "
                    f"{graph_lib.TOPOLOGIES}"
                )

    @property
    def use_affinity_d(self) -> bool:
        """Whether the learning-phase affinity bias d (Eq. 3) is active."""
        return self.algorithm == "p2pl_affinity" and self.eta_d != 0.0

    @property
    def use_affinity_b(self) -> bool:
        """Whether the consensus-phase affinity bias b (Eq. 4) is active."""
        return self.algorithm == "p2pl_affinity" and self.eta_b != 0.0

    @property
    def use_max_norm_init(self) -> bool:
        """Whether peers synchronize to the max-norm init (Sec. IV-A)."""
        return self.max_norm_init or self.algorithm in ("p2pl", "p2pl_affinity")

    @property
    def use_async(self) -> bool:
        """Whether any asynchronous-round machinery is active.

        True iff the round is NOT the bulk-synchronous baseline: either
        consensus mixes bounded-staleness snapshots (``staleness_bound > 0``)
        or peers run heterogeneous local step counts (``steps_profile !=
        "uniform"``).  False means the legacy synchronous code path runs
        structurally unchanged (the fp32 bit-identity contract of
        ``staleness_bound=0``).
        """
        return self.staleness_bound > 0 or self.steps_profile != "uniform"


def compute_profile(cfg: P2PConfig) -> tuple[np.ndarray, np.ndarray]:
    """Per-peer compute profile of a config: ``(steps_k, period_k)``.

    Host-side (numpy, trace-time constant) arrays of shape (K,):

    ``steps_k``   int32 — local SGD steps peer k completes per round
                  (``<= cfg.local_steps``; the local-phase scan still runs
                  the full T iterations, peers past their budget hold their
                  parameters fixed so every runtime keeps one static shape).
    ``period_k``  int32 — rounds between peer k's snapshot publications: a
                  peer at speed ``1 / period_k`` finishes a local phase every
                  ``period_k`` rounds of fast-peer wall-clock.  Delivery is
                  additionally forced whenever a snapshot would otherwise
                  exceed ``cfg.staleness_bound`` rounds of age.

    Invariants: every entry of ``steps_k`` is >= 1 and every entry of
    ``period_k`` is >= 1; the "uniform" profile returns (T, 1) for every peer.
    """
    k, t = cfg.num_peers, cfg.local_steps
    steps = np.full((k,), t, np.int32)
    period = np.ones((k,), np.int32)
    if cfg.steps_profile == "straggler":
        n_slow = max(1, int(round(k * cfg.straggler_frac)))
        slow = np.arange(k) >= k - n_slow
        steps[slow] = max(1, t // cfg.straggler_period)
        period[slow] = cfg.straggler_period
    elif cfg.steps_profile == "linear":
        speed = np.linspace(1.0, 1.0 / cfg.straggler_period, k)
        steps = np.maximum(1, np.round(t * speed)).astype(np.int32)
    return steps, period


class AdaptiveState(NamedTuple):
    """Run state of the adaptive (state-dependent) partner selection.

    Both leaves carry the stacked leading K axis like every other state leaf
    (one row per peer in the vmap runtime, a (1, ...) block per mesh slice in
    the pod runtime), so the existing sharding specs, scan carry, and buffer
    donation apply unchanged:

    ``key``         (K, 2) uint32 — the PRNG key driving partner randomness,
                    replicated row-wise (every peer holds the SAME key, so all
                    peers derive the SAME matching with no extra traffic); one
                    split is consumed per round inside the jitted step.
    ``last_losses`` (K,) f32 — each peer's mean training loss of the previous
                    round, the selection signal of loss-proximity pairing.  In
                    the pod runtime this is the "cheap K-vector" exchanged per
                    round: one all_gather of K scalars.
    """

    key: jax.Array  # (K, 2) uint32, identical rows
    last_losses: jax.Array  # (K,) f32


class StalenessState(NamedTuple):
    """Bounded-staleness delivery buffer (``cfg.staleness_bound > 0``).

    Sender-side snapshot model: a straggling peer fails to publish to ALL of
    its out-neighbors at once, so one buffered snapshot per SENDER is exactly
    the per-neighbor "last received state" — every receiver of peer j holds
    the same stale copy — at O(params) instead of O(K * params) memory.  Both
    leaves carry the stacked leading K axis (a (1, ...) block per mesh slice
    in the pod runtime), so sharding specs, scan carry, and buffer donation
    apply unchanged:

    ``published``  params-shaped pytree — each sender's last published
                   parameter snapshot, the source of every OFF-diagonal
                   consensus term while the sender is between publications
                   (the self term always uses the receiver's live params).
    ``age``        (K,) int32 — rounds since each snapshot was taken.
                   Invariant: ``age <= cfg.staleness_bound`` after every
                   round (delivery is forced before the bound is crossed).
    """

    published: PyTree
    age: jax.Array  # (K,) int32


class P2PState(NamedTuple):
    """Stacked peer state; every leaf has leading axis K.

    ``protocol`` holds the consensus protocol's own state: ``()`` for gossip
    (stateless), ``protocols.PushSumState(mass=(K,))`` for push_sum — the
    per-peer scalar mass whose ratio de-biases the parameters.  It rides
    through the jitted round like any other leaf.  ``adaptive`` is ``()``
    unless ``cfg.schedule == "adaptive"``, in which case it carries the
    ``AdaptiveState`` (PRNG key + previous-round per-peer losses) that the
    round step consumes to build the round's topology on device.
    ``compression`` is ``()`` unless ``cfg.compressor != "none"``, in which
    case it carries the CHOCO-style public-estimate stack (zeros_like params
    at init): every node's dense running estimate of every peer's parameters,
    advanced by the decompressed payloads each consensus step — the
    error-feedback residual is implicitly ``params - estimate``.  In the
    sharded runtime this tree is REPLICATED per device, not peer-sharded
    (``sharding.specs.peer_stacked_pspecs`` special-cases it): receivers need
    every sender's estimate, and all replicas advance identically because
    they see the same payloads.
    ``staleness`` is ``()`` unless ``cfg.staleness_bound > 0``, in which case
    it carries the ``StalenessState`` (each sender's last published snapshot
    + its integer age) that bounded-staleness consensus mixes in place of the
    live neighbor parameters.  Unlike ``compression`` it IS peer-sharded in
    the pod runtime (published rows ride the same ppermute lanes as live
    parameters; only the (K,) ages are all-gathered).
    """

    params: PyTree
    momentum: PyTree
    d_bias: PyTree  # affinity learning-phase bias (Eq. 3)
    b_bias: PyTree  # affinity consensus-phase bias (Eq. 4)
    round_idx: jax.Array  # scalar int32
    protocol: PyTree = ()  # consensus-protocol state (see protocols.py)
    adaptive: PyTree = ()  # AdaptiveState for schedule="adaptive", else ()
    compression: PyTree = ()  # public-estimate stack for cfg.compressor != "none"
    staleness: PyTree = ()  # StalenessState for cfg.staleness_bound > 0, else ()


def build_schedule(cfg: P2PConfig) -> graph_lib.GraphSchedule:
    """The config's communication-graph schedule (period 1 for "static")."""
    build = lambda topo: graph_lib.build_graph(  # noqa: E731
        topo, cfg.num_peers, p=cfg.erdos_renyi_p, seed=cfg.graph_seed
    )
    if cfg.schedule == "adaptive":
        raise ValueError(
            "schedule='adaptive' has no pretraced graph sequence: each "
            "round's topology is computed on device from run state "
            "(graph.adaptive_round_matrices inside the jitted round step); "
            "there is no GraphSchedule to build"
        )
    if cfg.schedule == "static":
        return graph_lib.static_schedule(build(cfg.topology))
    if cfg.schedule == "link_dropout":
        return graph_lib.link_dropout_schedule(
            build(cfg.topology), cfg.link_survival_prob, cfg.schedule_rounds,
            seed=cfg.schedule_seed,
        )
    if cfg.schedule == "random_matching":
        return graph_lib.random_matching_schedule(
            cfg.num_peers, cfg.schedule_rounds, seed=cfg.schedule_seed
        )
    if cfg.schedule == "one_way_matching":
        return graph_lib.one_way_matching_schedule(
            cfg.num_peers, cfg.schedule_rounds, seed=cfg.schedule_seed
        )
    if cfg.schedule == "peer_churn":
        return graph_lib.peer_churn_schedule(
            build(cfg.topology), cfg.peer_online_prob, cfg.schedule_rounds,
            seed=cfg.schedule_seed,
        )
    # round_robin (validated in __post_init__)
    return graph_lib.round_robin_schedule(
        [build(t) for t in cfg.round_robin_topologies]
    )


def mixing_constants(
    cfg: P2PConfig, data_sizes: np.ndarray | None = None
) -> tuple[np.ndarray, np.ndarray, graph_lib.GraphSchedule]:
    """Stacked per-round row-stochastic (W, Beta, schedule) for a config.

    The pre-protocol entry point, equivalent to the gossip protocol's
    ``constants``: returns (R, K, K) numpy stacks — R = 1 for the static
    schedule — that the jitted round fn closes over and indexes with
    ``round_idx % R``, so a time-varying run still compiles exactly once.
    """
    sched = build_schedule(cfg)
    w, beta = graph_lib.schedule_matrices(
        sched, cfg.mixing, data_sizes=data_sizes,
        consensus_step_size=cfg.consensus_step_size,
    )
    return w, beta, sched


def protocol_constants(
    cfg: P2PConfig, data_sizes: np.ndarray | None = None
) -> tuple[protocols_lib.ProtocolConstants, graph_lib.GraphSchedule]:
    """Stacked (R, K, K) round constants of the config's consensus protocol."""
    sched = build_schedule(cfg)
    proto = protocols_lib.get_protocol(cfg.protocol)
    if sched.directed and not proto.directed_capable:
        warnings.warn(
            f"protocol {cfg.protocol!r} on a directed schedule "
            f"({sched.name!r}): a row-stochastic consensus point is biased on "
            "asymmetric graphs — use protocol='push_sum' unless the bias is "
            "deliberate",
            stacklevel=2,
        )
    consts = proto.constants(
        sched, cfg.mixing, data_sizes=data_sizes,
        consensus_step_size=cfg.consensus_step_size,
    )
    return consts, sched


def init_state(
    rng: jax.Array,
    init_fn: Callable[[jax.Array], PyTree],
    cfg: P2PConfig,
    data_sizes: np.ndarray | None = None,
) -> P2PState:
    """Independent per-peer init (PyTorch-style default), then optional max-norm sync.

    ``data_sizes`` seeds the protocol state — for push_sum, initial mass
    proportional to n_k makes the de-biased estimates track the
    *data-weighted* parameter average (uniform mass without it).

    ``init_fn`` may be a bare per-peer init callable or a
    ``core.task.TrainTask`` (its ``init_params`` is used).
    """
    init_fn = resolve_init_fn(init_fn)
    keys = jax.random.split(rng, cfg.num_peers)
    params = jax.vmap(init_fn)(keys)
    if cfg.use_max_norm_init:
        params = consensus_lib.max_norm_sync(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    proto = protocols_lib.get_protocol(cfg.protocol)
    adaptive: PyTree = ()
    if cfg.schedule == "adaptive":
        # every peer holds the SAME key (replicated rows), so all peers derive
        # the same matching each round; losses start at 0, so round 0's
        # loss-proximity matching is the deterministic tie-break pairing
        sel_key = jax.random.PRNGKey(cfg.adaptive_seed)
        adaptive = AdaptiveState(
            key=jnp.broadcast_to(sel_key[None, :], (cfg.num_peers, 2)),
            last_losses=jnp.zeros((cfg.num_peers,), jnp.float32),
        )
    comp = compression_lib.from_config(cfg)
    staleness: PyTree = ()
    if cfg.staleness_bound > 0:
        # warm start: every sender's first snapshot is its (possibly
        # max-norm-synced) init, age 0 — exactly what a synchronous round 0
        # would deliver.  jnp.copy, not an alias: the scan driver donates the
        # state, and a buffer appearing under two leaves cannot be donated
        staleness = StalenessState(
            published=jax.tree.map(jnp.copy, params),
            age=jnp.zeros((cfg.num_peers,), jnp.int32),
        )
    return P2PState(
        params=params,
        momentum=zeros,
        d_bias=jax.tree.map(jnp.zeros_like, params),
        b_bias=jax.tree.map(jnp.zeros_like, params),
        round_idx=jnp.zeros((), jnp.int32),
        protocol=proto.init_state(params, data_sizes),
        adaptive=adaptive,
        compression=comp.init_estimate(params),
        staleness=staleness,
    )


# ---------------------------------------------------------------------------
# Learning phase (Eq. 3)
# ---------------------------------------------------------------------------


def _local_phase_stats(
    state: P2PState,
    loss_fn: LossFn,
    batches: PyTree,
    cfg: P2PConfig,
    *,
    axis_name: str | None = None,
    steps_k: jax.Array | None = None,
) -> tuple[P2PState, jax.Array]:
    """``local_phase`` returning the full (T, K) per-step per-peer losses.

    The public ``local_phase`` reduces them to the (T,) per-step mean; the
    adaptive schedule path needs the K axis intact (each peer's mean loss is
    the next round's partner-selection signal), so the scan body lives here
    and both consumers apply their own reduction to the SAME materialized
    buffer — which is what keeps the reported losses bit-identical across the
    runtimes and drivers.

    ``axis_name`` is set by the sharded runtime, where K is a mesh axis and
    the leaves seen here are (1, ...) blocks: the (T, 1) per-step losses then
    all-gather the K per-peer scalars, so any later reduction runs over the
    same (T, K) buffer — and produces the same bits — as the vmap runtime.

    ``steps_k`` (int32, leading axis matching the stacked leaves: (K,) in the
    vmap runtime, this peer's (1,) block in the pod runtime) caps peer k at
    ``steps_k[k]`` local updates: the scan still runs the full T iterations —
    one static shape for every compute profile — but iterations at or past a
    peer's budget hold its parameters and momentum fixed (``jnp.where`` on
    the traced step index, so heterogeneous profiles share one compile).
    Losses keep reporting all T slots; a finished peer re-reports its frozen
    parameters' loss on each later step's batch.  ``None`` (the "uniform"
    profile) is the structurally unmasked legacy scan — the bit-identity
    baseline.
    """
    loss_fn = resolve_loss_fn(loss_fn)
    # one forward serves both the loss value and the gradient: cheaper than
    # separate vmap(loss)/vmap(grad) passes, and it pins the loss to the same
    # expression graph in the vmap and shard_map runtimes (a standalone
    # vmap(loss_fn) fuses differently at batch K than at batch 1, breaking
    # the runtimes' bit-parity contract on the reported losses)
    value_and_grad_fn = jax.value_and_grad(loss_fn)

    def step(carry, xs):
        params, mom = carry
        batch_t = xs if steps_k is None else xs[0]
        losses, grads = jax.vmap(value_and_grad_fn)(params, batch_t)
        if cfg.momentum:
            new_mom = jax.tree.map(lambda m, g: cfg.momentum * m + g, mom, grads)
            update = new_mom
        else:
            new_mom = mom
            update = grads
        if cfg.use_affinity_d:
            new_params = jax.tree.map(
                lambda w, u, d: w - cfg.lr * u + cfg.eta_d * d,
                params,
                update,
                state.d_bias,  # d fixed during the local phase (Sec. IV-A)
            )
        else:
            new_params = jax.tree.map(lambda w, u: w - cfg.lr * u, params, update)
        if steps_k is not None:
            active = xs[1] < steps_k  # (K,) or (1,) bool

            def keep(new, old):
                mask = active.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(mask, new, old)

            new_params = jax.tree.map(keep, new_params, params)
            if cfg.momentum:
                new_mom = jax.tree.map(keep, new_mom, mom)
        return (new_params, new_mom), losses

    xs = (
        batches
        if steps_k is None
        else (batches, jnp.arange(cfg.local_steps, dtype=jnp.int32))
    )
    (params, mom), losses = jax.lax.scan(step, (state.params, state.momentum), xs)
    # cross-peer reductions OUTSIDE the scan, on the materialized (T, K)
    # buffer: an in-scan mean compiles differently in the (XLA-peeled) first
    # iteration than in the loop body, so the vmap and shard_map runtimes
    # would disagree in the last ulp; out here both reduce identical buffers
    if axis_name is not None:
        losses = jax.lax.all_gather(losses, axis_name, axis=1, tiled=True)  # (T, K)

    # b <- (1/S) w (updated during local learning; fixed during consensus).
    b_bias = state.b_bias
    if cfg.use_affinity_b:
        s = max(cfg.consensus_steps, 1)
        b_bias = jax.tree.map(lambda w: w / s, params)

    return state._replace(params=params, momentum=mom, b_bias=b_bias), losses


def local_phase(
    state: P2PState,
    loss_fn: LossFn,
    batches: PyTree,
    cfg: P2PConfig,
    *,
    axis_name: str | None = None,
    steps_k: jax.Array | None = None,
) -> tuple[P2PState, jax.Array]:
    """Run up to T local steps on every peer.

    batches: pytree whose leaves are (T, K, ...) — step-major, then peer.
    ``steps_k`` (optional per-peer int32 budget, see ``_local_phase_stats``)
    caps how many of the T steps each peer applies.  Returns (new_state,
    per-step mean loss (T,)).
    """
    state, losses = _local_phase_stats(
        state, loss_fn, batches, cfg, axis_name=axis_name, steps_k=steps_k
    )
    return state, jnp.mean(losses, axis=1)  # (T,) per-step mean over peers


# ---------------------------------------------------------------------------
# Consensus phase (Eq. 4)
# ---------------------------------------------------------------------------


def consensus_phase(
    state: P2PState,
    cfg: P2PConfig,
    consts: protocols_lib.ProtocolConstants,
) -> P2PState:
    """Run S consensus steps of the config's protocol; updates the affinity
    bias d en route.

    ``consts`` is ONE round's (K, K) slice of the protocol constants (select
    it from the stacked schedule with ``protocols.round_constants``).  The
    affinity biases operate on the *de-biased* parameters for every protocol:
    gossip parameters are their own estimates, and push_sum's ``mix`` divides
    the mass back out before returning.
    """
    if cfg.consensus_steps == 0:
        return state._replace(round_idx=state.round_idx + 1)

    proto = protocols_lib.get_protocol(cfg.protocol)
    comp = compression_lib.from_config(cfg)
    if not comp.identity:
        return _consensus_phase_compressed(state, cfg, consts, proto, comp)
    if cfg.staleness_bound > 0:
        return _consensus_phase_async(state, cfg, consts, proto)
    params, d_bias, proto_state = state.params, state.d_bias, state.protocol
    # Peers whose beta row is all-zero (isolated this round — e.g. churned
    # out of a time-varying schedule) have no neighbors to be biased toward:
    # their d stays 0 rather than decaying toward the origin.
    has_nbrs = jnp.sum(consts.beta, axis=1) > 0  # (K,)
    for _ in range(cfg.consensus_steps):
        if cfg.use_affinity_d:
            # d_k <- (1/T) sum_j beta_kj (w_j - w_k), from the *incoming*
            # neighbor parameters of this consensus step (Sec. IV-A).
            nbr_avg = consensus_lib.mix_stacked(consts.beta, params)
            d_bias = jax.tree.map(
                lambda avg, w: jnp.where(
                    has_nbrs.reshape((-1,) + (1,) * (w.ndim - 1)),
                    (avg - w) / cfg.local_steps,
                    jnp.zeros_like(w),
                ),
                nbr_avg,
                params,
            )
        proto_state, mixed = proto.mix(proto_state, params, consts)
        if cfg.use_affinity_b:
            mixed = jax.tree.map(
                lambda m, b: m + cfg.eta_b * b, mixed, state.b_bias
            )
        params = mixed

    return state._replace(
        params=params, d_bias=d_bias, protocol=proto_state,
        round_idx=state.round_idx + 1,
    )


def _consensus_phase_compressed(
    state: P2PState,
    cfg: P2PConfig,
    consts: protocols_lib.ProtocolConstants,
    proto: protocols_lib.ConsensusProtocol,
    comp: compression_lib.Compressor,
) -> P2PState:
    """``consensus_phase`` when consensus messages cross a compressed wire.

    Each step: ship the compressed parameter-to-estimate difference
    (``C(x - x̂)``), advance the public-estimate stack in
    ``P2PState.compression`` by its decompression (``x̂ <- x̂ + D(payload)``
    — CHOCO-SGD's estimate tracking, see ``repro.compression``; the stack is
    warm-started at the initial parameters), and run the protocol's
    ``mix_compressed`` — the CONVEX form: self term on the TRUE parameters
    (never on the wire), off-diagonal terms on the dense estimates, a
    contraction that estimate lag cannot destabilize.  The affinity bias d
    runs on estimate differences, ``d = (sum_j beta_kj x̂_j - x̂_k) / T``:
    what receivers actually know of each other.  Push-sum mass rides
    uncompressed inside ``mix_compressed``.
    """
    params, d_bias, proto_state = state.params, state.d_bias, state.protocol
    est = state.compression
    has_nbrs = jnp.sum(consts.beta, axis=1) > 0  # (K,)
    for _ in range(cfg.consensus_steps):
        _, est = compression_lib.ef_compress_tree(comp, params, est)
        xhat = est
        if cfg.use_affinity_d:
            nbr_avg = consensus_lib.mix_stacked(consts.beta, xhat)
            d_bias = jax.tree.map(
                lambda avg, xh: jnp.where(
                    has_nbrs.reshape((-1,) + (1,) * (xh.ndim - 1)),
                    (avg - xh) / cfg.local_steps,
                    jnp.zeros_like(xh),
                ),
                nbr_avg,
                xhat,
            )
        proto_state, mixed = proto.mix_compressed(proto_state, params, xhat, consts)
        if cfg.use_affinity_b:
            mixed = jax.tree.map(
                lambda m, b: m + cfg.eta_b * b, mixed, state.b_bias
            )
        params = mixed

    return state._replace(
        params=params, d_bias=d_bias, protocol=proto_state,
        compression=est, round_idx=state.round_idx + 1,
    )


def _staleness_delivery(
    cfg: P2PConfig, round_idx: jax.Array, age: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One async round's delivery decision from the full (K,) snapshot ages.

    Returns ``(delivered, new_age, decay)``, all (K,):

    ``delivered``  bool — sender k publishes a fresh snapshot this round,
                   either on its compute schedule (every ``period_k`` rounds
                   of the config's profile) or FORCED because its snapshot
                   would otherwise exceed ``cfg.staleness_bound`` rounds of
                   age — the bounded-staleness guarantee.  Traced per-round
                   booleans: the mask gates buffer updates only, never the
                   (static) communication structure, so one compile covers
                   every round.
    ``new_age``    int32 — post-delivery snapshot ages (0 where delivered);
                   invariant ``new_age <= cfg.staleness_bound``.
    ``decay``      f32 — ``staleness_decay ** new_age``, the per-SENDER
                   weight multiplier of this round's mix (1.0 for fresh
                   snapshots).

    Both runtimes call this on the same (K,) age vector (the pod runtime
    all-gathers its K scalar ages first), so the delivery pattern — and with
    it the round's effective mixing matrix — is identical across runtimes.
    """
    _, periods_np = compute_profile(cfg)
    periods = jnp.asarray(periods_np)  # (K,) int32, trace-time constant
    scheduled = jax.lax.rem(round_idx, periods) == periods - 1
    delivered = scheduled | (age + 1 > cfg.staleness_bound)
    new_age = jnp.where(delivered, 0, age + 1)
    base = jnp.asarray(cfg.staleness_decay, jnp.float32)
    decay = base ** new_age.astype(jnp.float32)
    return delivered, new_age, decay


def _consensus_phase_async(
    state: P2PState,
    cfg: P2PConfig,
    consts: protocols_lib.ProtocolConstants,
    proto: protocols_lib.ConsensusProtocol,
) -> P2PState:
    """``consensus_phase`` under bounded-staleness delivery (vmap runtime).

    Each round: decide delivery per sender (``_staleness_delivery``), advance
    the ``StalenessState`` buffer (``published`` rows of delivering senders
    become their live post-local-phase parameters; ages reset or increment),
    then run the S consensus steps on the BUFFER — every off-diagonal term
    reads the sender's last published snapshot — with age-decayed weights
    renormalized per the protocol's stochasticity
    (``protocols.age_decayed_constants``): stale senders' outgoing weights
    shrink by ``staleness_decay ** age`` and the freed mass moves onto the
    diagonal, keeping gossip rows and push-sum columns stochastic, so
    push-sum mass conservation survives stale delivery exactly.

    The mix itself is the protocol's ``mix_compressed`` — the convex
    self-on-true-params / off-diagonal-on-substitute split is the same
    contraction whether the substitute is a compressed estimate or a stale
    snapshot.  Delivery happens once per ROUND: all S steps of a round mix
    the same buffer (a straggler cannot publish mid-round).  The affinity
    bias d also reads the buffer with the decayed beta — receivers can only
    be biased toward what they have actually received.
    """
    st: StalenessState = state.staleness
    delivered, age, decay = _staleness_delivery(cfg, state.round_idx, st.age)
    published = jax.tree.map(
        lambda p, q: jnp.where(
            delivered.reshape((-1,) + (1,) * (p.ndim - 1)), p, q
        ),
        state.params,
        st.published,
    )
    a_consts = protocols_lib.age_decayed_constants(
        consts, decay, proto.stochasticity
    )
    params, d_bias, proto_state = state.params, state.d_bias, state.protocol
    # neighbor support is read from the UNDECAYED beta: decay shrinks weights
    # but never disconnects a peer, so isolation (d = 0) matches the
    # synchronous rule
    has_nbrs = jnp.sum(consts.beta, axis=1) > 0  # (K,)
    for _ in range(cfg.consensus_steps):
        if cfg.use_affinity_d:
            nbr_avg = consensus_lib.mix_stacked(a_consts.beta, published)
            d_bias = jax.tree.map(
                lambda avg, w: jnp.where(
                    has_nbrs.reshape((-1,) + (1,) * (w.ndim - 1)),
                    (avg - w) / cfg.local_steps,
                    jnp.zeros_like(w),
                ),
                nbr_avg,
                params,
            )
        proto_state, mixed = proto.mix_compressed(
            proto_state, params, published, a_consts
        )
        if cfg.use_affinity_b:
            mixed = jax.tree.map(
                lambda m, b: m + cfg.eta_b * b, mixed, state.b_bias
            )
        params = mixed

    return state._replace(
        params=params, d_bias=d_bias, protocol=proto_state,
        staleness=StalenessState(published=published, age=age),
        round_idx=state.round_idx + 1,
    )


def run_round(
    state: P2PState,
    loss_fn: LossFn,
    batches: PyTree,
    cfg: P2PConfig,
    consts: protocols_lib.ProtocolConstants,
    *,
    steps_k: jax.Array | None = None,
) -> tuple[P2PState, P2PState, jax.Array]:
    """One full round: local phase then consensus phase.

    ``consts`` is the round's (K, K) ``ProtocolConstants`` slice; ``steps_k``
    the optional (K,) per-peer local-step budget of a heterogeneous compute
    profile (see ``compute_profile``).  Returns (state_after_local,
    state_after_consensus, local losses (T,)) so callers can evaluate test
    accuracy at both phase boundaries — the paper's central measurement
    (Figs. 2-6).
    """
    after_local, losses = local_phase(state, loss_fn, batches, cfg, steps_k=steps_k)
    after_consensus = consensus_phase(after_local, cfg, consts)
    return after_local, after_consensus, losses


# ---------------------------------------------------------------------------
# Sharded peer-axis runtime (shard_map over the mesh, peer_axis="pod")
# ---------------------------------------------------------------------------


def _shard_map_fn():
    """Version-compat shard_map: jax.shard_map (>= 0.6) or the experimental
    module it graduated from, with replication checking disabled either way
    (the runtime's replicated outputs — round_idx, losses — are replicated by
    construction; the check's rewrite rules don't cover every jax version)."""
    try:
        from jax import shard_map as sm  # jax >= 0.6
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm

    def wrap(f, *, mesh, in_specs, out_specs):
        for kw in ({"check_rep": False}, {"check_vma": False}, {}):
            try:
                return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
            except TypeError:
                continue
        raise RuntimeError("no compatible shard_map signature found")

    return wrap


def consensus_phase_sharded(
    state: P2PState,
    cfg: P2PConfig,
    consts: protocols_lib.ProtocolConstants,
    *,
    axis_name: str,
    lanes,
) -> P2PState:
    """``consensus_phase`` inside a shard_map block: one peer per mesh slice.

    Every ``P2PState`` leaf carries this peer's (1, ...) block of the stacked
    axis; ``consts`` is the round's full (K, K) slice (replicated — protocol
    matrices are tiny next to parameters).  Neighbor parameters arrive through
    one ``ppermute`` per ``PermLane`` (``consensus.gather_peer_leaf``); the mix
    is then this peer's (1, K) row of the same einsum the stacked runtime
    computes, which keeps the two runtimes bit-identical in fp32.

    The leaves are *pipelined* (double-buffered): leaf ``i+1``'s ppermute
    lanes are issued before leaf ``i``'s reconstruction is consumed by its mix
    matvec, and an ``optimization_barrier`` pins the pair so XLA's scheduler
    cannot serialize the in-flight sends behind the compute.  On a real mesh
    the lane traffic for the next leaf therefore hides behind the current
    leaf's matvecs; the per-leaf arithmetic is untouched, so the fp32
    bit-parity contract with the vmap runtime holds unchanged.
    """
    if cfg.consensus_steps == 0:
        return state._replace(round_idx=state.round_idx + 1)

    proto = protocols_lib.get_protocol(cfg.protocol)
    comp = compression_lib.from_config(cfg)
    if not comp.identity:
        return _consensus_phase_sharded_compressed(
            state, cfg, consts, proto, comp, axis_name=axis_name, lanes=lanes
        )
    if cfg.staleness_bound > 0:
        return _consensus_phase_sharded_async(
            state, cfg, consts, proto, axis_name=axis_name, lanes=lanes
        )
    k = consts.w.shape[-1]
    my = jax.lax.axis_index(axis_name)
    beta_row = jnp.take(consts.beta, my, axis=0)[None]  # (1, K)
    params, d_bias, proto_state = state.params, state.d_bias, state.protocol
    has_nbrs = jnp.sum(beta_row, axis=1) > 0  # (1,)
    b_bias_leaves = jax.tree.leaves(state.b_bias)
    # a protocol written against the pre-scan interface (whole-tree
    # ``mix_sharded`` override, no ``mix_sharded_begin``) still works: it runs
    # the unpipelined whole-tree path instead of silently hitting the base
    # class's NotImplementedError (or worse, ignoring its override)
    legacy_mix = (
        type(proto).mix_sharded_begin
        is protocols_lib.ConsensusProtocol.mix_sharded_begin
    )
    if legacy_mix:
        for _ in range(cfg.consensus_steps):
            params_full = consensus_lib.gather_peer_rows(params, axis_name, lanes, k)
            if cfg.use_affinity_d:
                nbr_avg = consensus_lib.mix_stacked(beta_row, params_full)
                d_bias = jax.tree.map(
                    lambda avg, w: jnp.where(
                        has_nbrs.reshape((-1,) + (1,) * (w.ndim - 1)),
                        (avg - w) / cfg.local_steps,
                        jnp.zeros_like(w),
                    ),
                    nbr_avg,
                    params,
                )
            proto_state, mixed = proto.mix_sharded(
                proto_state, params, params_full, consts.w,
                axis_name=axis_name, lanes=lanes,
            )
            if cfg.use_affinity_b:
                mixed = jax.tree.map(
                    lambda m, b: m + cfg.eta_b * b, mixed, state.b_bias
                )
            params = mixed
        return state._replace(
            params=params, d_bias=d_bias, protocol=proto_state,
            round_idx=state.round_idx + 1,
        )

    for _ in range(cfg.consensus_steps):
        # scalar/context work once per step (push_sum's mass lane rides here)
        proto_state, ctx = proto.mix_sharded_begin(
            proto_state, consts.w, axis_name=axis_name, lanes=lanes
        )
        leaves, treedef = jax.tree.flatten(params)
        mixed_leaves, d_leaves = [], []
        nxt = (
            consensus_lib.gather_peer_leaf(leaves[0], axis_name, lanes, k)
            if leaves else None
        )
        for i, x in enumerate(leaves):
            x_full = nxt
            # issue leaf i+1's lanes BEFORE leaf i's reconstruction is consumed
            nxt = (
                consensus_lib.gather_peer_leaf(leaves[i + 1], axis_name, lanes, k)
                if i + 1 < len(leaves) else None
            )
            d_i = None
            if cfg.use_affinity_d:
                # d_k <- (1/T) sum_j beta_kj (w_j - w_k); isolated peers
                # (all-zero beta row this round) keep d = 0
                nbr_avg = consensus_lib.mix_leaf(beta_row, x_full)
                d_i = jnp.where(
                    has_nbrs.reshape((-1,) + (1,) * (x.ndim - 1)),
                    (nbr_avg - x) / cfg.local_steps,
                    jnp.zeros_like(x),
                )
            m_i = proto.mix_sharded_leaf(ctx, x, x_full)
            if cfg.use_affinity_b:
                m_i = m_i + cfg.eta_b * b_bias_leaves[i]
            if nxt is not None:
                # double-buffer: group the next leaf's in-flight lanes with
                # this leaf's results so neither side is sunk past the other
                if d_i is not None:
                    nxt, m_i, d_i = jax.lax.optimization_barrier((nxt, m_i, d_i))
                else:
                    nxt, m_i = jax.lax.optimization_barrier((nxt, m_i))
            mixed_leaves.append(m_i)
            d_leaves.append(d_i)
        params = jax.tree.unflatten(treedef, mixed_leaves)
        if cfg.use_affinity_d:
            d_bias = jax.tree.unflatten(treedef, d_leaves)

    return state._replace(
        params=params, d_bias=d_bias, protocol=proto_state,
        round_idx=state.round_idx + 1,
    )


def _consensus_phase_sharded_compressed(
    state: P2PState,
    cfg: P2PConfig,
    consts: protocols_lib.ProtocolConstants,
    proto: protocols_lib.ConsensusProtocol,
    comp: compression_lib.Compressor,
    *,
    axis_name: str,
    lanes,
) -> P2PState:
    """``consensus_phase_sharded`` over a compressed wire.

    What rides the wire changes: instead of each raw fp32 leaf, every array
    of the leaf's compressed difference payload (top-k values + indices, or
    int8 tensor + fp32 scale) is broadcast with one tiled ``all_gather`` per
    payload array.  Broadcast — not the schedule's edge lanes — because the
    CHOCO estimate stack demands it: each device holds the full (K, ...)
    public-estimate stack REPLICATED in ``state.compression``
    (``sharding.specs.peer_stacked_pspecs`` keeps it un-sharded), and the
    replicas only stay consistent (provably so, for shard_map's replication
    checker) if every device advances every row from the same payloads every
    step.  This is the same semantics the vmap compressed runtime computes,
    and the wire still never carries fp32 parameters.

    The ``all_gather`` broadcast is a SIMULATOR artifact, not the modeled
    traffic.  The modeled per-edge system stores estimate rows only for each
    node's union in-neighbors and delivers payloads on every union lane of
    the schedule every step (active or not — sender and receiver copies of
    ``x̂`` must advance in lockstep); rows outside the union stay frozen at
    the warm start and are never read, because their mixing and affinity
    weights are zero in every round.  Its read-observable dynamics are
    therefore identical to this simulation, and the analytic bytes model
    prices exactly that standing union-lane traffic
    (``benchmarks.wire.estimate_gossip_bytes_per_round``), not the K*(K-1)
    gather.

    After advancing the stack, the receiver substitutes its TRUE block for
    its own row of a TEMPORARY copy of the stack (the convex mix's self term
    is exact under any compressor; the carried estimate itself advances only
    from payloads, so replicas stay consistent) and applies the protocol's
    ordinary ``mix_sharded_leaf`` row arithmetic.  ``mix_sharded_begin`` is
    untouched: push-sum's scalar mass lane stays uncompressed, so mass
    conservation is exact.

    Numerics note: this path is allclose — not bit-identical — to the vmap
    compressed path (a (1, K)-row einsum on the estimate vs. the stacked
    diag/off-diag split).  The bit-parity contract of the pod runtime applies
    to ``compressor="none"``, which never enters here.
    """
    my = jax.lax.axis_index(axis_name)
    beta_row = jnp.take(consts.beta, my, axis=0)[None]  # (1, K)
    params, d_bias, proto_state = state.params, state.d_bias, state.protocol
    has_nbrs = jnp.sum(beta_row, axis=1) > 0  # (1,)
    b_bias_leaves = jax.tree.leaves(state.b_bias)
    leaves, treedef = jax.tree.flatten(params)
    e_leaves = jax.tree.leaves(state.compression)  # each (K, ...) replicated
    for _ in range(cfg.consensus_steps):
        # push-sum's scalar mass lane rides the schedule's edge lanes,
        # uncompressed, exactly as on the identity path
        proto_state, ctx = proto.mix_sharded_begin(
            proto_state, consts.w, axis_name=axis_name, lanes=lanes
        )
        mixed_leaves, d_leaves, new_e = [], [], []
        for i, x in enumerate(leaves):
            est = e_leaves[i]
            # sender side: this peer's difference to its own public estimate
            my_est = jax.lax.dynamic_slice_in_dim(est, my, 1, axis=0)
            payload = comp.compress(x - my_est)
            gathered = jax.tree.map(
                lambda a: jax.lax.all_gather(a, axis_name, axis=0, tiled=True),
                payload,
            )
            # every replica advances the whole stack by the same payloads —
            # including its own row, which must match what OTHER devices hold
            # for this sender (never shortcut it with the true x)
            est = est + comp.decompress(gathered, est)
            my_est = jax.lax.dynamic_slice_in_dim(est, my, 1, axis=0)
            d_i = None
            if cfg.use_affinity_d:
                # d on estimate differences (what receivers actually know of
                # each other) — mirrors the vmap compressed path
                nbr_avg = consensus_lib.mix_leaf(beta_row, est)
                d_i = jnp.where(
                    has_nbrs.reshape((-1,) + (1,) * (x.ndim - 1)),
                    (nbr_avg - my_est) / cfg.local_steps,
                    jnp.zeros_like(x),
                )
            # convex mix: the receiver's own row is its true block (the self
            # term is exact under any compressor); only this TEMPORARY view
            # is patched — the carried estimate advances from payloads alone
            xhat_full = est.at[my].set(x[0])
            m_i = proto.mix_sharded_leaf(ctx, x, xhat_full)
            if cfg.use_affinity_b:
                m_i = m_i + cfg.eta_b * b_bias_leaves[i]
            mixed_leaves.append(m_i)
            d_leaves.append(d_i)
            new_e.append(est)
        leaves = mixed_leaves
        e_leaves = new_e
        if cfg.use_affinity_d:
            d_bias = jax.tree.unflatten(treedef, d_leaves)

    return state._replace(
        params=jax.tree.unflatten(treedef, leaves),
        d_bias=d_bias,
        protocol=proto_state,
        compression=jax.tree.unflatten(treedef, e_leaves),
        round_idx=state.round_idx + 1,
    )


def _consensus_phase_sharded_async(
    state: P2PState,
    cfg: P2PConfig,
    consts: protocols_lib.ProtocolConstants,
    proto: protocols_lib.ConsensusProtocol,
    *,
    axis_name: str,
    lanes,
) -> P2PState:
    """``consensus_phase_sharded`` under bounded-staleness delivery.

    The same round semantics as the vmap ``_consensus_phase_async``, one peer
    per mesh slice.  The cheap cross-peer exchange is one ``all_gather`` of
    the K scalar snapshot AGES (the adaptive schedule's K-losses pattern):
    every peer then computes the same (K,) delivery mask and the same
    renormalized (K, K) decayed constants from the replicated round slice.
    Published SNAPSHOT rows — not live parameters — ride the schedule's
    static ppermute lanes; the delivery mask only gates which rows of the
    buffer were refreshed before the sends, so the lane structure (and the
    one-compile property) is untouched by who straggles when.

    Because a round's published buffer is FIXED across its S consensus steps
    (delivery is per round), each leaf is gathered once before the step loop
    instead of per step — the async path trades the sync path's leaf
    pipelining for S-fold fewer lane transfers.  The mix is the protocol's
    ``mix_split_sharded_begin`` / ``mix_split_sharded_leaf`` pair: this
    peer's row of the vmap path's diagonal/off-diagonal decomposition,
    operation for operation (self term elementwise on the true block,
    off-diagonal einsum row on the snapshot stack), which keeps the async
    pod runtime fp32 BIT-IDENTICAL to the vmap ``_consensus_phase_async`` —
    the same parity contract as the synchronous paths.  Push-sum's mass
    lane rides inside ``mix_split_sharded_begin`` on the same decayed
    matrix, so the renormalized column sums — and mass conservation — hold
    exactly.
    """
    k = consts.w.shape[-1]
    my = jax.lax.axis_index(axis_name)
    st: StalenessState = state.staleness  # published (1, ...), age (1,)
    age_full = jax.lax.all_gather(st.age, axis_name, axis=0, tiled=True)  # (K,)
    delivered, age_full_new, decay = _staleness_delivery(
        cfg, state.round_idx, age_full
    )
    del_mine = jax.lax.dynamic_slice(delivered, (my,), (1,))  # (1,) bool
    published = jax.tree.map(
        lambda p, q: jnp.where(
            del_mine.reshape((-1,) + (1,) * (p.ndim - 1)), p, q
        ),
        state.params,
        st.published,
    )
    age_mine = jax.lax.dynamic_slice(age_full_new, (my,), (1,))
    a_consts = protocols_lib.age_decayed_constants(
        consts, decay, proto.stochasticity
    )
    beta_row = jnp.take(a_consts.beta, my, axis=0)[None]  # (1, K), decayed
    has_nbrs = jnp.sum(jnp.take(consts.beta, my, axis=0)[None], axis=1) > 0  # (1,)
    params, d_bias, proto_state = state.params, state.d_bias, state.protocol
    b_bias_leaves = jax.tree.leaves(state.b_bias)
    leaves, treedef = jax.tree.flatten(params)
    pub_full_leaves = [
        consensus_lib.gather_peer_leaf(pl, axis_name, lanes, k)
        for pl in jax.tree.leaves(published)
    ]
    for _ in range(cfg.consensus_steps):
        proto_state, ctx = proto.mix_split_sharded_begin(
            proto_state, a_consts.w, axis_name=axis_name, lanes=lanes
        )
        mixed_leaves, d_leaves = [], []
        for i, x in enumerate(leaves):
            pub_full = pub_full_leaves[i]
            d_i = None
            if cfg.use_affinity_d:
                # d from the snapshot stack as carried (own row = own
                # published block) — mirrors the vmap async path, which
                # mixes beta over the buffer itself
                nbr_avg = consensus_lib.mix_leaf(beta_row, pub_full)
                d_i = jnp.where(
                    has_nbrs.reshape((-1,) + (1,) * (x.ndim - 1)),
                    (nbr_avg - x) / cfg.local_steps,
                    jnp.zeros_like(x),
                )
            # convex split: self term on the true block (diagonal weight),
            # off-diagonal accumulation on the snapshot stack — the own row
            # of pub_full is never read
            m_i = proto.mix_split_sharded_leaf(ctx, x, pub_full)
            if cfg.use_affinity_b:
                m_i = m_i + cfg.eta_b * b_bias_leaves[i]
            mixed_leaves.append(m_i)
            d_leaves.append(d_i)
        leaves = mixed_leaves
        if cfg.use_affinity_d:
            d_bias = jax.tree.unflatten(treedef, d_leaves)

    return state._replace(
        params=jax.tree.unflatten(treedef, leaves),
        d_bias=d_bias,
        protocol=proto_state,
        staleness=StalenessState(published=published, age=age_mine),
        round_idx=state.round_idx + 1,
    )


MIX_MODES = ("auto", "bridge", "segment")
_BRIDGE_MAX_PEERS = 64  # "auto" uses the bit-parity bridge mix up to here


def consensus_phase_hier(
    state: P2PState,
    cfg: P2PConfig,
    *,
    axis_name: str,
    num_devices: int,
    mix_mode: str,
    ops: protocols_lib.SparseRoundOps | None = None,
    dense_consts: protocols_lib.ProtocolConstants | None = None,
) -> P2PState:
    """``consensus_phase`` inside a shard_map block holding a (p, ...) BLOCK
    of peers (p = K / devices > 1) — the hierarchical runtime's mix.

    Two modes, selected by ``mix_mode``:

    "bridge" (K <= 64): per leaf, all-gather the (K, ...) stack and run the
    SAME full dense einsum the stacked runtime runs — ``dense_consts`` is the
    round's (K, K) slice scattered back losslessly from the sparse schedule
    (``graph.SparseSchedule.to_dense``) — then keep this device's p rows.
    Slicing AFTER the reduction preserves every bit; (p, K)-row forms of the
    matvec leaves (scalar parameters, the push-sum mass) reduce in a
    different order and drift by an ulp.  Each device duplicates the full
    K x K mix, which is exactly the regime's point: K <= 64 makes the
    duplicated flops irrelevant next to fp32 bit-identity with the vmap and
    pod runtimes.

    "segment" (large K): per leaf, ring-stream the peer blocks across the
    mesh and keep only this block's (p, D, ...) neighbor slots
    (``consensus.ring_gather_slots``), then segment-sum with the sparse
    ``ops`` (the round's degree-bounded ``SparseRoundOps``, replicated —
    K*D floats, tiny next to parameters even at K = 4096).  Peak per-device
    consensus memory is O(K * D * feat / devices) and traffic O(K * feat)
    per device — no (K, K), no (K, feat) — at the cost of bitwise parity
    (degree-bounded sums reduce in slot order; results are allclose to
    dense, not bit-identical).
    """
    if cfg.consensus_steps == 0:
        return state._replace(round_idx=state.round_idx + 1)

    proto = protocols_lib.get_protocol(cfg.protocol)
    p = jax.tree.leaves(state.params)[0].shape[0]
    my = jax.lax.axis_index(axis_name)
    row0 = (my * p).astype(jnp.int32)

    if mix_mode == "bridge":
        if dense_consts is None:
            raise ValueError("bridge mode needs dense_consts (round (K, K) slice)")
        beta_r = dense_consts.beta  # (K, K) f32
        has_nbrs = jax.lax.dynamic_slice_in_dim(
            jnp.sum(beta_r, axis=1) > 0, row0, p, axis=0
        )  # (p,)
        begin_kwargs = dict(dense_w=dense_consts.w, row0=row0, block_size=p)

        def view(x):
            return jax.lax.all_gather(x, axis_name, axis=0, tiled=True)

        def nbr_avg_fn(x_view):
            full = consensus_lib.mix_leaf(beta_r, x_view)  # (K, ...)
            return jax.lax.dynamic_slice_in_dim(full, row0, p, axis=0)

    elif mix_mode == "segment":
        if ops is None:
            raise ValueError("segment mode needs ops (round SparseRoundOps)")
        blk = protocols_lib.SparseRoundOps(
            *(jax.lax.dynamic_slice_in_dim(o, row0, p, axis=0) for o in ops)
        )
        has_nbrs = jnp.sum(blk.beta, axis=1) > 0  # (p,)
        begin_kwargs = dict(ops_block=blk)

        def view(x):
            return consensus_lib.ring_gather_slots(
                x, blk.nbr_idx, axis_name, num_devices
            )

        def nbr_avg_fn(x_view):
            return consensus_lib.slot_sum(blk.beta, x_view)

    else:
        raise ValueError(f"unknown mix_mode {mix_mode!r}; 'bridge' or 'segment'")

    params, d_bias, proto_state = state.params, state.d_bias, state.protocol
    b_bias_leaves = jax.tree.leaves(state.b_bias)
    for _ in range(cfg.consensus_steps):
        proto_state, ctx = proto.mix_hier_begin(
            proto_state, mode=mix_mode, axis_name=axis_name,
            num_devices=num_devices, **begin_kwargs,
        )
        leaves, treedef = jax.tree.flatten(params)
        mixed_leaves, d_leaves = [], []
        for i, x in enumerate(leaves):
            x_view = view(x)
            d_i = None
            if cfg.use_affinity_d:
                # d_k <- (1/T) sum_j beta_kj (w_j - w_k); isolated peers
                # (all-zero beta row this round) keep d = 0
                avg = nbr_avg_fn(x_view)
                d_i = jnp.where(
                    has_nbrs.reshape((-1,) + (1,) * (x.ndim - 1)),
                    (avg - x) / cfg.local_steps,
                    jnp.zeros_like(x),
                )
            m_i = proto.mix_hier_leaf(ctx, x, x_view)
            if cfg.use_affinity_b:
                m_i = m_i + cfg.eta_b * b_bias_leaves[i]
            mixed_leaves.append(m_i)
            d_leaves.append(d_i)
        params = jax.tree.unflatten(treedef, mixed_leaves)
        if cfg.use_affinity_d:
            d_bias = jax.tree.unflatten(treedef, d_leaves)

    return state._replace(
        params=params, d_bias=d_bias, protocol=proto_state,
        round_idx=state.round_idx + 1,
    )


def _make_hier_round_step(
    loss_fn: LossFn,
    cfg: P2PConfig,
    data_sizes: np.ndarray | None = None,
    *,
    mesh,
    axis_name: str,
    peers_per_device: int,
    mix_mode: str = "auto",
):
    """The hierarchical (vmap-within-device x shard_map) round step:
    ``peers_per_device`` peers share each mesh slice, decoupling K from the
    device count — K = 4096 runs on an 8-device mesh with 512 peers each.

    The local phase is the SAME ``_local_phase_stats`` scan (vmap over the
    (p, ...) block instead of the full (K, ...) stack — bit-identical rows),
    and the consensus phase is ``consensus_phase_hier`` over the round's
    degree-bounded ``graph.SparseSchedule`` operands.
    """
    from repro.sharding import specs as specs_lib

    # adaptive / compression / async / real-model x hierarchical: all four
    # rejections come from the declarative table, through the one formatter
    features_lib.check_config(cfg, peers_per_device=peers_per_device)
    loss_fn = resolve_loss_fn(loss_fn)
    if mix_mode not in MIX_MODES:
        raise ValueError(f"unknown mix_mode {mix_mode!r}; one of {MIX_MODES}")
    num_devices, _ = specs_lib.hierarchical_layout(
        cfg.num_peers, mesh, peer_axis=axis_name,
        peers_per_device=peers_per_device,
    )
    mode = mix_mode
    if mode == "auto":
        mode = "bridge" if cfg.num_peers <= _BRIDGE_MAX_PEERS else "segment"

    proto = protocols_lib.get_protocol(cfg.protocol)
    sched = build_schedule(cfg)
    if sched.directed and not proto.directed_capable:
        warnings.warn(
            f"protocol {cfg.protocol!r} on a directed schedule "
            f"({sched.name!r}): a row-stochastic consensus point is biased on "
            "asymmetric graphs — use protocol='push_sum' unless the bias is "
            "deliberate",
            stacklevel=2,
        )
    sparse = graph_lib.SparseSchedule.from_schedule(
        sched, cfg.mixing, data_sizes=data_sizes,
        consensus_step_size=cfg.consensus_step_size,
        stochasticity=proto.stochasticity,
    )
    period = sparse.period
    shard_map = _shard_map_fn()
    from jax.sharding import PartitionSpec as P

    if mode == "bridge":
        # Lossless densification: the bridge mix replays the stacked
        # runtime's full (K, K) einsums and slices this device's rows, so it
        # wants the round constants in exactly the stacked runtime's form.
        w_np, beta_np = sparse.to_dense()
        w_s = jnp.asarray(w_np, jnp.float32)  # (R, K, K)
        beta_s = jnp.asarray(beta_np, jnp.float32)

        def block(state: P2PState, batches: PyTree, w, bt):
            after_local, losses = local_phase(
                state, loss_fn, batches, cfg, axis_name=axis_name
            )
            idx = jax.lax.rem(state.round_idx, jnp.int32(period))
            after_cons = consensus_phase_hier(
                after_local, cfg,
                axis_name=axis_name, num_devices=num_devices, mix_mode=mode,
                dense_consts=protocols_lib.ProtocolConstants(w=w[idx], beta=bt[idx]),
            )
            return after_local, after_cons, losses

        extra_args = (w_s, beta_s)
        extra_specs = (P(None, None, None), P(None, None, None))
    else:
        # stacked (R, ...) degree-bounded operands — R*K*D floats, replicated
        self_w_s = jnp.asarray(sparse.self_w, jnp.float32)
        nbr_idx_s = jnp.asarray(sparse.nbr_idx, jnp.int32)
        nbr_w_s = jnp.asarray(sparse.nbr_w, jnp.float32)
        beta_s = jnp.asarray(sparse.beta, jnp.float32)

        def block(state: P2PState, batches: PyTree, sw, ni, nw, bt):
            after_local, losses = local_phase(
                state, loss_fn, batches, cfg, axis_name=axis_name
            )
            idx = jax.lax.rem(state.round_idx, jnp.int32(period))
            after_cons = consensus_phase_hier(
                after_local, cfg,
                axis_name=axis_name, num_devices=num_devices, mix_mode=mode,
                ops=protocols_lib.SparseRoundOps(sw[idx], ni[idx], nw[idx], bt[idx]),
            )
            return after_local, after_cons, losses

        extra_args = (self_w_s, nbr_idx_s, nbr_w_s, beta_s)
        extra_specs = (
            P(None, None), P(None, None, None),
            P(None, None, None), P(None, None, None),
        )

    def step(state: P2PState, batches: PyTree):
        s_specs = specs_lib.peer_stacked_pspecs(state, peer_axis=axis_name)
        b_specs = specs_lib.peer_batch_pspecs(batches, peer_axis=axis_name)
        mapped = shard_map(
            block,
            mesh=mesh,
            in_specs=(s_specs, b_specs) + extra_specs,
            out_specs=(s_specs, s_specs, P(None)),
        )
        return mapped(state, batches, *extra_args)

    return step


def _make_round_step(
    loss_fn: LossFn,
    cfg: P2PConfig,
    data_sizes: np.ndarray | None = None,
    *,
    mesh=None,
    axis_name: str = "pod",
    peers_per_device: int | None = None,
    mix_mode: str = "auto",
):
    """The UNJITTED (state, batches) -> (after_local, after_consensus, losses)
    round step shared by every driver.

    ``mesh=None`` builds the stacked/vmap step; a mesh builds the sharded
    (``shard_map`` over ``axis_name``) step.  ``make_round_fn`` /
    ``make_sharded_round_fn`` jit it per round; ``make_scan_driver`` scans a
    whole chunk of calls inside one jitted program.  Sharing the step is what
    keeps the python-loop and scan drivers running the SAME per-round
    expression graph — the basis of their fp32 bit-parity contract.

    ``cfg.schedule == "adaptive"`` swaps the pretraced ``round_idx % R``
    constant stack for ``graph.adaptive_round_matrices``: the round's (K, K)
    W/Beta are computed inside the step from ``state.adaptive`` (previous
    round's per-peer losses + the threaded PRNG key), then the step stores
    this round's per-peer mean losses and the advanced key for the next
    round.  Still one compile per run — the selection is ordinary traced
    arithmetic, not a host callback.

    ``peers_per_device > 1`` (mesh required) builds the HIERARCHICAL step
    instead (``_make_hier_round_step``): p = K / devices peers vmapped within
    each mesh slice, sparse degree-bounded consensus across slices.
    """
    if peers_per_device is not None and peers_per_device != 1:
        if mesh is None:
            raise ValueError("peers_per_device > 1 needs a mesh (hierarchical runtime)")
        return _make_hier_round_step(
            loss_fn, cfg, data_sizes, mesh=mesh, axis_name=axis_name,
            peers_per_device=peers_per_device, mix_mode=mix_mode,
        )
    loss_fn = resolve_loss_fn(loss_fn)
    adaptive = cfg.schedule == "adaptive"
    proto = protocols_lib.get_protocol(cfg.protocol)
    sizes_dev = (
        None if data_sizes is None
        else jnp.asarray(np.asarray(data_sizes), jnp.float32)
    )
    # heterogeneous per-peer step budgets (None for "uniform": the masked
    # scan is never built, so the synchronous path stays structurally — and
    # bit-for-bit — the legacy one)
    steps_dev: jax.Array | None = None
    if cfg.steps_profile != "uniform":
        steps_np, _ = compute_profile(cfg)
        steps_dev = jnp.asarray(steps_np)  # (K,) int32

    def adaptive_consts(ad: "AdaptiveState", losses_full: jax.Array):
        """(this round's ProtocolConstants, next round's key) from run state.

        ``losses_full`` is the gathered (K,) selection signal — identical
        bits in both runtimes (the vmap runtime reads the stacked leaf, the
        pod runtime all-gathers the K scalars), so the matching, and with it
        the round's whole topology, is too.
        """
        key_round, key_next = jax.random.split(ad.key[0])
        w, beta = graph_lib.adaptive_round_matrices(
            losses_full, key_round, rule=cfg.partner_rule,
            eps=cfg.adaptive_eps, data_sizes=sizes_dev,
            consensus_step_size=cfg.consensus_step_size,
            stochasticity=proto.stochasticity,
        )
        return protocols_lib.ProtocolConstants(w=w, beta=beta), key_next

    if mesh is None:
        if adaptive:

            def step(state: P2PState, batches: PyTree):
                ad = state.adaptive
                consts, key_next = adaptive_consts(ad, ad.last_losses)
                after_local, losses_tk = _local_phase_stats(
                    state, loss_fn, batches, cfg, steps_k=steps_dev
                )
                new_ad = AdaptiveState(
                    key=jnp.broadcast_to(key_next[None, :], ad.key.shape),
                    last_losses=jnp.mean(losses_tk, axis=0),  # (K,) per peer
                )
                after_local = after_local._replace(adaptive=new_ad)
                after_cons = consensus_phase(after_local, cfg, consts)
                return after_local, after_cons, jnp.mean(losses_tk, axis=1)

            return step

        consts_np, _ = protocol_constants(cfg, data_sizes)
        consts = protocols_lib.ProtocolConstants(
            w=jnp.asarray(consts_np.w, jnp.float32),  # (R, K, K)
            beta=jnp.asarray(consts_np.beta, jnp.float32),
        )
        period = consts.w.shape[0]

        def step(state: P2PState, batches: PyTree):
            idx = jax.lax.rem(state.round_idx, jnp.int32(period))
            return run_round(
                state, loss_fn, batches, cfg,
                protocols_lib.round_constants(consts, idx),
                steps_k=steps_dev,
            )

        return step

    from repro.sharding import specs as specs_lib

    axis_sizes = dict(mesh.shape)
    if axis_sizes.get(axis_name) != cfg.num_peers:
        raise ValueError(
            f"mesh axis {axis_name!r} must have exactly num_peers="
            f"{cfg.num_peers} slices, got mesh shape {axis_sizes} "
            "(see repro.launch.mesh.make_peer_mesh)"
        )
    shard_map = _shard_map_fn()
    from jax.sharding import PartitionSpec as P

    def my_steps_block():
        # this peer's (1,) slice of the replicated (K,) step budgets (None
        # for the uniform profile — the unmasked legacy scan)
        if steps_dev is None:
            return None
        my = jax.lax.axis_index(axis_name)
        return jax.lax.dynamic_slice(steps_dev, (my,), (1,))

    if adaptive:
        # Any pair may be matched on any round, so the candidate lane set
        # covers the COMPLETE graph: the ppermute structure (lanes and their
        # perms) stays a trace-time constant while the round's on-device
        # weights null every edge the matching did not select — zero rows of
        # the gathered params meet zero mixing weights, contributing exactly
        # +-0.0, just as on a pretraced schedule's absent edges.
        union = ~np.eye(cfg.num_peers, dtype=bool)
        lanes = graph_lib.edge_color_lanes(union)

        def block_adaptive(state: P2PState, batches: PyTree):
            after_local, losses_tk = _local_phase_stats(
                state, loss_fn, batches, cfg, axis_name=axis_name,
                steps_k=my_steps_block(),
            )
            ad = state.adaptive
            # the cheap K-vector exchange: each peer contributes one scalar
            losses_full = jax.lax.all_gather(
                ad.last_losses, axis_name, axis=0, tiled=True
            )  # (K,)
            consts, key_next = adaptive_consts(ad, losses_full)
            my = jax.lax.axis_index(axis_name)
            peer_losses = jnp.mean(losses_tk, axis=0)  # (K,) replicated
            new_ad = AdaptiveState(
                key=key_next[None, :],  # this peer's (1, 2) block
                last_losses=jax.lax.dynamic_slice(peer_losses, (my,), (1,)),
            )
            after_local = after_local._replace(adaptive=new_ad)
            after_cons = consensus_phase_sharded(
                after_local, cfg, consts, axis_name=axis_name, lanes=lanes
            )
            return after_local, after_cons, jnp.mean(losses_tk, axis=1)

        def step(state: P2PState, batches: PyTree):
            s_specs = specs_lib.peer_stacked_pspecs(state, peer_axis=axis_name)
            b_specs = specs_lib.peer_batch_pspecs(batches, peer_axis=axis_name)
            mapped = shard_map(
                block_adaptive,
                mesh=mesh,
                in_specs=(s_specs, b_specs),
                out_specs=(s_specs, s_specs, P(None)),
            )
            return mapped(state, batches)

        return step

    consts_np, sched = protocol_constants(cfg, data_sizes)
    w_dev = jnp.asarray(consts_np.w, jnp.float32)  # (R, K, K)
    beta_dev = jnp.asarray(consts_np.beta, jnp.float32)
    period = w_dev.shape[0]
    lanes = graph_lib.schedule_lanes(sched)

    def block(state: P2PState, batches: PyTree, w_stack, beta_stack):
        # the per-step loss means all-gather inside the block (axis_name), so
        # the (T,) output is replicated — and reduced over the same (K,)
        # vector as the vmap runtime
        after_local, losses = local_phase(
            state, loss_fn, batches, cfg, axis_name=axis_name,
            steps_k=my_steps_block(),
        )
        idx = jax.lax.rem(state.round_idx, jnp.int32(period))
        consts = protocols_lib.round_constants(
            protocols_lib.ProtocolConstants(w=w_stack, beta=beta_stack), idx
        )
        after_cons = consensus_phase_sharded(
            after_local, cfg, consts, axis_name=axis_name, lanes=lanes
        )
        return after_local, after_cons, losses

    def step(state: P2PState, batches: PyTree):
        s_specs = specs_lib.peer_stacked_pspecs(state, peer_axis=axis_name)
        b_specs = specs_lib.peer_batch_pspecs(batches, peer_axis=axis_name)
        c_spec = P(None, None, None)
        mapped = shard_map(
            block,
            mesh=mesh,
            in_specs=(s_specs, b_specs, c_spec, c_spec),
            out_specs=(s_specs, s_specs, P(None)),
        )
        return mapped(state, batches, w_dev, beta_dev)

    return step


def make_sharded_round_fn(
    loss_fn: LossFn,
    cfg: P2PConfig,
    mesh,
    data_sizes: np.ndarray | None = None,
    *,
    axis_name: str = "pod",
    peers_per_device: int | None = None,
    mix_mode: str = "auto",
):
    """jit-compiled round over a REAL mesh: one peer replica per mesh slice.

    The drop-in production form of ``make_round_fn``: same signature for the
    returned callable, same (state, batches) -> (after_local, after_consensus,
    losses) contract, bit-identical fp32 results — but the peer axis is
    ``shard_map``'d over ``mesh``'s ``axis_name`` instead of vmapped, local
    phases run embarrassingly parallel, and the consensus mix lowers to one
    ppermute per schedule lane (``graph.schedule_lanes``) instead of a dense
    (K, K) einsum.  The protocol's (R, K, K) constants stay replicated and are
    sliced with ``round_idx % R`` inside the one jitted program.

    State/batch placement: any input works (jit reshards), but steady-state
    runs should place the state with ``sharding.specs.shard_peer_tree`` to
    avoid a per-round host transfer.

    ``peers_per_device > 1`` selects the hierarchical runtime: p = K /
    mesh-axis-size peers vmapped inside each slice, consensus over the
    degree-bounded sparse schedule (``mix_mode``: "auto" picks the bit-parity
    "bridge" mix for K <= 64 and the O(K * D / devices)-memory "segment" mix
    beyond — see ``consensus_phase_hier``).
    """
    return jax.jit(
        _make_round_step(
            loss_fn, cfg, data_sizes, mesh=mesh, axis_name=axis_name,
            peers_per_device=peers_per_device, mix_mode=mix_mode,
        )
    )


def make_round_fn(loss_fn: LossFn, cfg: P2PConfig, data_sizes: np.ndarray | None = None):
    """jit-compiled round closure over the (possibly time-varying) schedule.

    The protocol's full (R, K, K) constant stacks are closed over as device
    constants and indexed with ``round_idx % R`` *inside* the jitted program:
    one compile covers every round of a time-varying run — for any protocol —
    with no per-round host sync.
    """
    return jax.jit(_make_round_step(loss_fn, cfg, data_sizes))


def make_scan_driver(
    loss_fn: LossFn,
    cfg: P2PConfig,
    data_sizes: np.ndarray | None = None,
    *,
    mesh=None,
    axis_name: str = "pod",
    peers_per_device: int | None = None,
    mix_mode: str = "auto",
    donate: bool = True,
):
    """Fused multi-round driver: a whole chunk of rounds per jitted call.

    Returns ``drive(state, batches) -> (after_local, final_state, losses)``
    where every ``batches`` leaf carries a leading chunk axis C on top of the
    per-round layout — (C, T, K, ...) — and the C rounds run inside ONE
    ``lax.scan`` of the same round step the python-loop drivers jit
    (``_make_round_step``), so the results are fp32 bit-identical to C calls
    of ``make_round_fn`` / ``make_sharded_round_fn``.  ``after_local`` is the
    last round's post-local-phase state (the paper's eval instrument needs
    both phase boundaries), ``losses`` is the stacked (C, T) per-round series.

    Why it's faster than the python loop: one dispatch (and one
    ``device_get``, if the caller fetches anything) per C rounds instead of
    per round, round constants selected by ``round_idx % R`` inside the scan
    carry, and — with ``donate=True`` — ``donate_argnums`` on the input
    ``P2PState``, so params/opt/protocol buffers are reused in place instead
    of reallocated every round.  The donated input is CONSUMED: after
    ``drive(state, ...)`` the caller must use the returned state, never
    ``state`` itself.

    ``mesh=None`` scans the stacked/vmap runtime; a mesh scans the sharded
    (``peer_axis="pod"``) runtime, chunk axis outside the ``shard_map``.
    The chunk length C is not baked in: it is read from the batch shapes, and
    each distinct C compiles once (drive with ONE chunk size per run to keep
    the one-compile property).
    """
    step = _make_round_step(
        loss_fn, cfg, data_sizes, mesh=mesh, axis_name=axis_name,
        peers_per_device=peers_per_device, mix_mode=mix_mode,
    )

    def drive(state: P2PState, batches: PyTree):
        def body(carry, batches_r):
            st, _ = carry
            after_local, after_cons, losses = step(st, batches_r)
            return (after_cons, after_local), losses

        # the second carry slot threads the LAST round's after-local state out
        # of the scan (stacking every round's would hold C copies of params)
        (final, last_local), losses = jax.lax.scan(body, (state, state), batches)
        return last_local, final, losses

    return jax.jit(drive, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Serving extraction (the trained fleet's artifacts)
# ---------------------------------------------------------------------------


def serving_params(state: P2PState) -> PyTree:
    """Extract the personalized serving artifact from a trained state.

    The stacked (K, ...) per-peer parameter tree, detached from the
    optimizer/consensus leaves — P2PL's product is K *divergent* models, and
    this is the exact layout the stacked serving runtime consumes
    (``repro.launch.serve.make_fleet_generate_fn`` /
    ``make_fleet_classify_fn``): the same leading-K axis, so
    ``sharding.specs.peer_stacked_pspecs`` places training state and serving
    fleet identically.
    """
    return state.params


def consensus_averaged_params(
    stacked_params: PyTree, data_sizes: np.ndarray | None = None
) -> PyTree:
    """The ONE-model serving baseline: average the K peer rows, broadcast back.

    Collapses the stacked tree to its (data-weighted, else uniform) fp32
    average and re-broadcasts it to all K rows, so the averaged baseline
    routes through the IDENTICAL stacked serving path as the personalized
    fleet — the per-peer accuracy A/B (what personalization buys) differs
    only in the parameter rows, never in the serving code.
    """
    k = jax.tree.leaves(stacked_params)[0].shape[0]
    if data_sizes is None:
        w = jnp.full((k,), 1.0 / k, jnp.float32)
    else:
        sizes = jnp.asarray(data_sizes, jnp.float32)
        w = sizes / jnp.sum(sizes)

    def avg(p):
        mean = jnp.tensordot(w, p.astype(jnp.float32), axes=1)
        return jnp.broadcast_to(mean.astype(p.dtype), p.shape)

    return jax.tree.map(avg, stacked_params)


# ---------------------------------------------------------------------------
# Evaluation helpers (stratified accuracy — the paper's seen/unseen split)
# ---------------------------------------------------------------------------


def evaluate_stacked(
    apply_fn: Callable[[PyTree, jax.Array], jax.Array],
    params: PyTree,
    images: jax.Array,
    labels: jax.Array,
) -> jax.Array:
    """Per-peer test accuracy: (K,) from stacked params on a shared test set."""

    def acc(p):
        logits = apply_fn(p, images)
        return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))

    return jax.vmap(acc)(params)


def stratified_accuracy(
    apply_fn: Callable[[PyTree, jax.Array], jax.Array],
    params: PyTree,
    images: jax.Array,
    labels: jax.Array,
    class_groups: dict[str, np.ndarray],
) -> dict[str, jax.Array]:
    """Accuracy per named class group (e.g. {"seen": [0,1], "unseen": [7,8]}).

    Predictions are restricted to the union of all group classes, matching the
    paper's K-class tasks (e.g. 4-class task over {0,1,7,8}).
    """
    all_classes = np.sort(np.concatenate(list(class_groups.values())))

    def preds(p):
        # restrict predictions to the task's class set (the paper's K-class tasks)
        logits = apply_fn(p, images)
        m = jnp.full((logits.shape[-1],), -1e9, jnp.float32).at[jnp.asarray(all_classes)].set(0.0)
        return jnp.argmax(logits + m, axis=-1)

    pred = jax.vmap(preds)(params)  # (K, N)
    out = {}
    for name, classes in class_groups.items():
        sel = jnp.isin(labels, jnp.asarray(classes))
        denom = jnp.maximum(jnp.sum(sel), 1)
        out[name] = jnp.sum((pred == labels[None, :]) & sel[None, :], axis=1) / denom
    return out


def oscillation_amplitude(after_local: np.ndarray, after_consensus: np.ndarray) -> np.ndarray:
    """Mean |acc_after_consensus - acc_after_local| per round — the paper's
    sawtooth size.  Inputs: (rounds,) or (rounds, K)."""
    a = np.asarray(after_local, np.float64)
    c = np.asarray(after_consensus, np.float64)
    return np.abs(c - a).mean(axis=-1) if a.ndim > 1 else np.abs(c - a)
