"""Communication graphs and mixing matrices for peer-to-peer learning.

The paper (Sec. III-C) models the network as a flat, undirected, connected
graph; devices exchange parameters only over its edges.  Mixing matrices are
row-stochastic (P2PL, Sec. IV-B) — the paper's choice is data-size weighted:

    alpha_kj = n_j / (n_k + sum_{i in N(k)} n_i)        (neighbors j)
    alpha_kk = 1 - sum_j alpha_kj

Doubly-stochastic variants (metropolis, uniform) are provided for the
local-DSGD baselines common in the literature [10], [12].

Beyond the paper, graphs may be *directed* (``CommGraph(a, directed=True)``):
``adjacency[i, j]`` then means "i sends to j" — a peer can push without
receiving, the Sparse-Push setting.  Directed rounds need *column*-stochastic
weights (``column_stochastic_matrix``) consumed by the push-sum consensus
protocol (see repro/core/protocols.py); row-stochastic gossip on a directed
graph would silently bias the consensus point.

State-dependent (adaptive) schedules
------------------------------------
Everything above is *pretraced*: a ``GraphSchedule`` is a host-built, periodic
stack of graphs chosen before the first round, and the jitted runtime merely
indexes it with ``round_idx % R``.  The adaptive family at the bottom of this
module breaks that assumption: ``adaptive_round_matrices`` builds one round's
W/Beta **on device, inside the traced program**, from run state — the K-vector
of per-peer recent training losses plus a PRNG key threaded through
``P2PState`` (see ``repro.core.p2p.AdaptiveState``).  Partner selection is a
greedy minimum-score perfect matching (``greedy_matching``) over one of three
score rules (``ADAPTIVE_RULES``):

    loss_proximity — score[i, j] = |loss_i - loss_j|: peers gossip with the
                     peer whose training loss is closest (Onoszko et al.,
                     2107.08517 — loss-proximal peers tend to hold similar
                     data, so averaging with them costs less local progress);
    random         — symmetric uniform scores: the random-matching baseline,
                     re-sampled from the threaded key every round;
    eps_greedy     — with probability eps the round explores (random scores),
                     otherwise it exploits loss proximity.  The coin is per
                     round, not per peer, so the matching stays a matching.

The resulting matchings are symmetric (partner[partner[k]] == k), every
matrix builder guarantees exact row- (gossip) or column- (push_sum)
stochasticity on device, and nothing here leaves the trace: one compile
covers an entire adaptive run with no host callback.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

TOPOLOGIES = (
    "complete",
    "ring",
    "chain",
    "star",
    "torus2d",
    "erdos_renyi",
    "hypercube",
    "disconnected",  # for "no consensus" baselines (self-loops only)
    "directed_ring",  # i -> i+1 only: the canonical push-sum topology
)


def _reachable(adjacency: np.ndarray, start: int = 0) -> np.ndarray:
    k = adjacency.shape[0]
    seen = np.zeros(k, dtype=bool)
    stack = [start]
    seen[start] = True
    while stack:
        v = stack.pop()
        for u in np.nonzero(adjacency[v])[0]:
            if not seen[u]:
                seen[u] = True
                stack.append(int(u))
    return seen


@dataclasses.dataclass(frozen=True)
class CommGraph:
    """A communication graph over K peers.

    adjacency: (K, K) bool, no self loops.  ``adjacency[i, j]`` = "i sends to
    j"; undirected graphs (the default) must be symmetric, ``directed=True``
    admits one-way edges (a peer can push without receiving).
    """

    adjacency: np.ndarray
    directed: bool = False

    def __post_init__(self):
        """Validate squareness, symmetry (if undirected), and no self loops."""
        a = np.asarray(self.adjacency, dtype=bool)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if not self.directed and not np.array_equal(a, a.T):
            raise ValueError("graph must be undirected (symmetric adjacency)")
        if a.diagonal().any():
            raise ValueError("no self loops in adjacency (self weight is alpha_kk)")
        object.__setattr__(self, "adjacency", a)

    @property
    def num_peers(self) -> int:
        """K, the number of peers (rows of the adjacency)."""
        return self.adjacency.shape[0]

    def neighbors(self, k: int) -> np.ndarray:
        """Peers that peer k sends to (out-neighbors; all nbrs if undirected)."""
        return np.nonzero(self.adjacency[k])[0]

    def in_neighbors(self, k: int) -> np.ndarray:
        """Peers whose parameters peer k receives (== neighbors if undirected)."""
        return np.nonzero(self.adjacency[:, k])[0]

    def degree(self) -> np.ndarray:
        """(K,) out-degree per peer (== in_degree for undirected graphs)."""
        return self.adjacency.sum(axis=1)

    def in_degree(self) -> np.ndarray:
        """(K,) number of peers each peer receives from."""
        return self.adjacency.sum(axis=0)

    def out_degree(self) -> np.ndarray:
        """(K,) number of peers each peer sends to."""
        return self.adjacency.sum(axis=1)

    def is_connected(self) -> bool:
        """Weak connectivity (edge directions ignored)."""
        return bool(_reachable(self.adjacency | self.adjacency.T).all())

    def is_strongly_connected(self) -> bool:
        """Every peer reaches every peer along directed edges (push-sum's
        requirement for the de-biased estimates to converge)."""
        return bool(_reachable(self.adjacency).all() and _reachable(self.adjacency.T).all())

    def max_degree(self) -> int:
        """Max *in*-degree — the padded neighbor width of the sparse mixing
        row (== max degree for undirected graphs)."""
        return int(self.in_degree().max()) if self.num_peers else 0


def build_graph(topology: str, num_peers: int, *, p: float = 0.3, seed: int = 0) -> CommGraph:
    """Construct a named topology over ``num_peers`` devices."""
    k = num_peers
    if k < 1:
        raise ValueError("need at least one peer")
    a = np.zeros((k, k), dtype=bool)
    if topology == "complete":
        a = ~np.eye(k, dtype=bool)
        if k == 1:
            a = np.zeros((1, 1), dtype=bool)
    elif topology == "ring":
        for i in range(k):
            a[i, (i + 1) % k] = a[(i + 1) % k, i] = True
        np.fill_diagonal(a, False)
    elif topology == "chain":
        for i in range(k - 1):
            a[i, i + 1] = a[i + 1, i] = True
    elif topology == "star":
        a[0, 1:] = a[1:, 0] = True
    elif topology == "torus2d":
        side = int(round(np.sqrt(k)))
        if side * side != k:
            raise ValueError(f"torus2d needs a square peer count, got {k}")
        idx = lambda r, c: r * side + c  # noqa: E731
        for r in range(side):
            for c in range(side):
                a[idx(r, c), idx((r + 1) % side, c)] = True
                a[idx((r + 1) % side, c), idx(r, c)] = True
                a[idx(r, c), idx(r, (c + 1) % side)] = True
                a[idx(r, (c + 1) % side), idx(r, c)] = True
        np.fill_diagonal(a, False)
    elif topology == "hypercube":
        dim = int(round(np.log2(k)))
        if 2**dim != k:
            raise ValueError(f"hypercube needs a power-of-2 peer count, got {k}")
        for i in range(k):
            for d in range(dim):
                j = i ^ (1 << d)
                a[i, j] = a[j, i] = True
    elif topology == "erdos_renyi":
        rng = np.random.default_rng(seed)
        while True:
            u = rng.random((k, k)) < p
            a = np.triu(u, 1)
            a = a | a.T
            g = CommGraph(a)
            if g.is_connected():
                return g
    elif topology == "disconnected":
        pass  # all-zero adjacency: every peer isolated
    elif topology == "directed_ring":
        for i in range(k):
            a[i, (i + 1) % k] = True
        np.fill_diagonal(a, False)
        return CommGraph(a, directed=True)
    else:
        raise ValueError(f"unknown topology {topology!r}; one of {TOPOLOGIES}")
    return CommGraph(a)


# ---------------------------------------------------------------------------
# Mixing matrices
# ---------------------------------------------------------------------------

MIXINGS = ("data_weighted", "metropolis", "uniform_neighbor", "identity")


def mixing_matrix(
    graph: CommGraph,
    mixing: str = "data_weighted",
    *,
    data_sizes: Sequence[int] | None = None,
    consensus_step_size: float | np.ndarray = 1.0,
) -> np.ndarray:
    """Row-stochastic mixing matrix W with W[k, j] = alpha_kj.

    data_weighted — the paper's choice (Sec. V-A):
        alpha_kj = n_j / (n_k + sum_{i in N(k)} n_i), alpha_kk = remainder.
    metropolis — doubly stochastic: alpha_kj = 1 / (1 + max(deg_k, deg_j)).
    uniform_neighbor — alpha_kj = 1 / (deg_k + 1) (row stochastic).
    identity — no mixing (isolated training baseline).

    Neighbors are *in*-neighbors (the peers whose parameters k receives) —
    identical to the undirected notion on symmetric graphs.  Note that a
    row-stochastic W on a genuinely directed graph converges to a *biased*
    consensus point; directed runs should use ``column_stochastic_matrix``
    with the push-sum protocol instead.

    consensus_step_size: the paper's per-device epsilon_k^(t); W_eps =
    (1 - eps_k) I + eps_k W applied row-wise. eps=1 reproduces W.
    """
    k = graph.num_peers
    adj = graph.adjacency
    if mixing == "identity":
        w = np.eye(k)
    elif mixing == "data_weighted":
        if data_sizes is None:
            data_sizes = np.ones(k)
        n = np.asarray(data_sizes, dtype=np.float64)
        if n.shape != (k,) or (n <= 0).any():
            raise ValueError("data_sizes must be positive, one per peer")
        w = np.zeros((k, k))
        for i in range(k):
            nbrs = np.nonzero(adj[:, i])[0]
            denom = n[i] + n[nbrs].sum()
            w[i, nbrs] = n[nbrs] / denom
            w[i, i] = 1.0 - w[i, nbrs].sum()
    elif mixing == "metropolis":
        deg = graph.in_degree()
        w = np.zeros((k, k))
        for i in range(k):
            for j in np.nonzero(adj[:, i])[0]:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
            w[i, i] = 1.0 - w[i].sum()
    elif mixing == "uniform_neighbor":
        deg = graph.in_degree()
        w = np.zeros((k, k))
        for i in range(k):
            nbrs = np.nonzero(adj[:, i])[0]
            w[i, nbrs] = 1.0 / (deg[i] + 1.0)
            w[i, i] = 1.0 - w[i, nbrs].sum()
    else:
        raise ValueError(f"unknown mixing {mixing!r}; one of {MIXINGS}")

    eps = np.asarray(consensus_step_size, dtype=np.float64)
    if eps.ndim == 0:
        eps = np.full(k, float(eps))
    if eps.shape != (k,):
        raise ValueError("consensus_step_size must be scalar or (K,)")
    w = (1.0 - eps)[:, None] * np.eye(k) + eps[:, None] * w

    assert np.all(w >= -1e-12), "mixing weights must be nonnegative"
    assert np.allclose(w.sum(axis=1), 1.0), "mixing matrix must be row stochastic"
    return w


def column_stochastic_matrix(
    graph: CommGraph,
    mixing: str = "data_weighted",
    *,
    data_sizes: Sequence[int] | None = None,
    consensus_step_size: float | np.ndarray = 1.0,
) -> np.ndarray:
    """Column-stochastic push weights A with A[k, j] = mass j pushes to k.

    Column j splits sender j's mass over its *out*-neighbors and itself
    (sum_k A[k, j] = 1), so the total mass sum_k y_k is conserved every round
    — the push-sum invariant — on any directed, even disconnected, graph:

    data_weighted — out-neighbor k gets mass proportional to its data size:
        A[k, j] = n_k / (n_j + sum_{i in out(j)} n_i), A[j, j] = remainder.
    metropolis   — A[k, j] = 1 / (1 + max(outdeg_j, outdeg_k)) per edge j->k.
    uniform_neighbor — the classic push-sum split: A[k, j] = 1/(outdeg_j + 1).
    identity     — no mixing.

    On an undirected graph with ``metropolis`` weighting A is symmetric
    doubly-stochastic, i.e. identical to ``mixing_matrix`` — push-sum then
    degenerates to plain gossip with unit mass.

    consensus_step_size: per-device epsilon applied column-wise,
    A_eps = (1 - eps_j) I + eps_j A — still column-stochastic.
    """
    k = graph.num_peers
    adj = graph.adjacency
    if mixing == "identity":
        a = np.eye(k)
    elif mixing == "data_weighted":
        if data_sizes is None:
            data_sizes = np.ones(k)
        n = np.asarray(data_sizes, dtype=np.float64)
        if n.shape != (k,) or (n <= 0).any():
            raise ValueError("data_sizes must be positive, one per peer")
        a = np.zeros((k, k))
        for j in range(k):
            out = np.nonzero(adj[j])[0]
            denom = n[j] + n[out].sum()
            a[out, j] = n[out] / denom
            a[j, j] = 1.0 - a[out, j].sum()
    elif mixing == "metropolis":
        deg = graph.out_degree()
        a = np.zeros((k, k))
        for j in range(k):
            for i in np.nonzero(adj[j])[0]:
                a[i, j] = 1.0 / (1.0 + max(deg[j], deg[i]))
            a[j, j] = 1.0 - a[:, j].sum()
    elif mixing == "uniform_neighbor":
        deg = graph.out_degree()
        a = np.zeros((k, k))
        for j in range(k):
            out = np.nonzero(adj[j])[0]
            a[out, j] = 1.0 / (deg[j] + 1.0)
            a[j, j] = 1.0 - a[out, j].sum()
    else:
        raise ValueError(f"unknown mixing {mixing!r}; one of {MIXINGS}")

    eps = np.asarray(consensus_step_size, dtype=np.float64)
    if eps.ndim == 0:
        eps = np.full(k, float(eps))
    if eps.shape != (k,):
        raise ValueError("consensus_step_size must be scalar or (K,)")
    a = np.eye(k) * (1.0 - eps)[None, :] + eps[None, :] * a

    assert np.all(a >= -1e-12), "push weights must be nonnegative"
    assert np.allclose(a.sum(axis=0), 1.0), "push matrix must be column stochastic"
    assert np.all(np.diag(a) > 0), "senders must retain some mass (positive diagonal)"
    return a


def affinity_matrix(graph: CommGraph, *, data_sizes: Sequence[int] | None = None) -> np.ndarray:
    """Beta matrix for the affinity bias d (Sec. V-C):

        beta_kj = n_j / sum_{i in N(k)} n_i  for j in N(k), else 0.

    N(k) are k's *in*-neighbors (the peers it hears from; == neighbors on
    undirected graphs).  Rows sum to 1 over neighbors only (no self weight).
    Isolated peers get an all-zero row (d stays 0 — no neighbors to be
    biased toward).
    """
    k = graph.num_peers
    adj = graph.adjacency
    if data_sizes is None:
        data_sizes = np.ones(k)
    n = np.asarray(data_sizes, dtype=np.float64)
    b = np.zeros((k, k))
    for i in range(k):
        nbrs = np.nonzero(adj[:, i])[0]
        if len(nbrs) == 0:
            continue
        b[i, nbrs] = n[nbrs] / n[nbrs].sum()
    return b


# ---------------------------------------------------------------------------
# Time-varying graph schedules
# ---------------------------------------------------------------------------

SCHEDULES = (
    "static",
    "link_dropout",
    "random_matching",
    "peer_churn",
    "round_robin",
    "one_way_matching",  # directed: random sender->receiver pairs per round
)


@dataclasses.dataclass(frozen=True)
class GraphSchedule:
    """A periodic sequence of communication graphs, one per round.

    Round ``r`` communicates over ``graphs[r % period]``.  A period-1 schedule
    is exactly the paper's fixed-topology setting; longer periods model churn:
    links dropping (Sparse-Push-style time-varying graphs), gossip pairs
    re-sampled every round, or peers going offline.  All graphs must share the
    same peer count; individual rounds MAY be disconnected (consensus then
    relies on connectivity of the union over a window, the standard
    B-connectivity assumption of time-varying consensus analyses).
    """

    graphs: tuple[CommGraph, ...]
    name: str = "static"

    def __post_init__(self):
        """Validate a non-empty schedule with a uniform peer count."""
        graphs = tuple(self.graphs)
        if not graphs:
            raise ValueError("schedule needs at least one graph")
        k = graphs[0].num_peers
        if any(g.num_peers != k for g in graphs):
            raise ValueError("all graphs in a schedule must share the peer count")
        object.__setattr__(self, "graphs", graphs)

    @property
    def period(self) -> int:
        """R, the number of graphs before the schedule repeats."""
        return len(self.graphs)

    @property
    def num_peers(self) -> int:
        """K, shared by every graph in the schedule."""
        return self.graphs[0].num_peers

    @property
    def directed(self) -> bool:
        """True iff ANY round's graph is directed (drives protocol checks)."""
        return any(g.directed for g in self.graphs)

    def graph_at(self, round_idx: int) -> CommGraph:
        """The round's graph: periodic indexing ``round_idx % period``."""
        return self.graphs[round_idx % self.period]

    def max_degree(self) -> int:
        """Max (in-)degree over all rounds — the padding width for sparse kernels."""
        return max(g.max_degree() for g in self.graphs)

    def union_graph(self) -> CommGraph:
        """OR of all adjacencies: the B-connectivity window of one period."""
        adj = np.zeros((self.num_peers, self.num_peers), dtype=bool)
        for g in self.graphs:
            adj |= g.adjacency
        return CommGraph(adj, directed=self.directed)

    def union_is_connected(self) -> bool:
        """Weak connectivity of the period union (B-connectivity check)."""
        return self.union_graph().is_connected()

    def union_is_strongly_connected(self) -> bool:
        """Strong connectivity of the period union — push-sum's condition for
        the de-biased estimates to reach consensus (trivially equal to
        ``union_is_connected`` for undirected schedules)."""
        return self.union_graph().is_strongly_connected()


def static_schedule(graph: CommGraph) -> GraphSchedule:
    """Period-1 wrapper — backwards-compatible fixed topology."""
    return GraphSchedule((graph,), name="static")


def link_dropout_schedule(
    base: CommGraph, survival_prob: float, rounds: int, *, seed: int = 0
) -> GraphSchedule:
    """Each base edge independently survives each round with prob ``survival_prob``.

    On a directed base every directed edge is dropped *independently* — a
    round may keep i->j while losing j->i, exactly the asymmetric-link
    failures push-sum is built for.  Undirected bases drop whole links.
    """
    if not 0.0 < survival_prob <= 1.0:
        raise ValueError("survival_prob must be in (0, 1]")
    if rounds < 1:
        raise ValueError("need at least one round")
    rng = np.random.default_rng(seed)
    k = base.num_peers
    graphs = []
    if base.directed:
        ei, ej = np.nonzero(base.adjacency)
        for _ in range(rounds):
            keep = rng.random(len(ei)) < survival_prob
            a = np.zeros((k, k), dtype=bool)
            a[ei[keep], ej[keep]] = True
            graphs.append(CommGraph(a, directed=True))
        return GraphSchedule(tuple(graphs), name="link_dropout")
    iu, ju = np.triu_indices(k, 1)
    edge_mask = base.adjacency[iu, ju]
    for _ in range(rounds):
        keep = edge_mask & (rng.random(len(iu)) < survival_prob)
        a = np.zeros((k, k), dtype=bool)
        a[iu[keep], ju[keep]] = True
        graphs.append(CommGraph(a | a.T))
    return GraphSchedule(tuple(graphs), name="link_dropout")


def random_matching_schedule(num_peers: int, rounds: int, *, seed: int = 0) -> GraphSchedule:
    """One-peer pairwise gossip: a random perfect matching per round.

    Every peer talks to at most one partner per round (classic randomized
    gossip); with odd ``num_peers`` one peer idles (self-loop via its own
    mixing weight).
    """
    if num_peers < 2:
        raise ValueError("matching needs at least two peers")
    if rounds < 1:
        raise ValueError("need at least one round")
    rng = np.random.default_rng(seed)
    graphs = []
    for _ in range(rounds):
        perm = rng.permutation(num_peers)
        a = np.zeros((num_peers, num_peers), dtype=bool)
        for p in range(0, num_peers - 1, 2):
            i, j = perm[p], perm[p + 1]
            a[i, j] = a[j, i] = True
        graphs.append(CommGraph(a))
    return GraphSchedule(tuple(graphs), name="random_matching")


def peer_churn_schedule(
    base: CommGraph, online_prob: float, rounds: int, *, seed: int = 0
) -> GraphSchedule:
    """Peers go offline/online per round; offline peers lose all their edges.

    An offline peer keeps training locally but neither sends nor receives —
    its mixing row degenerates to the self-loop (weight 1) and its affinity
    row to zero, so its parameters and d bias are untouched by consensus.
    """
    if not 0.0 < online_prob <= 1.0:
        raise ValueError("online_prob must be in (0, 1]")
    if rounds < 1:
        raise ValueError("need at least one round")
    rng = np.random.default_rng(seed)
    k = base.num_peers
    graphs = []
    for _ in range(rounds):
        online = rng.random(k) < online_prob
        a = base.adjacency & online[:, None] & online[None, :]
        graphs.append(CommGraph(a))
    return GraphSchedule(tuple(graphs), name="peer_churn")


def one_way_matching_schedule(num_peers: int, rounds: int, *, seed: int = 0) -> GraphSchedule:
    """Directed pairwise gossip: a random one-way matching per round.

    Each round pairs peers at random and each pair transmits in ONE direction
    (sender -> receiver) — the Sparse-Push communication pattern where a push
    costs the sender nothing in return traffic.  Row-stochastic gossip cannot
    average under this schedule (receivers double-count, senders are never
    heard); the push-sum protocol's mass correction makes it exact.
    """
    if num_peers < 2:
        raise ValueError("matching needs at least two peers")
    if rounds < 1:
        raise ValueError("need at least one round")
    rng = np.random.default_rng(seed)
    graphs = []
    for _ in range(rounds):
        perm = rng.permutation(num_peers)
        a = np.zeros((num_peers, num_peers), dtype=bool)
        for p in range(0, num_peers - 1, 2):
            a[perm[p], perm[p + 1]] = True  # perm[p] sends, perm[p+1] receives
        graphs.append(CommGraph(a, directed=True))
    return GraphSchedule(tuple(graphs), name="one_way_matching")


def round_robin_schedule(graphs: Sequence[CommGraph]) -> GraphSchedule:
    """Cycle deterministically over a fixed list of graphs."""
    return GraphSchedule(tuple(graphs), name="round_robin")


# ---------------------------------------------------------------------------
# Permutation-lane extraction (sharded peer-axis runtime)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PermLane:
    """One ``jax.lax.ppermute``'s worth of edges.

    ``ppermute`` requires distinct sources and distinct destinations, so a
    round's edge set is partitioned into lanes (a bipartite edge coloring);
    the sharded runtime issues one ppermute per lane per consensus step.

    perm:         static ((src, dst), ...) pairs fed to ppermute verbatim.
    src_for_dst:  (K,) — src_for_dst[k] is the peer whose payload k receives
                  in this lane, or the sentinel K when k receives nothing
                  (the receiver scatters with ``mode="drop"``).
    """

    perm: tuple[tuple[int, int], ...]
    src_for_dst: tuple[int, ...]


def edge_color_lanes(adjacency: np.ndarray) -> tuple[PermLane, ...]:
    """Partition ``adjacency[src, dst]`` edges into ppermute-able lanes.

    Greedy bipartite edge coloring: each lane uses every peer at most once as
    a source and at most once as a destination.  Deterministic (row-major edge
    order); lane count is at most in_degree + out_degree - 1 per Vizing-style
    bounds, and exactly the max degree for the regular graphs we ship
    (rings: 1-2 lanes, matchings: 1).
    """
    adjacency = np.asarray(adjacency, dtype=bool)
    k = adjacency.shape[0]
    lanes: list[dict[int, int]] = []  # per lane: dst -> src
    for src, dst in zip(*np.nonzero(adjacency)):
        src, dst = int(src), int(dst)
        for lane in lanes:
            if dst not in lane and src not in lane.values():
                lane[dst] = src
                break
        else:
            lanes.append({dst: src})
    out = []
    for lane in lanes:
        src_for_dst = np.full((k,), k, dtype=np.int32)
        for dst, src in lane.items():
            src_for_dst[dst] = src
        out.append(
            PermLane(
                perm=tuple(sorted((src, dst) for dst, src in lane.items())),
                src_for_dst=tuple(int(s) for s in src_for_dst),
            )
        )
    return tuple(out)


def schedule_lanes(schedule: GraphSchedule) -> tuple[PermLane, ...]:
    """Static ppermute lanes covering the UNION of the period's edge sets.

    One lane set serves every round of the schedule, so the jitted sharded
    round keeps the one-compile property: the lanes (and their perms) are
    trace-time constants while the round's mixing weights — selected with
    ``round_idx % R`` inside the program — zero out any lane edge absent from
    that round's graph.
    """
    return edge_color_lanes(schedule.union_graph().adjacency)


def schedule_matrices(
    schedule: GraphSchedule,
    mixing: str = "data_weighted",
    *,
    data_sizes: Sequence[int] | None = None,
    consensus_step_size: float | np.ndarray = 1.0,
    stochasticity: str = "row",
) -> tuple[np.ndarray, np.ndarray]:
    """Stacked per-round mixing/affinity matrices: (R, K, K) W and Beta.

    Row ``r`` is the mixing matrix of ``schedule.graphs[r]`` under the same
    weighting rule; the jitted runtime indexes this stack with
    ``round_idx % R`` so every round reuses one compiled program.

    stochasticity: "row" (gossip, ``mixing_matrix``) or "column" (push-sum,
    ``column_stochastic_matrix``).
    """
    if stochasticity == "row":
        build = mixing_matrix
    elif stochasticity == "column":
        build = column_stochastic_matrix
    else:
        raise ValueError(f"unknown stochasticity {stochasticity!r}; 'row' or 'column'")
    w = np.stack(
        [
            build(
                g, mixing, data_sizes=data_sizes, consensus_step_size=consensus_step_size
            )
            for g in schedule.graphs
        ]
    )
    beta = np.stack(
        [affinity_matrix(g, data_sizes=data_sizes) for g in schedule.graphs]
    )
    return w, beta


# ---------------------------------------------------------------------------
# Sparse degree-bounded schedules (large-K form of schedule_matrices)
# ---------------------------------------------------------------------------


def _padded_in_neighbors(
    mask: np.ndarray, degree_bound: int
) -> tuple[np.ndarray, np.ndarray]:
    """Padded neighbor lists from a row-oriented neighbor mask.

    ``mask[i, j]`` = "j is a neighbor of row i".  Returns ``(idx, valid)``:
    ``idx`` (K, D) int32 lists each row's neighbors in increasing index order
    (the same order ``np.nonzero`` yields, so weight sums reduce in the dense
    builders' order), padded with the row's own index; ``valid`` marks the
    real slots.
    """
    k = mask.shape[0]
    d = int(degree_bound)
    deg = mask.sum(axis=1)
    if d < int(deg.max(initial=0)):
        raise ValueError(
            f"degree_bound={d} below the actual max degree {int(deg.max())}"
        )
    # stable argsort of the negated mask puts True (neighbor) columns first,
    # in increasing column order
    order = np.argsort(~mask, axis=1, kind="stable")[:, :d].astype(np.int32)
    valid = np.arange(d)[None, :] < deg[:, None]
    own = np.arange(k, dtype=np.int32)[:, None]
    return np.where(valid, order, own), valid


def _slot_sum(vals: np.ndarray, valid: np.ndarray) -> np.ndarray:
    """Per-row sum over the real slots, in slot (== increasing index) order —
    the same sequential accumulation order as the dense builders' row sums."""
    return np.where(valid, vals, 0.0).sum(axis=1)


def _check_data_sizes(n, k: int) -> np.ndarray:
    if n is None:
        n = np.ones(k)
    n = np.asarray(n, dtype=np.float64)
    if n.shape != (k,) or (n <= 0).any():
        raise ValueError("data_sizes must be positive, one per peer")
    return n


def _check_eps(consensus_step_size, k: int) -> np.ndarray:
    eps = np.asarray(consensus_step_size, dtype=np.float64)
    if eps.ndim == 0:
        eps = np.full(k, float(eps))
    if eps.shape != (k,):
        raise ValueError("consensus_step_size must be scalar or (K,)")
    return eps


def _sparse_row_weights(
    graph: CommGraph,
    mixing: str,
    n: np.ndarray,
    eps: np.ndarray,
    nbr_idx: np.ndarray,
    valid: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """(self_w (K,), nbr_w (K, D)) — the rows of ``mixing_matrix`` without
    ever building (K, K).  Value-for-value identical to the dense builder:
    the same elementwise float64 expressions, summed in the same order."""
    k = graph.num_peers
    if mixing == "identity":
        nbr_w = np.zeros(nbr_idx.shape)
        self_w = np.ones(k)
    elif mixing == "data_weighted":
        denom = n + _slot_sum(n[nbr_idx], valid)
        nbr_w = np.where(valid, n[nbr_idx] / denom[:, None], 0.0)
        self_w = 1.0 - _slot_sum(nbr_w, valid)
    elif mixing == "metropolis":
        deg = graph.in_degree().astype(np.float64)
        nbr_w = np.where(
            valid, 1.0 / (1.0 + np.maximum(deg[:, None], deg[nbr_idx])), 0.0
        )
        self_w = 1.0 - _slot_sum(nbr_w, valid)
    elif mixing == "uniform_neighbor":
        deg = graph.in_degree().astype(np.float64)
        nbr_w = np.where(valid, 1.0 / (deg[:, None] + 1.0), 0.0)
        self_w = 1.0 - _slot_sum(nbr_w, valid)
    else:
        raise ValueError(f"unknown mixing {mixing!r}; one of {MIXINGS}")
    # consensus step size, row-wise: W_eps = (1 - eps) I + eps W
    nbr_w = eps[:, None] * nbr_w
    self_w = (1.0 - eps) + eps * self_w
    return self_w, nbr_w


def _sparse_col_weights(
    graph: CommGraph,
    mixing: str,
    n: np.ndarray,
    eps: np.ndarray,
    nbr_idx: np.ndarray,
    valid: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """(self_w (K,), nbr_w (K, D)) rows of ``column_stochastic_matrix``.

    ``nbr_w[i, s]`` is A[i, j] for in-neighbor j = nbr_idx[i, s] (the mass j
    pushes to i); the diagonal is a COLUMN property (sender j's retained
    mass), so it reduces over each sender's padded out-neighbor slots.
    """
    k = graph.num_peers
    adj = graph.adjacency
    if mixing == "identity":
        return np.ones(k), np.zeros(nbr_idx.shape)
    # out-neighbor structure: out_idx[j] = receivers of sender j's mass
    out_deg = graph.out_degree()
    out_idx, out_valid = _padded_in_neighbors(adj, max(int(out_deg.max()), 1))
    if mixing == "data_weighted":
        denom = n + _slot_sum(n[out_idx], out_valid)  # per sender j
        nbr_w = np.where(valid, n[:, None] / denom[nbr_idx], 0.0)
        col_vals = np.where(out_valid, n[out_idx] / denom[:, None], 0.0)
        self_w = 1.0 - _slot_sum(col_vals, out_valid)
    elif mixing == "metropolis":
        deg = out_deg.astype(np.float64)
        nbr_w = np.where(
            valid, 1.0 / (1.0 + np.maximum(deg[nbr_idx], deg[:, None])), 0.0
        )
        col_vals = np.where(
            out_valid, 1.0 / (1.0 + np.maximum(deg[:, None], deg[out_idx])), 0.0
        )
        self_w = 1.0 - _slot_sum(col_vals, out_valid)
    elif mixing == "uniform_neighbor":
        deg = out_deg.astype(np.float64)
        nbr_w = np.where(valid, 1.0 / (deg[nbr_idx] + 1.0), 0.0)
        col_vals = np.where(out_valid, 1.0 / (deg[:, None] + 1.0), 0.0)
        self_w = 1.0 - _slot_sum(col_vals, out_valid)
    else:
        raise ValueError(f"unknown mixing {mixing!r}; one of {MIXINGS}")
    # consensus step size, column-wise: A_eps = I (1 - eps) + eps A
    nbr_w = eps[nbr_idx] * nbr_w
    self_w = (1.0 - eps) + eps * self_w
    return self_w, nbr_w


def _sparse_beta(
    n: np.ndarray, nbr_idx: np.ndarray, valid: np.ndarray
) -> np.ndarray:
    """Padded rows of ``affinity_matrix``: beta[i, s] = n_j / sum_nbrs n,
    zero rows for isolated peers."""
    nsum = _slot_sum(n[nbr_idx], valid)
    safe = np.where(nsum > 0, nsum, 1.0)
    return np.where(valid & (nsum > 0)[:, None], n[nbr_idx] / safe[:, None], 0.0)


@dataclasses.dataclass(frozen=True)
class SparseSchedule:
    """Degree-bounded sparse form of a schedule's per-round mixing constants.

    The large-K counterpart of ``schedule_matrices``: instead of (R, K, K)
    dense stacks — 128 MB of float64 per matrix at K = 4096 — each round is a
    padded CSR-style edge list with a STATIC degree bound D:

        self_w  (R, K)    — retained self weight (W[r, i, i] / A[r, i, i])
        nbr_idx (R, K, D) — int32 global indices of row i's in-neighbors, in
                            increasing index order, padded with i's own index
        nbr_w   (R, K, D) — the off-diagonal weight per slot (0.0 at padding)
        beta    (R, K, D) — the affinity weight per slot (0.0 at padding)

    All weights are float64 (like the dense builders); runtimes cast to f32 at
    upload, so a value extracted here and a value sliced from the dense stack
    cast to the SAME f32 bits.  ``stochasticity`` records whether ``nbr_w``
    rows came from the row-stochastic (gossip) or column-stochastic
    (push-sum) builder.

    Conversion is lossless against the dense path: ``from_dense`` extracts
    the dense stacks' values verbatim and ``to_dense`` scatters them back —
    ``to_dense(from_dense(w, beta)) == (w, beta)`` exactly, and
    ``from_dense(*to_dense(s)) == s`` whenever every edge carries a nonzero
    weight (all weightings except "identity").  ``from_schedule`` builds the
    same values directly from the graphs without materializing (K, K) floats,
    for fleets far past the dense path's K <= 64 comfort zone.
    """

    self_w: np.ndarray  # (R, K) float64
    nbr_idx: np.ndarray  # (R, K, D) int32
    nbr_w: np.ndarray  # (R, K, D) float64
    beta: np.ndarray  # (R, K, D) float64
    stochasticity: str = "row"
    name: str = "static"

    def __post_init__(self):
        """Validate the padded (R, K, D) slot arrays and index bounds."""
        self_w = np.asarray(self.self_w, dtype=np.float64)
        nbr_idx = np.asarray(self.nbr_idx, dtype=np.int32)
        nbr_w = np.asarray(self.nbr_w, dtype=np.float64)
        beta = np.asarray(self.beta, dtype=np.float64)
        if self_w.ndim != 2:
            raise ValueError(f"self_w must be (R, K), got {self_w.shape}")
        r, k = self_w.shape
        for name, arr in (("nbr_idx", nbr_idx), ("nbr_w", nbr_w), ("beta", beta)):
            if arr.ndim != 3 or arr.shape[:2] != (r, k):
                raise ValueError(
                    f"{name} must be (R, K, D) matching self_w {self_w.shape}, "
                    f"got {arr.shape}"
                )
        if nbr_idx.shape != nbr_w.shape or nbr_w.shape != beta.shape:
            raise ValueError("nbr_idx, nbr_w, beta must share one (R, K, D) shape")
        if (nbr_idx < 0).any() or (nbr_idx >= k).any():
            raise ValueError("nbr_idx entries must index peers in [0, K)")
        if self.stochasticity not in ("row", "column"):
            raise ValueError(
                f"stochasticity must be 'row' or 'column', got {self.stochasticity!r}"
            )
        object.__setattr__(self, "self_w", self_w)
        object.__setattr__(self, "nbr_idx", nbr_idx)
        object.__setattr__(self, "nbr_w", nbr_w)
        object.__setattr__(self, "beta", beta)

    @property
    def period(self) -> int:
        """R, the number of rounds before the schedule repeats."""
        return self.self_w.shape[0]

    @property
    def num_peers(self) -> int:
        """K, the number of peers."""
        return self.self_w.shape[1]

    @property
    def degree_bound(self) -> int:
        """D, the padded per-peer neighbor-slot width."""
        return self.nbr_idx.shape[2]

    def round_edges(self, r: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Round ``r``'s edge list: (senders, receivers, weights) over the
        real (non-padding) slots — j -> i for each weight W[i, j]."""
        r = r % self.period
        recv, slot = np.nonzero(self.nbr_idx[r] != np.arange(self.num_peers)[:, None])
        send = self.nbr_idx[r, recv, slot]
        return send, recv.astype(np.int64), self.nbr_w[r, recv, slot]

    def to_dense(self) -> tuple[np.ndarray, np.ndarray]:
        """Scatter back to dense (R, K, K) (w, beta) stacks.

        Padding slots carry weight 0.0 and target the diagonal, so the
        scatter-add leaves every dense entry exactly equal to the value it
        was extracted (or built) from.  Meant for the K <= 64 parity regime —
        at K = 4096 this materializes the very arrays the sparse form avoids.
        """
        r, k, _ = self.nbr_idx.shape
        rows = np.arange(k)[None, :, None]
        rr = np.arange(r)[:, None, None]
        w = np.zeros((r, k, k))
        w[rr[..., 0], rows[..., 0], rows[..., 0]] = self.self_w
        np.add.at(w, (np.broadcast_to(rr, self.nbr_idx.shape),
                      np.broadcast_to(rows, self.nbr_idx.shape),
                      self.nbr_idx), self.nbr_w)
        beta = np.zeros((r, k, k))
        np.add.at(beta, (np.broadcast_to(rr, self.nbr_idx.shape),
                         np.broadcast_to(rows, self.nbr_idx.shape),
                         self.nbr_idx), self.beta)
        return w, beta

    @classmethod
    def from_dense(
        cls,
        w_stack: np.ndarray,
        beta_stack: np.ndarray,
        *,
        stochasticity: str = "row",
        degree_bound: int | None = None,
        name: str = "static",
    ) -> "SparseSchedule":
        """Verbatim extraction from dense (R, K, K) stacks (K <= 64 regime).

        The neighbor pattern of row i is the union of nonzero off-diagonal
        ``w`` and nonzero ``beta`` entries; values are copied bit-for-bit, so
        the round trip through ``to_dense`` is exact.
        """
        w_stack = np.asarray(w_stack, dtype=np.float64)
        beta_stack = np.asarray(beta_stack, dtype=np.float64)
        if w_stack.ndim != 3 or w_stack.shape != beta_stack.shape:
            raise ValueError(
                "w/beta must be matching (R, K, K) stacks, got "
                f"{w_stack.shape} and {beta_stack.shape}"
            )
        r, k, _ = w_stack.shape
        eye = np.eye(k, dtype=bool)
        pattern = ((w_stack != 0) | (beta_stack != 0)) & ~eye
        if degree_bound is None:
            degree_bound = max(1, int(pattern.sum(axis=2).max(initial=0)))
        rows = np.arange(k)[:, None]
        self_w = np.empty((r, k))
        idx = np.empty((r, k, degree_bound), np.int32)
        nbr_w = np.empty((r, k, degree_bound))
        beta_p = np.empty((r, k, degree_bound))
        for t in range(r):
            ix, valid = _padded_in_neighbors(pattern[t], degree_bound)
            self_w[t] = np.diagonal(w_stack[t])
            idx[t] = ix
            nbr_w[t] = np.where(valid, w_stack[t][rows, ix], 0.0)
            beta_p[t] = np.where(valid, beta_stack[t][rows, ix], 0.0)
        return cls(self_w, idx, nbr_w, beta_p, stochasticity=stochasticity, name=name)

    @classmethod
    def from_schedule(
        cls,
        schedule: GraphSchedule,
        mixing: str = "data_weighted",
        *,
        data_sizes: Sequence[int] | None = None,
        consensus_step_size: float | np.ndarray = 1.0,
        stochasticity: str = "row",
        degree_bound: int | None = None,
    ) -> "SparseSchedule":
        """Direct sparse build from the graphs — no (K, K) float stack, ever.

        Produces the exact values of ``schedule_matrices`` + ``from_dense``
        (same float64 expressions, same summation order) at any K; the
        neighbor pattern is the adjacency itself, so identity-mixing rounds
        keep their (weight-0) neighbor slots.
        """
        k = schedule.num_peers
        n = _check_data_sizes(data_sizes, k)
        eps = _check_eps(consensus_step_size, k)
        if degree_bound is None:
            degree_bound = max(1, schedule.max_degree())
        if stochasticity == "row":
            weights = _sparse_row_weights
        elif stochasticity == "column":
            weights = _sparse_col_weights
        else:
            raise ValueError(
                f"unknown stochasticity {stochasticity!r}; 'row' or 'column'"
            )
        self_w, idx, nbr_w, beta = [], [], [], []
        for g in schedule.graphs:
            ix, valid = _padded_in_neighbors(g.adjacency.T, degree_bound)
            sw, nw = weights(g, mixing, n, eps, ix, valid)
            self_w.append(sw)
            idx.append(ix)
            nbr_w.append(nw)
            beta.append(_sparse_beta(n, ix, valid))
        return cls(
            np.stack(self_w), np.stack(idx), np.stack(nbr_w), np.stack(beta),
            stochasticity=stochasticity, name=schedule.name,
        )


# ---------------------------------------------------------------------------
# Adaptive (state-dependent) partner selection — on-device, traceable
# ---------------------------------------------------------------------------

ADAPTIVE_RULES = ("loss_proximity", "random", "eps_greedy")

_MATCH_INF = jnp.float32(1e30)  # sentinel: masked (used-up) score entries


def partner_scores(
    losses: jax.Array,  # (K,) per-peer recent training losses
    key: jax.Array,  # PRNG key (uint32 (2,)) for this round's randomness
    rule: str = "loss_proximity",
    eps: float = 0.1,
) -> jax.Array:
    """Symmetric (K, K) pairing scores — LOWER is a more desirable partner.

    Traceable: ``rule``/``eps`` are trace-time constants, ``losses``/``key``
    are run state.  See module docstring for the three rules.
    """
    if rule not in ADAPTIVE_RULES:
        raise ValueError(f"unknown partner rule {rule!r}; one of {ADAPTIVE_RULES}")
    k = losses.shape[0]
    lf = losses.astype(jnp.float32)
    loss_s = jnp.abs(lf[:, None] - lf[None, :])
    if rule == "loss_proximity":
        return loss_s
    key_coin, key_scores = jax.random.split(key)
    u = jax.random.uniform(key_scores, (k, k), jnp.float32)
    rand_s = 0.5 * (u + u.T)  # symmetric, still uniform enough for ordering
    if rule == "random":
        return rand_s
    explore = jax.random.bernoulli(key_coin, eps)
    return jnp.where(explore, rand_s, loss_s)


def greedy_matching(scores: jax.Array) -> jax.Array:
    """Greedy minimum-score perfect matching over a symmetric (K, K) score
    matrix; returns ``partner`` (K,) int32 with ``partner[k] == k`` for an
    unmatched peer (odd K leaves exactly one).

    ``K // 2`` fixed-shape iterations of "take the global argmin pair, then
    mask both peers" — on the complete candidate graph every iteration finds a
    valid pair, so even K always yields a perfect matching.  Ties break
    deterministically (first flat index), keeping the selection bit-stable
    across the vmap and pod runtimes.
    """
    k = scores.shape[0]
    s0 = jnp.where(
        jnp.eye(k, dtype=bool), _MATCH_INF, scores.astype(jnp.float32)
    )
    partner0 = jnp.arange(k, dtype=jnp.int32)

    def body(_, carry):
        s, partner = carry
        flat = jnp.argmin(s)
        i = (flat // k).astype(jnp.int32)
        j = (flat % k).astype(jnp.int32)
        ok = s.reshape(-1)[flat] < _MATCH_INF  # all-masked => no pairs left
        paired = partner.at[i].set(j).at[j].set(i)
        partner = jnp.where(ok, paired, partner)
        used = (partner0 == i) | (partner0 == j)
        masked = jnp.where(used[:, None] | used[None, :], _MATCH_INF, s)
        s = jnp.where(ok, masked, s)
        return s, partner

    _, partner = jax.lax.fori_loop(0, k // 2, body, (s0, partner0))
    return partner


def matching_matrices(
    partner: jax.Array,  # (K,) int32, symmetric (partner[partner[k]] == k)
    *,
    data_sizes: jax.Array | None = None,
    consensus_step_size: float | jax.Array = 1.0,
    stochasticity: str = "row",
) -> tuple[jax.Array, jax.Array]:
    """On-device (W, Beta) for a pairwise matching round, dtype f32.

    Row form (gossip): W[k, p] = n_p / (n_k + n_p) for p = partner[k], the
    data-weighted rule of ``mixing_matrix`` restricted to degree <= 1; rows
    sum to exactly 1 by construction (the diagonal carries the remainder).
    Column form (push_sum): A[p, k] = n_p / (n_k + n_p) — sender k splits its
    mass between itself and its partner; columns sum to exactly 1.  On a
    symmetric matching A == W.T.  Beta is the affinity row: one-hot at the
    partner, all-zero for an unmatched peer (its d bias stays 0).

    ``consensus_step_size`` is the paper's epsilon: W_eps = (1 - eps) I +
    eps W applied row-wise (column-wise for the column form) — both remain
    exactly stochastic.
    """
    if stochasticity not in ("row", "column"):
        raise ValueError(
            f"unknown stochasticity {stochasticity!r}; 'row' or 'column'"
        )
    k = partner.shape[0]
    idx = jnp.arange(k, dtype=jnp.int32)
    n = (
        jnp.ones((k,), jnp.float32)
        if data_sizes is None
        else jnp.asarray(data_sizes, jnp.float32)
    )
    matched = partner != idx
    adj = (partner[:, None] == idx[None, :]) & matched[:, None]  # (K, K) bool
    denom = n[:, None] + n[None, :]
    beta = jnp.where(adj, 1.0, 0.0).astype(jnp.float32)
    eps = jnp.broadcast_to(
        jnp.asarray(consensus_step_size, jnp.float32), (k,)
    )
    eye = jnp.eye(k, dtype=jnp.float32)
    if stochasticity == "row":
        off = jnp.where(adj, n[None, :] / denom, 0.0)  # W[k, p] = n_p/(n_k+n_p)
        w = off + jnp.diag(1.0 - jnp.sum(off, axis=1))
        w = (1.0 - eps)[:, None] * eye + eps[:, None] * w
    else:
        off = jnp.where(adj, n[:, None] / denom, 0.0)  # A[p, k] = n_p/(n_k+n_p)
        w = off + jnp.diag(1.0 - jnp.sum(off, axis=0))
        w = (1.0 - eps)[None, :] * eye + eps[None, :] * w
    return w.astype(jnp.float32), beta


def adaptive_round_matrices(
    losses: jax.Array,  # (K,) per-peer recent training losses
    key: jax.Array,  # PRNG key for this round
    *,
    rule: str = "loss_proximity",
    eps: float = 0.1,
    data_sizes: jax.Array | None = None,
    consensus_step_size: float | jax.Array = 1.0,
    stochasticity: str = "row",
) -> tuple[jax.Array, jax.Array]:
    """One adaptive round's (W, Beta), computed entirely inside the trace.

    The composition the jitted round step calls: score -> greedy matching ->
    exactly-stochastic matrices.  No host callback, no recompile — the
    state-dependent topology subsystem's device-side entry point.
    """
    scores = partner_scores(losses, key, rule, eps)
    partner = greedy_matching(scores)
    return matching_matrices(
        partner,
        data_sizes=data_sizes,
        consensus_step_size=consensus_step_size,
        stochasticity=stochasticity,
    )


def spectral_gap(w: np.ndarray) -> float:
    """1 - |lambda_2| of the mixing matrix — the consensus rate.

    For row-stochastic (not necessarily symmetric) W we use the magnitudes of
    the eigenvalues; lambda_1 = 1 always.
    """
    eig = np.sort(np.abs(np.linalg.eigvals(w)))[::-1]
    if len(eig) < 2:
        return 1.0
    return float(1.0 - eig[1])
