"""TrainTask: the registry-backed bundle wiring a model into the peer axis.

Before this module, ``launch.train.run_paper_experiment`` had an implicit
contract — "the loss is always the paper's 2NN MLP built in
``configs/p2pl_mnist.py``" — and the model registry
(``repro.models.registry``: transformer / mamba2 / rwkv6 / moe with their
Pallas kernels) was a disjoint world.  A ``TrainTask`` makes that contract
explicit: everything the P2P drivers need to train a model end-to-end, chosen
by name through ``P2PConfig.model``.

A task provides:

``init_params(rng) -> params``
    One PEER's parameter pytree (the drivers vmap it over K split keys).
``loss_fn(params, batch) -> scalar``
    One peer's training loss on one batch.  It is traced ONCE per run inside
    the shared round step (the one-compile rule), so it must be pure jax with
    no data-dependent python control flow.
``apply_fn(params, inputs) -> (N, C) logits``
    The eval head ``p2p.stratified_accuracy`` vmaps over the stacked fleet.
``make_peer_batches(parts, batch_size, *, seed) -> batcher``
    Batcher over the per-peer shards of ``data/partition.py``; its
    ``round_batches(T)`` returns a batch pytree whose leaves are (T, K, ...)
    numpy arrays — step-major then peer, the ``local_phase`` layout.
``prepare_eval(x) -> inputs``
    Maps raw evaluation images to the model's input format (identity for the
    MLP; pixel-stream tokenization for sequence models).

``mnist_mlp`` is the legacy path STRUCTURALLY: its callables ARE
``models.mlp.init_2nn / loss_2nn / apply_2nn`` and its batcher IS
``data.pipeline.PeerBatcher`` — not wrappers — so selecting it traces the
exact pre-TrainTask expression graph (the fp32 bit-parity booby trap, like
``compressor="none"`` and ``staleness_bound=0`` before it).

``rwkv6_seqmnist`` is the first real-model workload: RWKV6 (Finch) run as a
recurrent network over the pixel stream of sequential MNIST — each 2x2-pooled
image becomes a 196-token intensity sequence, classified from the final
recurrent state — built from ``models.registry.build_sequence_classifier``
on a reduced ``ModelConfig``, trained under gossip AND push_sum in both the
vmap and pod runtimes via the scan driver.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.data import pipeline
from repro.models import mlp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainTask:
    """Everything the P2P drivers need to train one model family."""

    name: str
    init_params: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, Any], jax.Array]
    apply_fn: Callable[[PyTree, Any], jax.Array]
    make_peer_batches: Callable[..., Any]
    prepare_eval: Callable[[Any], Any]
    # None: the whole test set in ONE apply per peer (the legacy MLP eval
    # path, part of its bit-parity surface).  An int caps the eval minibatch:
    # sequence trunks materialize O(B * S * D)-and-worse intermediates, and
    # K peers x the full test set in one call OOMs on CI hosts.
    eval_batch_size: int | None = None
    # None: evaluate on the full test set.  An int subsamples it (seeded
    # permutation) — a 196-step recurrent forward over K peers x 10k test
    # sequences per eval round is minutes of CPU for a demo workload.
    eval_set_size: int | None = None
    description: str = ""


_BUILDERS: dict[str, Callable[[], TrainTask]] = {}
_CACHE: dict[str, TrainTask] = {}


def register_task(name: str, builder: Callable[[], TrainTask]) -> None:
    """Register a lazy task builder (built once, on first ``get_task``)."""
    if name in _BUILDERS:
        raise ValueError(f"task {name!r} already registered")
    _BUILDERS[name] = builder


def task_names() -> tuple[str, ...]:
    """Registered task names (no tasks are built)."""
    return tuple(sorted(_BUILDERS))


def get_task(name: str) -> TrainTask:
    """Build (once) and return the named task."""
    if name not in _BUILDERS:
        raise ValueError(f"unknown model {name!r}; one of {task_names()}")
    if name not in _CACHE:
        _CACHE[name] = _BUILDERS[name]()
    return _CACHE[name]


# ---------------------------------------------------------------------------
# mnist_mlp — the paper's 2NN, the structurally-identical legacy path
# ---------------------------------------------------------------------------


def _build_mnist_mlp() -> TrainTask:
    # the callables ARE the legacy ones — identity, not equivalence — so the
    # task-selected run traces the same program as the pre-TrainTask trainer
    return TrainTask(
        name="mnist_mlp",
        init_params=mlp.init_2nn,
        loss_fn=mlp.loss_2nn,
        apply_fn=mlp.apply_2nn,
        make_peer_batches=pipeline.PeerBatcher,
        prepare_eval=lambda x: x,
        description="the paper's 2NN MLP (784-200-200-10) on flat MNIST "
                    "images — the fp32 bit-parity legacy path",
    )


# ---------------------------------------------------------------------------
# rwkv6_seqmnist — RWKV6 in RNN mode over the pixel stream
# ---------------------------------------------------------------------------

# 2x2-pooled 28x28 -> 14x14 = 196 intensity tokens per image.  The classifier
# runs the trunk in RNN mode (token-sequential recurrence); chunk=49 tiles the
# sequence exactly (4 chunks, no padding) if the chunked scan is ever used.
SEQMNIST_POOL = 2
SEQMNIST_BINS = 16
_SEQMNIST_SEQ_LEN = (28 // SEQMNIST_POOL) ** 2


def seqmnist_model_config():
    """The reduced RWKV6 config of the sequential-MNIST task (CI-sized)."""
    from repro.configs.base import ModelConfig, SSMConfig

    return ModelConfig(
        name="rwkv6-seqmnist",
        family="rwkv6",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=SEQMNIST_BINS,
        ssm=SSMConfig(kind="rwkv6", state_dim=16, head_dim=16, chunk=49,
                      lora_rank=8),
        tie_embeddings=True,
        dtype="float32",
        remat=False,
    )


def _build_rwkv6_seqmnist() -> TrainTask:
    from repro.models import registry

    cfg = seqmnist_model_config()
    init, apply, loss = registry.build_sequence_classifier(cfg, num_classes=10)

    def make_peer_batches(parts, batch_size, *, seed=0, **kw):
        return pipeline.TokenSequenceBatcher(
            parts, batch_size, seed=seed,
            num_bins=SEQMNIST_BINS, pool=SEQMNIST_POOL, **kw,
        )

    return TrainTask(
        name="rwkv6_seqmnist",
        init_params=init,
        loss_fn=loss,
        apply_fn=apply,
        make_peer_batches=make_peer_batches,
        prepare_eval=lambda x: pipeline.images_to_tokens(
            x, num_bins=SEQMNIST_BINS, pool=SEQMNIST_POOL
        ),
        eval_batch_size=256,
        eval_set_size=512,
        description="RWKV6 (2 layers, d_model=64) as a recurrent net over "
                    f"the {_SEQMNIST_SEQ_LEN}-token pixel stream of "
                    "sequential MNIST, classified from the final state",
    )


register_task("mnist_mlp", _build_mnist_mlp)
register_task("rwkv6_seqmnist", _build_rwkv6_seqmnist)
