"""The declarative feature-compatibility table — ONE source of truth.

Every pairwise "feature A does not compose with feature B" rejection in the
repo lives here: the config layer (``P2PConfig.__post_init__``), the runtime
builders (``p2p._make_hier_round_step`` via ``make_sharded_round_fn``), the
launcher (``launch.train.run_paper_experiment``), and the CLI argparse layer
all call ``check()`` / ``check_config()`` and raise the SAME formatted
message through ``format_violation`` — so the error a user sees is identical
no matter which layer catches the combination first, and the README support
matrix is GENERATED from this table (``tools/check_support_matrix.py``)
instead of hand-maintained prose.

Structure:

* ``Feature`` — a named axis of the system with a ``predicate`` over a
  ``FeatureContext`` (is it active in this run?), a static ``title`` for the
  generated matrix, and a ``describe`` callback producing the concrete
  "what you asked for" clause of an error (e.g. ``compressor='topk'``).
* ``Incompatibility`` — an ordered (a, b) pair of feature names with the
  ``reason`` it cannot work and the ``workaround`` the error should suggest.
  Ordering is presentation only: the message reads "<a> is not supported
  with <b>: <reason>; <workaround>".
* ``FeatureContext`` — the plain-value snapshot the predicates see: the
  config axes plus the runtime axes a frozen config cannot know
  (``peers_per_device``).

Value validation (unknown names, out-of-range scalars) stays where the value
lives — this module owns only the *composition* rules.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class FeatureContext:
    """Plain-value snapshot of one run's feature axes.

    Built from a ``P2PConfig`` via ``context_from_config`` (runtime layers
    add ``peers_per_device``); kept as primitives so the table has no import
    edge back into ``core.p2p``.
    """

    schedule: str = "static"
    compressor: str = "none"
    steps_profile: str = "uniform"
    staleness_bound: int = 0
    model: str = "mnist_mlp"
    peers_per_device: int = 1


def context_from_config(cfg, *, peers_per_device: int = 1) -> FeatureContext:
    """Snapshot a ``P2PConfig``(-shaped) object into a ``FeatureContext``."""
    return FeatureContext(
        schedule=cfg.schedule,
        compressor=cfg.compressor,
        steps_profile=cfg.steps_profile,
        staleness_bound=cfg.staleness_bound,
        model=getattr(cfg, "model", "mnist_mlp"),
        peers_per_device=peers_per_device,
    )


@dataclasses.dataclass(frozen=True)
class Feature:
    """One composable axis: when is it on, and how is it named in errors."""

    name: str
    title: str  # static label for the generated support matrix
    predicate: Callable[[FeatureContext], bool]
    describe: Callable[[FeatureContext], str]  # concrete clause for errors


@dataclasses.dataclass(frozen=True)
class Incompatibility:
    """An (a, b) feature pair that must never be active together."""

    a: str
    b: str
    reason: str
    workaround: str


FEATURES: dict[str, Feature] = {
    f.name: f
    for f in (
        Feature(
            name="adaptive",
            title="schedule `adaptive` (loss-driven partner selection)",
            predicate=lambda c: c.schedule == "adaptive",
            describe=lambda c: "schedule='adaptive' (state-dependent partner "
                               "selection)",
        ),
        Feature(
            name="compression",
            title="compression `topk` / `qint8` (error feedback)",
            predicate=lambda c: c.compressor != "none",
            describe=lambda c: f"compressor={c.compressor!r} (compressed "
                               "gossip payloads)",
        ),
        Feature(
            name="staleness",
            title="async `staleness_bound > 0` (bounded-staleness gossip)",
            predicate=lambda c: c.staleness_bound > 0,
            describe=lambda c: f"staleness_bound={c.staleness_bound} "
                               "(bounded-staleness gossip)",
        ),
        Feature(
            name="async",
            title="async rounds (`--steps-profile` / `--staleness-bound`)",
            predicate=lambda c: (c.staleness_bound > 0
                                 or c.steps_profile != "uniform"),
            describe=lambda c: "asynchronous rounds (--steps-profile "
                               f"{c.steps_profile}, --staleness-bound "
                               f"{c.staleness_bound})",
        ),
        Feature(
            name="hierarchical",
            title="hierarchical runtime (`--peers-per-device > 1`)",
            predicate=lambda c: c.peers_per_device > 1,
            describe=lambda c: "the hierarchical runtime (peers_per_device "
                               f"= {c.peers_per_device} > 1)",
        ),
        Feature(
            name="real_model",
            title="registry TrainTask (`model != \"mnist_mlp\"`)",
            predicate=lambda c: c.model != "mnist_mlp",
            describe=lambda c: f"model={c.model!r} (a registry TrainTask)",
        ),
    )
}


INCOMPATIBILITIES: tuple[Incompatibility, ...] = (
    Incompatibility(
        a="staleness",
        b="adaptive",
        reason="the adaptive matching is derived from FRESH per-peer losses "
               "every round, which is exactly what a straggler cannot provide",
        workaround="run bounded-staleness gossip on a pretraced schedule, or "
                   "adaptive selection synchronously (staleness_bound=0)",
    ),
    Incompatibility(
        a="staleness",
        b="compression",
        reason="the staleness buffer stores raw sender snapshots while the "
               "compressed wire stores payload-advanced estimates — composing "
               "the two buffers is an open item",
        workaround="run async rounds uncompressed, or compression "
                   "synchronously (staleness_bound=0)",
    ),
    Incompatibility(
        a="adaptive",
        b="hierarchical",
        reason="the adaptive candidate set is the complete graph — dense "
               "O(K^2) matrices the hierarchical runtime's sparse "
               "degree-bounded path exists to avoid",
        workaround="run adaptive schedules with one peer per device "
                   "(peers_per_device=1), or use a pretraced schedule here",
    ),
    Incompatibility(
        a="compression",
        b="hierarchical",
        reason="the hierarchical bridge/segment mixes stream raw fp32 blocks, "
               "not payload-advanced estimates",
        workaround="run compressed gossip with one peer per device "
                   "(peers_per_device=1), or compressor='none' here",
    ),
    Incompatibility(
        a="async",
        b="hierarchical",
        reason="the hierarchical bridge/segment mixes stream live parameter "
               "blocks with no staleness buffer",
        workaround="run async rounds with one peer per device "
                   "(peers_per_device=1), or the uniform synchronous profile "
                   "here",
    ),
    Incompatibility(
        a="real_model",
        b="hierarchical",
        reason="the bridge/segment mixes and their sparse degree-bounded "
               "schedules are validated on the paper's 2NN only; a registry "
               "task's deep parameter tree has no hierarchical parity "
               "baseline yet",
        workaround="run registry tasks with one peer per device "
                   "(peers_per_device=1), or model='mnist_mlp' here",
    ),
)


def active_features(ctx: FeatureContext) -> tuple[str, ...]:
    """Names of the features a context switches on."""
    return tuple(n for n, f in FEATURES.items() if f.predicate(ctx))


def violations(ctx: FeatureContext) -> tuple[Incompatibility, ...]:
    """Table entries whose BOTH features are active in the context."""
    on = set(active_features(ctx))
    return tuple(i for i in INCOMPATIBILITIES if i.a in on and i.b in on)


def format_violation(inc: Incompatibility, ctx: FeatureContext) -> str:
    """THE formatter: every layer's composition error reads identically."""
    a, b = FEATURES[inc.a], FEATURES[inc.b]
    return (f"{a.describe(ctx)} is not supported with {b.describe(ctx)}: "
            f"{inc.reason}; {inc.workaround}")


def check(ctx: FeatureContext) -> None:
    """Raise ``ValueError`` on the first active incompatibility."""
    for inc in violations(ctx):
        raise ValueError(format_violation(inc, ctx))


def check_config(cfg, *, peers_per_device: int = 1) -> None:
    """``check`` over a ``P2PConfig``(-shaped) object, the common entry."""
    check(context_from_config(cfg, peers_per_device=peers_per_device))


def support_matrix_markdown() -> str:
    """Render the incompatibility table as the README's generated section.

    One row per table entry; regenerated/verified by
    ``tools/check_support_matrix.py`` so prose and code cannot drift.
    """
    lines = [
        "| feature | does not compose with | why |",
        "|---|---|---|",
    ]
    for inc in INCOMPATIBILITIES:
        lines.append(
            f"| {FEATURES[inc.a].title} | {FEATURES[inc.b].title} "
            f"| {inc.reason} |"
        )
    return "\n".join(lines) + "\n"
