"""Distributed average-consensus (gossip) operators.

Three execution forms of the same mathematical op — out_k = sum_j W[k,j] w_j:

1. **Stacked einsum** (`mix_stacked`): peer parameters are a pytree whose
   leaves carry a leading K axis. Used for CPU experiments (vmap runtime) and
   for the ``peer_axis="data"`` sharded mode, where the K axis is sharded over
   the mesh and XLA lowers the einsum into the appropriate collectives.
2. **Sparse gather** (`mix_sparse`): padded neighbor-index form; O(K * deg)
   instead of O(K^2). Feeds the Pallas `consensus_mix` kernel.
3. **Mesh collectives** (`mix_psum`, `mix_ring`): explicit collectives inside
   ``shard_map`` for ``peer_axis="pod"`` production mode — complete graphs map
   to a weighted all-reduce, ring graphs to two collective-permutes.

All operate on arbitrary pytrees and preserve leaf dtypes (mixing is computed
in float32 and cast back, matching how one would do it on TPU to avoid bf16
accumulation error across many neighbors).

These are the *primitive* mixing ops consumed by the consensus protocols in
``repro.core.protocols``: gossip's ``mix`` is exactly ``mix_stacked`` with a
row-stochastic W, and push-sum reuses the same einsum/gather forms with
column-stochastic weights re-scaled by the per-peer mass (the fused variant
lives in ``repro.kernels.consensus_mix.ops.consensus_mix_push_sum_stacked``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def mix_leaf(w_mat: jax.Array, leaf: jax.Array) -> jax.Array:
    """einsum over the leading peer axis, f32 accumulation (one leaf of
    ``mix_stacked``; public so leaf-pipelined consumers can call it per leaf)."""
    out = jnp.einsum(
        "kj,j...->k...",
        w_mat.astype(jnp.float32),
        leaf.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    )
    return out.astype(leaf.dtype)


def mix_stacked(w_mat: jax.Array, stacked: PyTree) -> PyTree:
    """Apply mixing matrix across the leading K axis of every leaf."""
    return jax.tree.map(lambda x: mix_leaf(w_mat, x), stacked)


# ---------------------------------------------------------------------------
# Sparse (padded-neighbor) form
# ---------------------------------------------------------------------------


def mixing_degrees(w_mat: np.ndarray) -> np.ndarray:
    """Per-peer neighbor count of a dense mixing matrix: off-diagonal nonzeros.

    The single definition of sparsity shared by ``sparse_mixing`` and the
    schedule-wide padding in ``consensus_mix.ops.sparse_from_schedule``.
    """
    off_diag = w_mat - np.diag(np.diag(w_mat))
    return (off_diag != 0).sum(axis=1)


def sparse_mixing(
    w_mat: np.ndarray, *, dmax: int | None = None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Convert a dense mixing matrix to padded (self_w, nbr_idx, nbr_w).

    nbr_idx: (K, Dmax) int32, padded with the peer's own index (weight 0).
    Returns numpy arrays — static per topology, closed over by jit.
    ``dmax`` overrides the padding width so every round of a time-varying
    schedule shares one shape (the max degree across the schedule).
    """
    k = w_mat.shape[0]
    off_diag = w_mat - np.diag(np.diag(w_mat))
    deg = mixing_degrees(w_mat)
    need = max(int(deg.max()), 1) if k else 1
    if dmax is None:
        dmax = need
    elif dmax < need:
        raise ValueError(f"dmax={dmax} below the actual max degree {need}")
    nbr_idx = np.tile(np.arange(k, dtype=np.int32)[:, None], (1, dmax))
    nbr_w = np.zeros((k, dmax), dtype=np.float32)
    for i in range(k):
        nbrs = np.nonzero(off_diag[i])[0]
        nbr_idx[i, : len(nbrs)] = nbrs
        nbr_w[i, : len(nbrs)] = off_diag[i, nbrs]
    self_w = np.diag(w_mat).astype(np.float32)
    return self_w, nbr_idx, nbr_w


def mix_sparse(
    self_w: jax.Array, nbr_idx: jax.Array, nbr_w: jax.Array, stacked: PyTree
) -> PyTree:
    """out_k = self_w[k] * x_k + sum_d nbr_w[k, d] * x[nbr_idx[k, d]]."""

    def leaf(x):
        xf = x.astype(jnp.float32)
        gathered = xf[nbr_idx]  # (K, Dmax, ...)
        bcast = nbr_w.reshape(nbr_w.shape + (1,) * (x.ndim - 1))
        sw = self_w.reshape((-1,) + (1,) * (x.ndim - 1))
        out = sw * xf + jnp.sum(bcast * gathered, axis=1)
        return out.astype(x.dtype)

    return jax.tree.map(leaf, stacked)


# ---------------------------------------------------------------------------
# Hierarchical (vmap-within-device x shard_map) forms
# ---------------------------------------------------------------------------


def scatter_rows(
    nbr_idx: jax.Array,  # (p, D) int32 — global column indices per row
    nbr_w: jax.Array,  # (p, D) f32 — weights (0.0 at padding slots)
    num_peers: int,
    *,
    row_ids: jax.Array | None = None,  # (p,) global row indices
    self_w: jax.Array | None = None,  # (p,) diagonal values, if any
) -> jax.Array:
    """Scatter padded sparse rows into a dense (p, K) weight block.

    The bridge between the degree-bounded ``graph.SparseSchedule`` operands
    and the dense row einsum: real slots place their weight at (row, idx);
    padding slots (idx == the row's own global index, weight 0.0) add +-0.0
    onto the diagonal entry, so the result equals the dense matrix block the
    sparse rows were extracted from — bit for bit, which is what lets the
    hierarchical runtime's K <= 64 "bridge" mode keep fp32 parity with the
    dense runtimes.
    """
    p = nbr_idx.shape[0]
    rows = jnp.arange(p, dtype=jnp.int32)
    block = jnp.zeros((p, num_peers), jnp.float32)
    if self_w is not None:
        if row_ids is None:
            raise ValueError("self_w placement needs the global row_ids")
        block = block.at[rows, row_ids].set(self_w.astype(jnp.float32))
    return block.at[rows[:, None], nbr_idx].add(nbr_w.astype(jnp.float32))


def ring_gather_slots(
    x_block: jax.Array,  # (p, ...) this device's contiguous block of rows
    nbr_idx: jax.Array,  # (p, D) int32 GLOBAL neighbor indices
    axis_name: str,
    num_devices: int,
) -> jax.Array:
    """Gather neighbor rows by global index across a block-sharded peer axis.

    Peers live block-major on the mesh: global row g sits on device g // p at
    local slot g % p.  The device's block streams around the ring — step s
    holds device (me + s)'s block after s ppermutes — and each step fills the
    slots whose owner just arrived, via a LOCAL take.  Returns (p, D, ...):
    per-device memory O(p * D * feat) and total traffic O(K * feat) per
    device, never a (K, ...) or (K, K) intermediate — the segment-mode
    communication primitive for fleets too large to all-gather.
    """
    p = x_block.shape[0]
    me = jax.lax.axis_index(axis_name)
    owner = nbr_idx // p  # (p, D) device holding each neighbor
    local = nbr_idx % p
    feat_dims = (1,) * (x_block.ndim - 1)
    perm = [(i, (i - 1) % num_devices) for i in range(num_devices)]
    visiting = x_block
    out = jnp.zeros(nbr_idx.shape + x_block.shape[1:], x_block.dtype)
    for s in range(num_devices):
        src = jax.lax.rem(me + s, num_devices)
        take = visiting[local]  # (p, D, ...)
        out = jnp.where((owner == src).reshape(owner.shape + feat_dims), take, out)
        if s + 1 < num_devices:
            visiting = jax.lax.ppermute(visiting, axis_name, perm=perm)
    return out


def mix_slots(
    self_w: jax.Array,  # (p,)
    nbr_w: jax.Array,  # (p, D)
    x_block: jax.Array,  # (p, ...)
    gathered: jax.Array,  # (p, D, ...) from ring_gather_slots
) -> jax.Array:
    """Segment-sum mix over gathered neighbor slots:
    out_i = self_w[i] * x_i + sum_d nbr_w[i, d] * gathered[i, d].
    f32 accumulation, cast back — the jnp twin of the Pallas segment kernel
    (kernels/consensus_mix/segment.py); O(p * D * feat), no (K, K)."""
    xf = x_block.astype(jnp.float32)
    gf = gathered.astype(jnp.float32)
    sw = self_w.reshape((-1,) + (1,) * (x_block.ndim - 1))
    bw = nbr_w.reshape(nbr_w.shape + (1,) * (x_block.ndim - 1))
    out = sw * xf + jnp.sum(bw * gf, axis=1)
    return out.astype(x_block.dtype)


def slot_sum(nbr_w: jax.Array, gathered: jax.Array) -> jax.Array:
    """Weighted slot reduction without the self term (affinity-beta form):
    out_i = sum_d nbr_w[i, d] * gathered[i, d], f32, cast back."""
    gf = gathered.astype(jnp.float32)
    bw = nbr_w.reshape(nbr_w.shape + (1,) * (gathered.ndim - 2))
    return jnp.sum(bw * gf, axis=1).astype(gathered.dtype)


# ---------------------------------------------------------------------------
# Mesh-collective forms (inside shard_map over the peer axis)
# ---------------------------------------------------------------------------


def gather_peer_leaf(v: jax.Array, axis_name: str, lanes, num_peers: int) -> jax.Array:
    """One leaf of ``gather_peer_rows``: (1, ...) block -> stacked (K, ...).

    Factored out so the sharded consensus phase can pipeline leaves — issuing
    leaf ``i+1``'s ppermutes while leaf ``i`` is still mixing (see
    ``repro.core.p2p.consensus_phase_sharded``) — without changing the
    per-leaf arithmetic that the bit-parity contract pins down.
    """
    my = jax.lax.axis_index(axis_name)
    full = jnp.zeros((num_peers,) + v.shape[1:], v.dtype)
    full = full.at[my].set(v[0])
    for lane in lanes:
        recv = jax.lax.ppermute(v, axis_name, perm=list(lane.perm))
        src = jnp.asarray(lane.src_for_dst, jnp.int32)[my]
        # sentinel src == num_peers marks "no payload this lane": dropped
        full = full.at[src].set(recv[0], mode="drop")
    return full


def gather_peer_rows(block: PyTree, axis_name: str, lanes, num_peers: int) -> PyTree:
    """Rebuild the stacked (K, ...) peer array inside a shard_map block.

    ``block`` leaves are this peer's (1, ...) slice of the stacked peer axis;
    ``lanes`` is a static ``graph.PermLane`` tuple (see ``edge_color_lanes``).
    One ppermute per lane sends the block along that lane's edges — the
    schedule-aware sparse communication pattern.  Rows of peers this shard
    never hears from stay ZERO; consumers multiply them by mixing weights that
    are zero on exactly those rows, so the zeros never contribute (and the
    reconstructed einsum stays bit-identical to the dense stacked form).
    """
    return jax.tree.map(
        lambda v: gather_peer_leaf(v, axis_name, lanes, num_peers), block
    )


def mix_psum(x: PyTree, axis_name: str, *, self_weight: float, peer_weight: float) -> PyTree:
    """Complete-graph gossip with uniform weights as one weighted all-reduce.

    out_k = self_weight * x_k + peer_weight * sum_{j != k} x_j
          = (self_weight - peer_weight) * x_k + peer_weight * psum(x).
    """

    def leaf(v):
        vf = v.astype(jnp.float32)
        total = jax.lax.psum(vf, axis_name)
        out = (self_weight - peer_weight) * vf + peer_weight * total
        return out.astype(v.dtype)

    return jax.tree.map(leaf, x)


def mix_ring(
    x: PyTree, axis_name: str, *, self_weight: float, left_weight: float, right_weight: float
) -> PyTree:
    """Ring-graph gossip: two collective_permutes + weighted sum."""
    # axis size via the psum-of-1 identity (jax.lax.axis_size is not
    # available on every supported jax version)
    n = jax.lax.psum(1, axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]

    def leaf(v):
        vf = v.astype(jnp.float32)
        from_left = jax.lax.ppermute(vf, axis_name, perm=fwd)
        from_right = jax.lax.ppermute(vf, axis_name, perm=bwd)
        out = self_weight * vf + left_weight * from_left + right_weight * from_right
        return out.astype(v.dtype)

    return jax.tree.map(leaf, x)


def mix_collective(
    x: PyTree,
    axis_name: str,
    w_row: jax.Array,
    *,
    topology: str = "complete",
) -> PyTree:
    """General row of a mixing matrix applied across a mesh axis.

    ``w_row`` is the (K,) weight row for *this* shard's peer index
    (use jax.lax.axis_index to select).  Complete topology uses an all-gather;
    sparse topologies should prefer mix_ring / mix_psum.
    """
    if topology == "complete":

        def leaf(v):
            vf = v.astype(jnp.float32)
            allv = jax.lax.all_gather(vf, axis_name)  # (K, ...)
            w = w_row.reshape((-1,) + (1,) * (allv.ndim - 1))
            return jnp.sum(w * allv, axis=0).astype(v.dtype)

        return jax.tree.map(leaf, x)
    raise ValueError(f"mix_collective only supports complete topology, got {topology!r}")


# ---------------------------------------------------------------------------
# Max-norm synchronization (P2PL initialization, Ref. [6])
# ---------------------------------------------------------------------------


def max_norm_sync(stacked: PyTree) -> PyTree:
    """All peers adopt, per leaf, the initialization with the largest L2 norm.

    P2PL replaces plain random init with a one-round synchronization where the
    highest-norm initialization wins (larger-norm inits preserve gradient
    diversity better after averaging).  Communication cost: one scalar norm
    exchange + one parameter broadcast — modeled here as an argmax-gather over
    the stacked peer axis.
    """

    def leaf(x):
        k = x.shape[0]
        norms = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32).reshape(k, -1)), axis=1))
        winner = jnp.argmax(norms)
        return jnp.broadcast_to(x[winner], x.shape).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


def consensus_error(stacked: PyTree) -> jax.Array:
    """Model drift metric: mean_k ||w_k - w_bar||_2 over all leaves (f32)."""
    leaves = jax.tree.leaves(stacked)
    k = leaves[0].shape[0]
    sq = jnp.zeros((k,), jnp.float32)
    for x in leaves:
        xf = x.astype(jnp.float32).reshape(k, -1)
        mean = jnp.mean(xf, axis=0, keepdims=True)
        sq = sq + jnp.sum(jnp.square(xf - mean), axis=1)
    return jnp.mean(jnp.sqrt(sq))


def pairwise_drift(stacked: PyTree) -> jax.Array:
    """Max over peer pairs of ||w_i - w_j||_2 — the paper's drift/divergence."""
    leaves = jax.tree.leaves(stacked)
    k = leaves[0].shape[0]
    sq = jnp.zeros((k, k), jnp.float32)
    for x in leaves:
        xf = x.astype(jnp.float32).reshape(k, -1)
        # ||x_i - x_j||^2 = ||x_i||^2 + ||x_j||^2 - 2 x_i . x_j
        n2 = jnp.sum(xf * xf, axis=1)
        sq = sq + n2[:, None] + n2[None, :] - 2.0 * (xf @ xf.T)
    return jnp.sqrt(jnp.maximum(sq, 0.0)).max()
