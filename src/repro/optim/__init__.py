"""Pure-JAX pytree optimizers (no optax in this container).

``sgd`` implements the paper's PyTorch-default Polyak momentum:
    buf <- mu * buf + g;  w <- w - lr * buf
``adamw`` for the LLM substrate.  All optimizers are (init, update) pairs over
arbitrary pytrees, f32 state regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple[PyTree, PyTree]]
    # update(grads, state, params, step) -> (new_params, new_state)


def sgd(lr: float | Callable[[jax.Array], jax.Array], momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _s: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, step):
        eta = lr_fn(step)
        if momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - eta * g.astype(jnp.float32)).astype(p.dtype),
                params,
                grads,
            )
            return new, state
        buf = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        new = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - eta * m).astype(p.dtype), params, buf
        )
        return new, buf

    return Optimizer(init, update)


def adamw(
    lr: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _s: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads
        )
        mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
        eta = lr_fn(step)

        def upd(p, mh_, vh_):
            pf = p.astype(jnp.float32)
            step_ = mh_ / (jnp.sqrt(vh_) + eps) + weight_decay * pf
            return (pf - eta * step_).astype(p.dtype)

        return jax.tree.map(upd, params, mh, vh), {"m": m, "v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float):
    return lambda _step: lr


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    return fn


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
