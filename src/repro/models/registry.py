"""Build a uniform Model interface from a ModelConfig.

Every family exposes:
    init(rng) -> params
    loss_fn(params, batch) -> scalar            (train step substrate)
    init_cache(batch, seq_len) -> cache         (decode substrate)
    prefill(params, batch, cache) -> (logits, cache)
    decode_step(params, token, pos, cache) -> (logits, cache)
    make_batch(rng, batch, seq) -> batch pytree (synthetic, family-correct)
    batch_specs(batch, seq) -> ShapeDtypeStruct pytree (dry-run stand-ins)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models import transformer as tf

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, PyTree], jax.Array]
    init_cache: Callable[[int, int], PyTree]
    prefill: Callable[[PyTree, PyTree, PyTree], tuple]
    decode_step: Callable[[PyTree, jax.Array, jax.Array, PyTree], tuple]
    make_batch: Callable[[jax.Array, int, int], PyTree]
    batch_specs: Callable[[int, int], PyTree]


def _token_batch(rng, cfg, b, s):
    k1, k2 = jax.random.split(rng)
    return {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size, jnp.int32),
    }


def _token_specs(cfg, b, s):
    t = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {"tokens": t, "labels": t}


def split_vlm_seq(cfg: ModelConfig, s: int) -> tuple[int, int]:
    np_ = min(cfg.num_prefix_embeddings, max(s - 1, 1))
    return np_, s - np_


def split_encdec_seq(s: int) -> tuple[int, int]:
    enc = max(s // 4, 1)
    return enc, max(s - enc, 1)


def build_sequence_classifier(cfg: ModelConfig, num_classes: int):
    """(init, apply, loss) for sequence classification on a registry family.

    ``apply(params, tokens (B, S) int32) -> (B, num_classes) f32 logits``:
    the family's trunk run over the token sequence, the final position's
    hidden state (the RNN summary for recurrent families) through one linear
    head.  ``loss(params, (tokens, labels (B,) int32))`` is mean cross
    entropy — the ``(params, batch) -> scalar`` shape ``core.p2p`` trains.

    Currently rwkv6-only: recurrent families have a natural "state after the
    whole sequence" readout; attention families would need pooling choices
    this signature does not yet take.
    """
    if cfg.family != "rwkv6":
        raise ValueError(
            f"build_sequence_classifier supports family 'rwkv6', got "
            f"{cfg.family!r}"
        )
    dtype = tf.compute_dtype(cfg)

    def init(key: jax.Array) -> PyTree:
        k_trunk, k_head = jax.random.split(key)
        params = tf.rwkv6_init_model(k_trunk, cfg)
        params["cls_head"] = {
            "w": common.dense_init(k_head, cfg.d_model, num_classes, dtype),
            "b": jnp.zeros((num_classes,), dtype),
        }
        return params

    def apply(params: PyTree, tokens: jax.Array) -> jax.Array:
        # RNN mode (chunked=False): token-sequential recurrence — for short
        # classification sequences it beats the chunked scan on CPU time AND
        # peak memory (no (B, heads, chunk, chunk) intermediates)
        h = tf.rwkv6_features(params, cfg, tokens, chunked=False)[:, -1]  # (B, D)
        head = params["cls_head"]
        return (h.astype(jnp.float32) @ head["w"].astype(jnp.float32)
                + head["b"].astype(jnp.float32))

    def loss(params: PyTree, batch) -> jax.Array:
        tokens, labels = batch
        return common.cross_entropy_loss(apply(params, tokens), labels)

    return init, apply, loss


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        init = lambda k: tf.decoder_init(k, cfg)
        loss = lambda p, b: tf.decoder_loss_fn(p, cfg, b)
        init_cache = lambda b, s: tf.decoder_init_cache(cfg, b, s)
        prefill = lambda p, batch, c: tf.decoder_prefill(p, cfg, batch, c)
        decode = lambda p, t, pos, c: tf.decoder_decode_step(p, cfg, t, pos, c)

        if fam == "vlm":

            def make_batch(rng, b, s):
                np_, st = split_vlm_seq(cfg, s)
                k1, k2 = jax.random.split(rng)
                out = _token_batch(k1, cfg, b, st)
                out["patches"] = jax.random.normal(k2, (b, np_, cfg.frontend_dim), jnp.float32)
                return out

            def batch_specs(b, s):
                np_, st = split_vlm_seq(cfg, s)
                out = _token_specs(cfg, b, st)
                out["patches"] = jax.ShapeDtypeStruct((b, np_, cfg.frontend_dim), jnp.float32)
                return out

        else:
            make_batch = lambda rng, b, s: _token_batch(rng, cfg, b, s)
            batch_specs = lambda b, s: _token_specs(cfg, b, s)

    elif fam == "rwkv6":
        init = lambda k: tf.rwkv6_init_model(k, cfg)
        loss = lambda p, b: tf.rwkv6_loss_fn(p, cfg, b)
        init_cache = lambda b, s: tf.rwkv6_init_state(cfg, b)
        prefill = lambda p, batch, c: tf.rwkv6_prefill(p, cfg, batch, c)
        decode = lambda p, t, pos, c: tf.rwkv6_decode_step(p, cfg, t, pos, c)
        make_batch = lambda rng, b, s: _token_batch(rng, cfg, b, s)
        batch_specs = lambda b, s: _token_specs(cfg, b, s)

    elif fam == "hybrid":
        init = lambda k: tf.hybrid_init(k, cfg)
        loss = lambda p, b: tf.hybrid_loss_fn(p, cfg, b)
        init_cache = lambda b, s: tf.hybrid_init_cache(cfg, b, s)
        prefill = lambda p, batch, c: tf.hybrid_prefill(p, cfg, batch, c)
        decode = lambda p, t, pos, c: tf.hybrid_decode_step(p, cfg, t, pos, c)
        make_batch = lambda rng, b, s: _token_batch(rng, cfg, b, s)
        batch_specs = lambda b, s: _token_specs(cfg, b, s)

    elif fam == "encdec":
        init = lambda k: tf.encdec_init(k, cfg)
        loss = lambda p, b: tf.encdec_loss_fn(p, cfg, b)

        def init_cache(b, s):
            enc, dec = split_encdec_seq(s)
            return tf.encdec_init_cache(cfg, b, dec, enc)

        prefill = lambda p, batch, c: tf.encdec_prefill(p, cfg, batch, c)
        decode = lambda p, t, pos, c: tf.encdec_decode_step(p, cfg, t, pos, c)

        def make_batch(rng, b, s):
            enc, dec = split_encdec_seq(s)
            k1, k2 = jax.random.split(rng)
            out = _token_batch(k1, cfg, b, dec)
            out["frames"] = jax.random.normal(k2, (b, enc, cfg.frontend_dim), jnp.float32)
            return out

        def batch_specs(b, s):
            enc, dec = split_encdec_seq(s)
            out = _token_specs(cfg, b, dec)
            out["frames"] = jax.ShapeDtypeStruct((b, enc, cfg.frontend_dim), jnp.float32)
            return out

    else:
        raise ValueError(f"unknown family {fam!r}")

    return Model(
        cfg=cfg,
        init=init,
        loss_fn=loss,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode,
        make_batch=make_batch,
        batch_specs=batch_specs,
    )
