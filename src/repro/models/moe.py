"""Mixture-of-Experts with grouped-capacity dispatch (expert parallel).

Dispatch strategy (pjit-friendly, no shard_map):
- tokens are split into G routing groups; at scale G = the mesh `data` axis
  size, so routing/gather/scatter never cross data shards (local routing with
  local capacity, as in GShard/Switch).
- within a group, top-k assignments receive a slot ``(expert, position)``
  where position = running count of that expert's tokens (capacity C;
  overflow tokens are dropped — standard capacity-factor semantics).
- expert compute is three grouped einsums over (G, E, C, D) with the expert
  axis E sharded over the mesh `model` axis (expert parallelism); the combine
  scatter-add produces a partial sum per model shard that XLA resolves with an
  all-reduce — the honest EP+TP collective cost (an all-to-all dispatch
  variant is a §Perf optimization, see EXPERIMENTS.md).

FLOPs are O(N * top_k * capacity_factor * D * F) — matching the paper-family
"activated parameters" accounting (no dense all-expert compute).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import common
from repro.sharding import logical


def init(key: jax.Array, d_model: int, cfg: MoEConfig, dtype) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, f = cfg.num_experts, cfg.expert_ff
    p = {
        "router": common.dense_init(kr, d_model, e, jnp.float32),
        "w_gate": common.truncated_normal_init(kg, (e, d_model, f), d_model**-0.5, dtype),
        "w_up": common.truncated_normal_init(ku, (e, d_model, f), d_model**-0.5, dtype),
        "w_down": common.truncated_normal_init(kd, (e, f, d_model), f**-0.5, dtype),
    }
    if cfg.num_shared:
        p["shared"] = common.mlp_init(ks, d_model, cfg.num_shared * f, dtype, gated=True)
    return p


def _num_groups(cfg: MoEConfig, n_tokens: int) -> int:
    g = max(1, cfg.router_groups)
    return math.gcd(g, n_tokens)


def capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    c = math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def apply(
    params: dict, cfg: MoEConfig, x: jax.Array, *, act: str = "silu"
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (out (B,S,D), load-balance aux loss scalar f32)."""
    b, s, d = x.shape
    n = b * s
    g = _num_groups(cfg, n)
    ng = n // g
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(cfg, ng)

    xg = x.reshape(g, ng, d)
    xg = logical.shard(xg, "expert_group", None, "embed")

    # --- routing (f32) ------------------------------------------------------
    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Ng, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G, Ng, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance loss: E * sum_e f_e * P_e  (Switch Transformer form)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (G, Ng, K, E)
    f_e = onehot.sum(axis=(1, 2)) / ng  # (G, E) fraction routed (pre-capacity)
    p_e = probs.mean(axis=1)  # (G, E)
    aux = e * jnp.mean(jnp.sum(f_e / k * p_e, axis=-1))

    # --- slot assignment ----------------------------------------------------
    # Flatten (token, k-choice) assignments in token order; position within
    # each expert = exclusive running count; position >= C drops the token.
    flat_e = expert_idx.reshape(g, ng * k)  # (G, A) expert id per assignment
    flat_gate = gate_vals.reshape(g, ng * k)
    flat_tok = jnp.broadcast_to(jnp.arange(ng)[:, None], (ng, k)).reshape(ng * k)
    flat_tok = jnp.broadcast_to(flat_tok, (g, ng * k))

    assign_oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (G, A, E)
    pos_in_e = jnp.cumsum(assign_oh, axis=1) - assign_oh  # exclusive cumsum
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=-1)[..., 0]  # (G, A)
    keep = pos < c
    dest = jnp.where(keep, flat_e * c + pos, e * c)  # overflow slot = e*c

    # All slot bookkeeping is vmapped over G so the group axis stays a true
    # scatter/gather *batch* dim — GSPMD partitions batch dims over `data`;
    # an explicit 2-D index formulation defeats that and replicates every
    # group on every chip (measured: 16x combine payload for deepseek-v2).
    def build_slots(dest_g, tok_g, gate_g):
        st = jnp.full((e * c + 1,), ng, jnp.int32).at[dest_g].set(tok_g)
        sg = jnp.zeros((e * c + 1,), jnp.float32).at[dest_g].set(gate_g)
        return st[:-1], sg[:-1]  # drop the overflow slot

    slot_tok, slot_gate = jax.vmap(build_slots)(dest, flat_tok, flat_gate)

    # --- gather -> expert compute -> combine --------------------------------
    x_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xe = jax.vmap(lambda xp, st: xp[st])(x_pad, slot_tok)  # (G, E*C, D)
    xe = xe.reshape(g, e, c, d)
    xe = logical.shard(xe, "expert_group", "experts", None, None)

    h_gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    h_up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    h = common.act_fn(act)(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    ye = logical.shard(ye, "expert_group", "experts", None, None)

    y_flat = ye.reshape(g, e * c, d) * slot_gate[..., None].astype(ye.dtype)

    # combine in the model dtype: each token receives at most top_k + shared
    # partial outputs, so bf16 accumulation is safe — and it halves the
    # expert-parallel psum payload (a measured 2x on the collective term).
    def combine(yt, st):
        return jnp.zeros((ng + 1, d), x.dtype).at[st].add(yt)

    out = jax.vmap(combine)(y_flat.astype(x.dtype), slot_tok)
    out = out[:, :ng]
    out = logical.shard(out, "expert_group", None, "embed")

    if "shared" in params:
        out = out + common.mlp_apply(params["shared"], xg, act=act).reshape(g, ng, d)

    return out.reshape(b, s, d), aux


def apply_dense_reference(
    params: dict, cfg: MoEConfig, x: jax.Array, *, act: str = "silu"
) -> tuple[jax.Array, jax.Array]:
    """O(N·E) oracle: every expert computed on every token, masked by top-k
    gates, no capacity dropping.  Used only in tests to validate `apply`."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    dense_gates = jnp.zeros_like(probs)
    nidx = jnp.arange(xf.shape[0])[:, None]
    dense_gates = dense_gates.at[nidx, expert_idx].set(gate_vals)  # (N, E)

    h_gate = jnp.einsum("nd,edf->enf", xf, params["w_gate"])
    h_up = jnp.einsum("nd,edf->enf", xf, params["w_up"])
    h = common.act_fn(act)(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up
    ye = jnp.einsum("enf,efd->end", h, params["w_down"])  # (E, N, D)
    out = jnp.einsum("end,ne->nd", ye.astype(jnp.float32), dense_gates)

    onehot = jax.nn.one_hot(expert_idx, cfg.num_experts, dtype=jnp.float32)
    f_e = onehot.sum(axis=(0, 1)) / xf.shape[0]
    p_e = probs.mean(axis=0)
    aux = cfg.num_experts * jnp.sum(f_e / cfg.top_k * p_e)

    out = out.astype(x.dtype)
    if "shared" in params:
        out = out + common.mlp_apply(params["shared"], x, act=act).reshape(-1, d)
    return out.reshape(b, s, d), aux
