"""Attention: GQA (full / sliding-window) and MLA (DeepSeek-V2), with KV caches.

Cache layout is unified: every cache carries ``pos_ids`` — the absolute
position stored in each slot (-1 = empty).  Full-causal caches have
``cache_len = max_seq``; sliding-window caches are ring buffers of
``cache_len = window`` (write slot = pos % window), which is what makes
``long_500k`` decode O(window) in memory for attention archs.

MLA caches the *latent* (c_kv, k_rope) — the paper-faithful DeepSeek-V2
design; ``mla_absorb`` switches decode to the weight-absorbed form that never
re-expands K/V over the cache length (a §Perf item).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models import common
from repro.sharding import logical

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init(key: jax.Array, d_model: int, cfg: AttentionConfig, dtype) -> dict:
    if cfg.kind == "mla":
        return _mla_init(key, d_model, cfg, dtype)
    return _gqa_init(key, d_model, cfg, dtype)


def _gqa_init(key, d_model, cfg: AttentionConfig, dtype) -> dict:
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "w_q": common.dense_init(kq, d_model, (h, dh), dtype),
        "w_k": common.dense_init(kk, d_model, (kh, dh), dtype),
        "w_v": common.dense_init(kv, d_model, (kh, dh), dtype),
        "w_o": common.dense_init(ko, h * dh, d_model, dtype),
    }
    if cfg.qkv_bias:
        del kb
        p["b_q"] = jnp.zeros((h, dh), dtype)
        p["b_k"] = jnp.zeros((kh, dh), dtype)
        p["b_v"] = jnp.zeros((kh, dh), dtype)
    return p


def _mla_init(key, d_model, cfg: AttentionConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    h = cfg.num_heads
    nope, rope, vd, lora = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    p = {
        "w_dkv": common.dense_init(ks[0], d_model, lora + rope, dtype),
        "kv_norm": common.rmsnorm_init(lora, dtype),
        "w_uk": common.dense_init(ks[1], lora, (h, nope), dtype),
        "w_uv": common.dense_init(ks[2], lora, (h, vd), dtype),
        "w_o": common.dense_init(ks[3], h * vd, d_model, dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = common.dense_init(ks[4], d_model, cfg.q_lora_rank, dtype)
        p["q_norm"] = common.rmsnorm_init(cfg.q_lora_rank, dtype)
        p["w_uq"] = common.dense_init(ks[5], cfg.q_lora_rank, (h, nope + rope), dtype)
    else:
        p["w_q"] = common.dense_init(ks[5], d_model, (h, nope + rope), dtype)
    return p


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg: AttentionConfig, batch: int, max_seq: int, dtype) -> dict:
    """Decode cache; ring buffer of size window when sliding-window.

    cache_quant="int8" stores K/V as int8 with a per-(slot, head) absmax
    scale — halves cache bytes (the decode memory-term floor) at <1e-2
    logit error (tests/test_perf_variants.py)."""
    cache_len = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    pos_ids = jnp.full((batch, cache_len), -1, jnp.int32)
    if cfg.kind == "mla":
        return {
            "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
            "pos_ids": pos_ids,
        }
    kv_shape = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    if cfg.cache_quant == "int8":
        return {
            "k": jnp.zeros(kv_shape, jnp.int8),
            "v": jnp.zeros(kv_shape, jnp.int8),
            "k_scale": jnp.zeros(kv_shape[:3], jnp.float16),
            "v_scale": jnp.zeros(kv_shape[:3], jnp.float16),
            "pos_ids": pos_ids,
        }
    return {
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
        "pos_ids": pos_ids,
    }


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, Kh, D) -> (int8 values, f16 per-(token, head) scales)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def _dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def cache_bytes(cfg: AttentionConfig, batch: int, max_seq: int, bytes_per_el: int = 2) -> int:
    cache_len = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    if cfg.kind == "mla":
        return batch * cache_len * (cfg.kv_lora_rank + cfg.qk_rope_dim) * bytes_per_el
    return batch * cache_len * 2 * cfg.num_kv_heads * cfg.head_dim * bytes_per_el


def _write_slots(cache_len: int, positions: jax.Array) -> jax.Array:
    """Ring-buffer slot for each absolute position (identity if cache covers seq)."""
    return positions % cache_len


def _scatter_cache(buf: jax.Array, slots: jax.Array, values: jax.Array) -> jax.Array:
    """buf: (B, C, ...); slots: (B, T); values: (B, T, ...)."""
    bidx = jnp.arange(buf.shape[0])[:, None]
    return buf.at[bidx, slots].set(values.astype(buf.dtype))


# ---------------------------------------------------------------------------
# Core attend
# ---------------------------------------------------------------------------


def _attend(q, k, v, mask, scale):
    """q: (B,T,Kh,G,dh) grouped query; k/v: (B,C,Kh,dh); mask: (B,1,1,T,C)."""
    scores = jnp.einsum("btkgd,bckd->bkgtc", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale + jnp.where(mask, 0.0, NEG_INF)  # mask: (B,1,1,T,C)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgtc,bckd->btkgd", probs, v.astype(jnp.float32))
    return ctx


def _make_mask(q_pos: jax.Array, kv_pos: jax.Array, window: Optional[int]) -> jax.Array:
    """(B, T, C) bool: causal, slot-valid, and optionally windowed."""
    m = (kv_pos[:, None, :] >= 0) & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        m &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    return m


def gqa_apply(
    params: dict,
    cfg: AttentionConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    causal: bool = True,
) -> tuple[jax.Array, Optional[dict]]:
    """x: (B, T, D); positions: (B, T) absolute. Returns (out, new_cache)."""
    b, t, _ = x.shape
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kh

    q = jnp.einsum("btd,dhx->bthx", x, params["w_q"])
    k = jnp.einsum("btd,dkx->btkx", x, params["w_k"])
    v = jnp.einsum("btd,dkx->btkx", x, params["w_v"])
    if "b_q" in params:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = logical.shard(q, "batch", "seq", "heads", "head_dim")
    k = logical.shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical.shard(v, "batch", "seq", "kv_heads", "head_dim")
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        cache_len = cache["k"].shape[1]
        slots = _write_slots(cache_len, positions)
        if "k_scale" in cache:  # int8-quantized cache
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            cache = {
                "k": _scatter_cache(cache["k"], slots, kq),
                "v": _scatter_cache(cache["v"], slots, vq),
                "k_scale": _scatter_cache(cache["k_scale"], slots, ks),
                "v_scale": _scatter_cache(cache["v_scale"], slots, vs),
                "pos_ids": _scatter_cache(cache["pos_ids"], slots, positions),
            }
            kk = _dequantize_kv(cache["k"], cache["k_scale"])
            vv = _dequantize_kv(cache["v"], cache["v_scale"])
            kv_pos = cache["pos_ids"]
        else:
            cache = {
                "k": _scatter_cache(cache["k"], slots, k),
                "v": _scatter_cache(cache["v"], slots, v),
                "pos_ids": _scatter_cache(cache["pos_ids"], slots, positions),
            }
            kk, vv, kv_pos = cache["k"], cache["v"], cache["pos_ids"]
    else:
        kk, vv, kv_pos = k, v, positions

    if causal:
        mask = _make_mask(positions, kv_pos, cfg.sliding_window)
    else:
        mask = (kv_pos[:, None, :] >= 0) & jnp.ones((b, t, 1), bool)
    qg = q.reshape(b, t, kh, g, dh)
    ctx = _attend(qg, kk, vv, mask[:, None, None], dh**-0.5)
    ctx = ctx.reshape(b, t, h * dh).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", ctx, params["w_o"])
    return logical.shard(out, "batch", "residual_seq", "embed"), cache


def cross_attention_apply(
    params: dict,
    cfg: AttentionConfig,
    x: jax.Array,
    enc_kv: tuple[jax.Array, jax.Array],
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V (no RoPE)."""
    b, t, _ = x.shape
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,dhx->bthx", x, params["w_q"])
    k, v = enc_kv
    qg = q.reshape(b, t, kh, h // kh, dh)
    mask = jnp.ones((b, 1, 1, t, k.shape[1]), bool)
    ctx = _attend(qg, k, v, mask, dh**-0.5).reshape(b, t, h * dh).astype(x.dtype)
    return jnp.einsum("bte,ed->btd", ctx, params["w_o"])


def encoder_kv(
    params: dict, cfg: AttentionConfig, enc_out: jax.Array
) -> tuple[jax.Array, jax.Array]:
    k = jnp.einsum("btd,dkx->btkx", enc_out, params["w_k"])
    v = jnp.einsum("btd,dkx->btkx", enc_out, params["w_v"])
    return k, v


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def _mla_q(params, cfg: AttentionConfig, x, positions):
    if cfg.q_lora_rank:
        cq = jnp.einsum("btd,dr->btr", x, params["w_dq"])
        cq = common.rmsnorm(params["q_norm"], cq)
        q = jnp.einsum("btr,rhx->bthx", cq, params["w_uq"])
    else:
        q = jnp.einsum("btd,dhx->bthx", x, params["w_q"])
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = common.apply_rope(q[..., cfg.qk_nope_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(
    params: dict,
    cfg: AttentionConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
) -> tuple[jax.Array, Optional[dict]]:
    b, t, _ = x.shape
    h = cfg.num_heads
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5

    q_nope, q_rope = _mla_q(params, cfg, x, positions)

    dkv = jnp.einsum("btd,dr->btr", x, params["w_dkv"])
    c_kv = common.rmsnorm(params["kv_norm"], dkv[..., : cfg.kv_lora_rank])
    k_rope = common.apply_rope(dkv[..., cfg.kv_lora_rank :], positions, cfg.rope_theta)
    c_kv = logical.shard(c_kv, "batch", "seq", "kv_lora")

    if cache is not None:
        cache_len = cache["c_kv"].shape[1]
        slots = _write_slots(cache_len, positions)
        cache = {
            "c_kv": _scatter_cache(cache["c_kv"], slots, c_kv),
            "k_rope": _scatter_cache(cache["k_rope"], slots, k_rope),
            "pos_ids": _scatter_cache(cache["pos_ids"], slots, positions),
        }
        c_all, krope_all, kv_pos = cache["c_kv"], cache["k_rope"], cache["pos_ids"]
    else:
        c_all, krope_all, kv_pos = c_kv, k_rope, positions

    mask = _make_mask(positions, kv_pos, cfg.sliding_window)[:, None]  # (B,1,T,C)

    if cfg.mla_absorb and cache is not None:
        # Absorbed decode: score/context directly in the latent space.
        q_lat = jnp.einsum(
            "bthn,rhn->bthr", q_nope.astype(jnp.float32), params["w_uk"].astype(jnp.float32)
        )
        scores = jnp.einsum("bthr,bcr->bhtc", q_lat, c_all.astype(jnp.float32))
        scores += jnp.einsum(
            "bthp,bcp->bhtc", q_rope.astype(jnp.float32), krope_all.astype(jnp.float32)
        )
        scores = scores * scale + jnp.where(mask, 0.0, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhtc,bcr->bthr", probs, c_all.astype(jnp.float32))
        ctx = jnp.einsum("bthr,rhv->bthv", ctx_lat, params["w_uv"].astype(jnp.float32))
    else:
        # Expanded path (training / prefill / naive decode baseline).
        k_nope = jnp.einsum("bcr,rhn->bchn", c_all, params["w_uk"])
        vv = jnp.einsum("bcr,rhv->bchv", c_all, params["w_uv"])
        scores = jnp.einsum(
            "bthn,bchn->bhtc", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32)
        )
        scores += jnp.einsum(
            "bthp,bcp->bhtc", q_rope.astype(jnp.float32), krope_all.astype(jnp.float32)
        )
        scores = scores * scale + jnp.where(mask, 0.0, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhtc,bchv->bthv", probs, vv.astype(jnp.float32))

    ctx = ctx.reshape(b, t, h * cfg.v_head_dim).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", ctx, params["w_o"])
    return logical.shard(out, "batch", "residual_seq", "embed"), cache


def apply(params, cfg: AttentionConfig, x, positions, *, cache=None, causal=True):
    if cfg.kind == "mla":
        return mla_apply(params, cfg, x, positions, cache=cache)
    return gqa_apply(params, cfg, x, positions, cache=cache, causal=causal)
