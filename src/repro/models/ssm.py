"""State-space / linear-attention blocks: Mamba2 (SSD) and RWKV6 (Finch).

Both are implemented three ways:
- ``*_scan``   — sequential recurrence (the oracle; also the decode step),
- ``*_chunked``— chunk-parallel form used for training/prefill: intra-chunk
  pairwise attention + inter-chunk state recurrence, processed under
  ``lax.scan`` over chunks so peak memory is O(chunk^2) not O(L^2).  This is
  also exactly the tiling the Pallas kernels use (see repro/kernels/mamba2,
  repro/kernels/rwkv6).
- Pallas TPU kernels for the hot inner loops (validated against these).

Numerical invariant of the chunked forms: every decay factor appears as
exp(cum_t - cum_s) with t >= s and non-positive log-decays, so all weights are
<= 1 — no overflow regardless of decay magnitude.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import common
from repro.sharding import logical


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba2_dims(d_model: int, cfg: SSMConfig) -> dict:
    d_inner = cfg.expand * d_model
    nheads = d_inner // cfg.head_dim
    conv_channels = d_inner + 2 * cfg.ngroups * cfg.state_dim
    return dict(d_inner=d_inner, nheads=nheads, conv_channels=conv_channels)


def mamba2_init(key: jax.Array, d_model: int, cfg: SSMConfig, dtype) -> dict:
    dims = mamba2_dims(d_model, cfg)
    d_in, h, cc = dims["d_inner"], dims["nheads"], dims["conv_channels"]
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * cfg.ngroups * cfg.state_dim + h
    return {
        "in_proj": common.dense_init(ks[0], d_model, d_proj, dtype),
        "conv_w": common.truncated_normal_init(
            ks[1], (cfg.conv_dim, cc), cfg.conv_dim**-0.5, dtype
        ),
        "conv_b": jnp.zeros((cc,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1 at init
        "D": jnp.ones((h,), jnp.float32),
        "norm": common.rmsnorm_init(d_in, dtype),
        "out_proj": common.dense_init(ks[2], d_in, d_model, dtype),
    }


def mamba2_state(d_model: int, cfg: SSMConfig, batch: int, dtype) -> dict:
    dims = mamba2_dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, dims["conv_channels"]), dtype),
        "ssm": jnp.zeros((batch, dims["nheads"], cfg.head_dim, cfg.state_dim), jnp.float32),
    }


def _mamba2_preproc(params, cfg: SSMConfig, x, conv_state=None):
    """in_proj + causal depthwise conv; returns (z, xh, Bm, Cm, dt, new_conv_state)."""
    b, l, d_model = x.shape
    dims = mamba2_dims(d_model, cfg)
    d_in, h, p, n, g = dims["d_inner"], dims["nheads"], cfg.head_dim, cfg.state_dim, cfg.ngroups

    proj = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z, xbc, dt = jnp.split(proj, [d_in, d_in + dims["conv_channels"]], axis=-1)

    # causal depthwise conv over seq (kernel conv_dim)
    if conv_state is None:
        pad = jnp.zeros((b, cfg.conv_dim - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    new_conv_state = xbc_pad[:, -(cfg.conv_dim - 1) :] if cfg.conv_dim > 1 else pad
    conv = sum(
        xbc_pad[:, i : i + l] * params["conv_w"][i][None, None] for i in range(cfg.conv_dim)
    ) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)

    xh = conv[..., :d_in].reshape(b, l, h, p)
    bm = conv[..., d_in : d_in + g * n].reshape(b, l, g, n)
    cm = conv[..., d_in + g * n :].reshape(b, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, L, H)
    return z, xh, bm, cm, dt, new_conv_state


def _mamba2_finish(params, z, y, x_dtype):
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = common.rmsnorm(params["norm"], y.astype(x_dtype))
    return jnp.einsum("ble,ed->bld", y, params["out_proj"])


def _expand_groups(t: jax.Array, h: int) -> jax.Array:
    """(B, L, G, N) -> (B, L, H, N) by repeating groups."""
    g = t.shape[2]
    return jnp.repeat(t, h // g, axis=2)


def mamba2_apply_scan(params, cfg: SSMConfig, x, state=None):
    """Sequential oracle / decode path. x: (B, L, D). Returns (out, state)."""
    b, l, d_model = x.shape
    dims = mamba2_dims(d_model, cfg)
    h = dims["nheads"]
    if state is None:
        state = mamba2_state(d_model, cfg, b, x.dtype)
    z, xh, bm, cm, dt, conv_state = _mamba2_preproc(params, cfg, x, state["conv"])
    a = -jnp.exp(params["A_log"])  # (H,)
    bm = _expand_groups(bm, h).astype(jnp.float32)
    cm = _expand_groups(cm, h).astype(jnp.float32)
    xf = xh.astype(jnp.float32)

    def step(s, inp):
        xt, bt, ct, dtt = inp  # (B,H,P), (B,H,N), (B,H,N), (B,H)
        decay = jnp.exp(dtt * a)[..., None, None]  # (B,H,1,1)
        s = s * decay + (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        yt = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, yt

    inps = (
        xf.transpose(1, 0, 2, 3),
        bm.transpose(1, 0, 2, 3),
        cm.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
    )
    s_final, ys = jax.lax.scan(step, state["ssm"], inps)
    y = ys.transpose(1, 0, 2, 3) + params["D"][None, None, :, None] * xf
    out = _mamba2_finish(params, z, y.reshape(b, l, -1), x.dtype)
    return out, {"conv": conv_state, "ssm": s_final}


def mamba2_apply_chunked(params, cfg: SSMConfig, x, state=None):
    """Chunk-parallel SSD. Non-multiple lengths are zero-padded: padded steps
    carry dt=0 (decay=1, zero input) so the state passes through unchanged."""
    b, l, d_model = x.shape
    dims = mamba2_dims(d_model, cfg)
    h, p, n = dims["nheads"], cfg.head_dim, cfg.state_dim
    q = min(cfg.chunk, l)
    if state is None:
        state = mamba2_state(d_model, cfg, b, x.dtype)

    z, xh, bm, cm, dt, conv_state = _mamba2_preproc(params, cfg, x, state["conv"])
    a = -jnp.exp(params["A_log"])
    bm = _expand_groups(bm, h).astype(jnp.float32)
    cm = _expand_groups(cm, h).astype(jnp.float32)
    xf = xh.astype(jnp.float32)

    pad = (-l) % q
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xf, bm, cm, dt = zpad(xf), zpad(bm), zpad(cm), zpad(dt)
    l_pad = l + pad
    nc = l_pad // q

    # chunked views, scanned chunk-major to bound memory at O(q^2)
    def chunk_view(t):
        return t.reshape(b, nc, q, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))


    xc, bc, cc_, dtc = map(chunk_view, (xf, bm, cm, dt))

    tri = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(s, inp):
        xq, bq, cq, dtq = inp  # (B,q,H,P), (B,q,H,N), (B,q,H,N), (B,q,H)
        logd = dtq * a  # (B,q,H) <= 0
        cum = jnp.cumsum(logd, axis=1)  # inclusive
        # intra-chunk: att[t,s] = exp(cum_t - cum_s) * (C_t . B_s) * dt_s, s <= t
        pair = cum[:, :, None] - cum[:, None, :]  # (B,q,q,H) t,s
        pair = jnp.where(tri[None, :, :, None], pair, -jnp.inf)
        att = jnp.exp(pair) * jnp.einsum("bthn,bshn->btsh", cq, bq)
        att = att * dtq[:, None]  # dt_s
        y = jnp.einsum("btsh,bshp->bthp", att, xq)
        # inter-chunk: y_t += C_t . (exp(cum_t) * S_prev)
        y = y + jnp.einsum("bthn,bhpn->bthp", cq * jnp.exp(cum)[..., None], s)
        # state update: S = exp(cum_last) S + sum_s exp(cum_last - cum_s) dt_s B_s x_s^T
        rem = jnp.exp(cum[:, -1:, :] - cum)  # (B,q,H)
        s = s * jnp.exp(cum[:, -1])[..., None, None] + jnp.einsum(
            "bshn,bshp->bhpn", bq * (rem * dtq)[..., None], xq
        )
        return s, y

    s_final, yc = jax.lax.scan(chunk_step, state["ssm"], (xc, bc, cc_, dtc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, l_pad, h, p)[:, :l]
    y = y + params["D"][None, None, :, None] * xf[:, :l]
    out = _mamba2_finish(params, z, y.reshape(b, l, -1), x.dtype)
    return out, {"conv": conv_state, "ssm": s_final}


# ===========================================================================
# RWKV6 (Finch) — data-dependent decay
# ===========================================================================

_TM_MIX_NAMES = ("r", "k", "v", "g", "w")


def rwkv6_init(key: jax.Array, d_model: int, d_ff: int, cfg: SSMConfig, dtype) -> dict:
    ks = jax.random.split(key, 16)
    d = d_model
    r = cfg.lora_rank
    h = d // cfg.head_dim
    tm = {
        "ln": common.layernorm_init(d, dtype),
        "mu_base": jnp.full((d,), 0.5, dtype),
        "mix_mu": jnp.full((5, d), 0.5, dtype),  # r,k,v,g,w
        "mix_lora_a": common.dense_init(ks[0], d, (5, r), dtype),
        "mix_lora_b": common.truncated_normal_init(ks[1], (5, r, d), 0.01, dtype),
        "w_r": common.dense_init(ks[2], d, d, dtype),
        "w_k": common.dense_init(ks[3], d, d, dtype),
        "w_v": common.dense_init(ks[4], d, d, dtype),
        "w_g": common.dense_init(ks[5], d, d, dtype),
        "w_o": common.dense_init(ks[6], d, d, dtype),
        "decay_base": jnp.full((d,), -4.0, jnp.float32),  # w0: decay ~ exp(-exp(-4+dx))
        "decay_lora_a": common.dense_init(ks[7], d, 2 * r, dtype),
        "decay_lora_b": common.truncated_normal_init(ks[8], (2 * r, d), 0.01, dtype),
        "bonus_u": common.truncated_normal_init(ks[9], (h, cfg.head_dim), 0.5, jnp.float32),
        "out_ln": common.layernorm_init(d, dtype),  # per-head groupnorm folded to LN
    }
    cm = {
        "ln": common.layernorm_init(d, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk_ff": common.dense_init(ks[10], d, d_ff, dtype),
        "wv_ff": common.dense_init(ks[11], d_ff, d, dtype),
        "wr_gate": common.dense_init(ks[12], d, d, dtype),
    }
    return {"time_mix": tm, "channel_mix": cm}


def rwkv6_state(d_model: int, cfg: SSMConfig, batch: int, dtype) -> dict:
    h = d_model // cfg.head_dim
    return {
        "tm_prev": jnp.zeros((batch, d_model), dtype),
        "cm_prev": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, h, cfg.head_dim, cfg.head_dim), jnp.float32),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """xx_t = x_{t-1}; xx_0 = prev (carried across calls). x: (B, L, D)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _tm_projections(tm: dict, x: jax.Array, prev: jax.Array):
    """Data-dependent token-shift mixing (ddlerp) + projections + decay."""
    xx = _token_shift(x, prev)
    sx = xx - x
    base = x + sx * tm["mu_base"]
    lora_mid = jnp.tanh(jnp.einsum("bld,dmr->blmr", base, tm["mix_lora_a"]).astype(jnp.float32))
    lora_out = jnp.einsum("blmr,mrd->blmd", lora_mid.astype(x.dtype), tm["mix_lora_b"])
    mixed = {}
    for i, name in enumerate(_TM_MIX_NAMES):
        m = tm["mix_mu"][i] + lora_out[:, :, i]
        mixed[name] = x + sx * m
    r = jnp.einsum("bld,de->ble", mixed["r"], tm["w_r"])
    k = jnp.einsum("bld,de->ble", mixed["k"], tm["w_k"])
    v = jnp.einsum("bld,de->ble", mixed["v"], tm["w_v"])
    g = jnp.einsum("bld,de->ble", mixed["g"], tm["w_g"])
    dlo = jnp.tanh(jnp.einsum("bld,dr->blr", mixed["w"], tm["decay_lora_a"]).astype(jnp.float32))
    dw = jnp.einsum("blr,rd->bld", dlo.astype(x.dtype), tm["decay_lora_b"])
    # log-decay per channel: logd = -exp(w0 + dw)  (always negative)
    logd = -jnp.exp(jnp.clip(tm["decay_base"] + dw.astype(jnp.float32), -12.0, 4.0))
    return r, k, v, g, logd, x[:, -1]


def _heads(t: jax.Array, head_dim: int) -> jax.Array:
    b, l, d = t.shape
    return t.reshape(b, l, d // head_dim, head_dim)


def _tm_output(tm: dict, o: jax.Array, g: jax.Array, dtype):
    b, l = o.shape[:2]
    o = common.layernorm(tm["out_ln"], o.reshape(b, l, -1).astype(dtype))
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(dtype)
    return jnp.einsum("bld,de->ble", o, tm["w_o"])


def rwkv6_time_mix_scan(tm: dict, cfg: SSMConfig, x, prev, wkv):
    """Sequential WKV oracle / decode. Returns (out, new_prev, new_wkv)."""
    r, k, v, g, logd, new_prev = _tm_projections(tm, x, prev)
    dk = cfg.head_dim
    rh, kh, vh = (_heads(t, dk).astype(jnp.float32) for t in (r, k, v))
    ld = _heads(logd, dk)
    u = tm["bonus_u"]  # (H, dk)

    def step(s, inp):
        rt, kt, vt, ldt = inp  # (B,H,dk) each
        # o_t = r_t . (S_{t-1} + (u*k_t) v_t^T)
        ot = jnp.einsum("bhi,bhij->bhj", rt, s) + jnp.einsum(
            "bhi,bhi,bhj->bhj", rt, u[None] * kt, vt
        )
        s = jnp.exp(ldt)[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, ot

    inps = tuple(t.transpose(1, 0, 2, 3) for t in (rh, kh, vh, ld))
    wkv_final, os = jax.lax.scan(step, wkv, inps)
    o = os.transpose(1, 0, 2, 3)  # (B, L, H, dk)
    return _tm_output(tm, o, g, x.dtype), new_prev, wkv_final


def rwkv6_time_mix_chunked(tm: dict, cfg: SSMConfig, x, prev, wkv):
    """Chunk-parallel WKV: intra-chunk pairwise + inter-chunk state scan.
    Non-multiple lengths are zero-padded (log-decay 0, k = v = 0 => the state
    passes through padded steps unchanged); padded outputs are sliced off."""
    b, l, d = x.shape
    q = min(cfg.chunk, l)
    r, k, v, g, logd, new_prev = _tm_projections(tm, x, prev)
    dk = cfg.head_dim
    h = d // dk
    rh, kh, vh = (_heads(t, dk).astype(jnp.float32) for t in (r, k, v))
    ld = _heads(logd, dk)
    u = tm["bonus_u"][None, None]  # (1,1,H,dk)

    pad = (-l) % q
    if pad:
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad), (0, 0), (0, 0)])
        rh, kh, vh, ld = zpad(rh), zpad(kh), zpad(vh), zpad(ld)
    l_pad = l + pad
    nc = l_pad // q

    def chunk_view(t):
        return t.reshape(b, nc, q, h, dk).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, ldc = map(chunk_view, (rh, kh, vh, ld))
    tri_strict = jnp.tril(jnp.ones((q, q), bool), k=-1)

    def chunk_step(s, inp):
        rq, kq, vq, ldq = inp  # (B,q,H,dk)
        cum = jnp.cumsum(ldq, axis=1)  # inclusive; <= 0, decreasing in t
        cum_ex = cum - ldq  # exclusive: RWKV reads S_{t-1} (decay after read)
        # att[t,s] = sum_i r_t[i] k_s[i] exp(cum_ex_t - cum_s), strictly s < t
        pair = cum_ex[:, :, None, :, :] - cum[:, None, :, :, :]  # (B,t,s,H,dk)
        pair = jnp.where(tri_strict[None, :, :, None, None], pair, -jnp.inf)
        att = jnp.einsum("bthi,bshi,btshi->btsh", rq, kq, jnp.exp(pair))
        y = jnp.einsum("btsh,bshj->bthj", att, vq)
        # current-step bonus: (r_t . (u * k_t)) v_t
        diag = jnp.einsum("bthi,bthi->bth", rq, u * kq)
        y = y + diag[..., None] * vq
        # inter-chunk: r_t . (exp(cum_ex_t) * S_prev)
        y = y + jnp.einsum("bthi,bhij->bthj", rq * jnp.exp(cum_ex), s)
        # state: S = exp(cum_last) S + sum_s exp(cum_last - cum_s + ld_s?...)
        # contribution of s decays by steps s+1..last: exp(cum_last - cum_s)
        rem = jnp.exp(cum[:, -1:] - cum)  # (B,q,H,dk)
        s = s * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
            "bshi,bshj->bhij", kq * rem, vq
        )
        return s, y

    wkv_final, yc = jax.lax.scan(chunk_step, wkv, (rc, kc, vc, ldc))
    o = yc.transpose(1, 0, 2, 3, 4).reshape(b, l_pad, h, dk)[:, :l]
    return _tm_output(tm, o, g, x.dtype), new_prev, wkv_final


def rwkv6_channel_mix(cm: dict, x, prev):
    xx = _token_shift(x, prev)
    sx = xx - x
    xk = x + sx * cm["mu_k"]
    xr = x + sx * cm["mu_r"]
    k = jnp.einsum("bld,df->blf", xk, cm["wk_ff"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("blf,fd->bld", k, cm["wv_ff"])
    rg = jax.nn.sigmoid(jnp.einsum("bld,de->ble", xr, cm["wr_gate"]).astype(jnp.float32))
    return rg.astype(x.dtype) * kv, x[:, -1]


def rwkv6_block_apply(params, cfg: SSMConfig, x, state, *, chunked: bool):
    """Full RWKV6 layer: time-mix + channel-mix with pre-LN residuals."""
    tm, cm = params["time_mix"], params["channel_mix"]
    h_in = common.layernorm(tm["ln"], x)
    fn = rwkv6_time_mix_chunked if chunked else rwkv6_time_mix_scan
    o, tm_prev, wkv = fn(tm, cfg, h_in, state["tm_prev"], state["wkv"])
    x = x + o
    c_in = common.layernorm(cm["ln"], x)
    o2, cm_prev = rwkv6_channel_mix(cm, c_in, state["cm_prev"])
    x = x + o2
    return x, {"tm_prev": tm_prev, "cm_prev": cm_prev, "wkv": wkv}
