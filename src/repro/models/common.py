"""Shared layer primitives: norms, projections, RoPE, activations, inits.

Functional style: every layer is an ``init(key, ...) -> params`` plus an
``apply(params, x, ...) -> y`` pair operating on plain dict pytrees.  Compute
dtype is configurable (bf16 on TPU, f32 on CPU smoke); norm/softmax accumulate
in f32 throughout.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.sharding import logical


def truncated_normal_init(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def dense_init(
    key, in_dim: int, out_dims: Sequence[int] | int, dtype, *, scale: float | None = None
):
    """Fan-in scaled init for a dense kernel (in_dim, *out_dims)."""
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    scale = scale if scale is not None else in_dim**-0.5
    return truncated_normal_init(key, (in_dim, *out_dims), scale, dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    # dim**-0.5 keeps tied-unembedding logits O(1) at init.
    return truncated_normal_init(key, (vocab, dim), dim**-0.5, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    if x.ndim == angles.ndim + 1:  # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def mlp_init(key, d_model: int, d_ff: int, dtype, *, gated: bool = True) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp_apply(params: dict, x: jax.Array, *, act: str = "silu") -> jax.Array:
    """SwiGLU when w_gate present; plain act-MLP otherwise. x: (B, S, D)."""
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    up = logical.shard(up, "batch", "seq", "mlp")
    if "w_gate" in params:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = act_fn(act)(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = act_fn(act)(up.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    # residual-stream boundary: under sequence parallelism this reshards the
    # seq dim over `model` (XLA inserts reduce-scatter here instead of a
    # full all-reduce)
    return logical.shard(out, "batch", "residual_seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_lookup(table: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    out = jnp.take(table, tokens, axis=0).astype(compute_dtype)
    return logical.shard(out, "batch", "residual_seq", "embed")


def unembed(table_or_head: jax.Array, x: jax.Array, *, transpose: bool) -> jax.Array:
    """Logits in f32. transpose=True when sharing the embedding table (V, D)."""
    if transpose:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), table_or_head.astype(jnp.float32))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32), table_or_head.astype(jnp.float32))
    return logical.shard(logits, "batch", "seq", "vocab")


def cross_entropy_loss(logits: jax.Array, labels: jax.Array, *, ignore_id: int = -100) -> jax.Array:
    """Mean token cross entropy, f32, with ignore mask."""
    logits = logits.astype(jnp.float32)
    mask = (labels != ignore_id).astype(jnp.float32)
    safe = jnp.where(labels == ignore_id, 0, labels)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
