"""Model stacks: dense/MoE decoders, RWKV6, Zamba2 hybrid, enc-dec, VLM.

All stacks use *layer-stacked* parameters (leading L axis, built with
vmap(init)) applied under ``lax.scan`` — HLO size is independent of depth,
which is what keeps 94-layer dry-run lowering tractable.  ``cfg.remat``
wraps the scanned block in ``jax.checkpoint``.

Per-family batch/IO contracts (see data/pipeline.py and launch/dryrun.py):
  dense/moe/rwkv6/hybrid : batch = {tokens (B,S), labels (B,S)}
  vlm                    : + patches (B, Np, frontend_dim); text len = S - Np
  encdec                 : frames (B, S_enc, frontend_dim), tokens/labels (B, S_dec)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, moe, ssm
from repro.sharding import logical

PyTree = Any


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ===========================================================================
# Decoder block (dense MLP or MoE)
# ===========================================================================


def _block_init(key, cfg: ModelConfig, *, use_moe: bool, dense_ff: Optional[int] = None) -> dict:
    dtype = compute_dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": common.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.init(k1, cfg.d_model, cfg.attention, dtype),
        "ln2": common.rmsnorm_init(cfg.d_model, dtype),
    }
    if use_moe:
        p["moe"] = moe.init(k2, cfg.d_model, cfg.moe, dtype)
    else:
        p["mlp"] = common.mlp_init(k2, cfg.d_model, dense_ff or cfg.d_ff, dtype)
    return p


def _block_apply(p, cfg: ModelConfig, x, positions, cache):
    """Returns (x, new_cache, aux)."""
    h, cache = attention.apply(
        p["attn"], cfg.attention, common.rmsnorm(p["ln1"], x, cfg.norm_eps), positions, cache=cache
    )
    x = x + h
    h2 = common.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        h2, aux = moe.apply(p["moe"], cfg.moe, h2, act=cfg.act)
    else:
        h2, aux = common.mlp_apply(p["mlp"], h2, act=cfg.act), jnp.zeros((), jnp.float32)
    return x + h2, cache, aux


def _stacked_init(key, n, init_one):
    return jax.vmap(init_one)(jax.random.split(key, max(n, 1)))


def _scan_blocks(block_fn, x, stacked_params, stacked_cache, remat: bool):
    """scan over layers; carry (x, aux); xs = (params, cache); ys = cache."""
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def body(carry, layer):
        x, aux = carry
        p, c = layer
        x, c, a = fn(p, x, c)
        return (x, aux + a), c

    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stacked_params, stacked_cache)
    )
    return x, new_cache, aux


# ===========================================================================
# Decoder-only model (dense / moe / vlm share this)
# ===========================================================================


def decoder_init(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = compute_dtype(cfg)
    ks = jax.random.split(key, 6)
    p: dict = {"embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype)}
    n_dense_first = cfg.moe.first_dense_layers if cfg.moe else 0
    n_main = cfg.num_layers - n_dense_first
    if n_dense_first:
        p["first_layers"] = _stacked_init(
            ks[1],
            n_dense_first,
            lambda k: _block_init(k, cfg, use_moe=False, dense_ff=cfg.moe.dense_ff or cfg.d_ff),
        )
    p["layers"] = _stacked_init(
        ks[2], n_main, lambda k: _block_init(k, cfg, use_moe=cfg.moe is not None)
    )
    p["final_norm"] = common.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.family == "vlm":
        p["projector"] = common.dense_init(ks[4], cfg.frontend_dim, cfg.d_model, dtype)
    return p


def _decoder_embed(params, cfg: ModelConfig, tokens, patches=None):
    dtype = compute_dtype(cfg)
    x = common.embed_lookup(params["embed"], tokens, dtype)
    if patches is not None:
        px = jnp.einsum("bpf,fd->bpd", patches.astype(dtype), params["projector"])
        x = jnp.concatenate([px, x], axis=1)  # image patches are a prefix
    return x


def _null_cache(stacked_params):
    """Per-layer None caches are not scannable; use a zero-length dummy pytree."""
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    return jnp.zeros((n, 0), jnp.int32)


def _maybe_cache_to_none(c):
    return None if isinstance(c, jax.Array) and c.ndim >= 1 and c.shape[-1] == 0 else c


# The scanned block needs cache=None handled inside (dummy arrays flow through
# scan in the no-cache training path).
def _block_apply_cacheaware(p, cfg, x, positions, c):
    c = _maybe_cache_to_none(c)
    x, c2, aux = _block_apply(p, cfg, x, positions, c)
    if c2 is None:
        c2 = jnp.zeros((0,), jnp.int32)
    return x, c2, aux


def _decoder_trunk(params, cfg: ModelConfig, x, positions, caches):
    """caches: {"first": ..., "main": ...} stacked, or None (training)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches: dict | None = {} if caches is not None else None
    block = lambda p, h, c: _block_apply_cacheaware(p, cfg, h, positions, c)
    if "first_layers" in params:
        c = caches["first"] if caches is not None else _null_cache(params["first_layers"])
        x, nc, a = _scan_blocks(block, x, params["first_layers"], c, cfg.remat)
        aux = aux + a
        if new_caches is not None:
            new_caches["first"] = nc
    c = caches["main"] if caches is not None else _null_cache(params["layers"])
    x, nc, a = _scan_blocks(block, x, params["layers"], c, cfg.remat)
    aux = aux + a
    if new_caches is not None:
        new_caches["main"] = nc
    x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_caches, aux


def decoder_logits(params, cfg: ModelConfig, x):
    head = params.get("lm_head", params["embed"])
    return common.unembed(head, x, transpose="lm_head" not in params)


def decoder_loss_fn(params, cfg: ModelConfig, batch) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    patches = batch.get("patches")
    x = _decoder_embed(params, cfg, tokens, patches)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = logical.shard(x, "batch", "residual_seq", "embed")
    x, _, aux = _decoder_trunk(params, cfg, x, positions, None)
    if patches is not None:
        x = x[:, patches.shape[1] :]  # loss over text positions only
    logits = decoder_logits(params, cfg, x)
    loss = common.cross_entropy_loss(logits, labels)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_coef * aux / cfg.num_layers
    return loss


def decoder_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dtype = compute_dtype(cfg)
    n_first = cfg.moe.first_dense_layers if cfg.moe else 0
    n_main = cfg.num_layers - n_first

    def stack(n):
        return jax.vmap(lambda _i: attention.init_cache(cfg.attention, batch, max_seq, dtype))(
            jnp.arange(n)
        )

    caches = {"main": stack(n_main)}
    if n_first:
        caches["first"] = stack(n_first)
    return caches


def decoder_prefill(params, cfg: ModelConfig, batch, caches):
    tokens = batch["tokens"]
    patches = batch.get("patches")
    x = _decoder_embed(params, cfg, tokens, patches)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, caches, _ = _decoder_trunk(params, cfg, x, positions, caches)
    logits = decoder_logits(params, cfg, x[:, -1:])
    return logits, caches


def decoder_decode_step(params, cfg: ModelConfig, token, pos, caches):
    """token: (B,) int32; pos: (B,) absolute position of this token."""
    x = _decoder_embed(params, cfg, token[:, None])
    positions = pos[:, None]
    x, caches, _ = _decoder_trunk(params, cfg, x, positions, caches)
    logits = decoder_logits(params, cfg, x)
    return logits, caches


# ===========================================================================
# RWKV6 stack
# ===========================================================================


def rwkv6_init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = compute_dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "ln0": common.layernorm_init(cfg.d_model, dtype),
        "layers": _stacked_init(
            ks[1],
            cfg.num_layers,
            lambda k: ssm.rwkv6_init(k, cfg.d_model, cfg.d_ff, cfg.ssm, dtype),
        ),
        "final_norm": common.layernorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)
    return p


def rwkv6_init_state(cfg: ModelConfig, batch: int) -> dict:
    dtype = compute_dtype(cfg)
    one = lambda _i: ssm.rwkv6_state(cfg.d_model, cfg.ssm, batch, dtype)
    return jax.vmap(one)(jnp.arange(cfg.num_layers))


def _rwkv6_trunk(params, cfg: ModelConfig, x, states, *, chunked: bool):
    block = lambda p, h, s: ssm.rwkv6_block_apply(p, cfg.ssm, h, s, chunked=chunked)
    fn = jax.checkpoint(block) if cfg.remat else block

    def body(h, layer):
        p, s = layer
        h, s2 = fn(p, h, s)
        return h, s2

    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    return common.layernorm(params["final_norm"], x, cfg.norm_eps), new_states


def rwkv6_loss_fn(params, cfg: ModelConfig, batch) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    dtype = compute_dtype(cfg)
    x = common.embed_lookup(params["embed"], tokens, dtype)
    x = common.layernorm(params["ln0"], x, cfg.norm_eps)
    states = rwkv6_init_state(cfg, tokens.shape[0])
    x, _ = _rwkv6_trunk(params, cfg, x, states, chunked=True)
    logits = decoder_logits(params, cfg, x)
    return common.cross_entropy_loss(logits, labels)


def rwkv6_features(params, cfg: ModelConfig, tokens, *, chunked: bool = True) -> jax.Array:
    """Trunk hidden states (B, S, D) for sequence-level heads (no unembed).

    The full-sequence forward of ``rwkv6_loss_fn`` stopped before the logits:
    embed, ln0, the layer scan from a zero recurrent state, final norm.  Used
    by ``models.registry.build_sequence_classifier`` (e.g. the P2P
    ``rwkv6_seqmnist`` task reads position -1 as the RNN's summary state).

    ``chunked=False`` runs the token-sequential RNN recurrence instead of the
    chunked parallel scan: same math, but O(B * D) live state instead of the
    chunked form's O(B * heads * chunk^2) attention-shaped intermediates — on
    CPU, for short-sequence classification, it is both smaller and faster.
    """
    x = common.embed_lookup(params["embed"], tokens, compute_dtype(cfg))
    x = common.layernorm(params["ln0"], x, cfg.norm_eps)
    states = rwkv6_init_state(cfg, tokens.shape[0])
    x, _ = _rwkv6_trunk(params, cfg, x, states, chunked=chunked)
    return x


def rwkv6_prefill(params, cfg: ModelConfig, batch, states):
    tokens = batch["tokens"]
    x = common.embed_lookup(params["embed"], tokens, compute_dtype(cfg))
    x = common.layernorm(params["ln0"], x, cfg.norm_eps)
    x, states = _rwkv6_trunk(params, cfg, x, states, chunked=True)
    return decoder_logits(params, cfg, x[:, -1:]), states


def rwkv6_decode_step(params, cfg: ModelConfig, token, pos, states):
    del pos  # recurrent: position-free
    x = common.embed_lookup(params["embed"], token[:, None], compute_dtype(cfg))
    x = common.layernorm(params["ln0"], x, cfg.norm_eps)
    x, states = _rwkv6_trunk(params, cfg, x, states, chunked=False)
    return decoder_logits(params, cfg, x), states


# ===========================================================================
# Zamba2-style hybrid: Mamba2 backbone + weight-shared attention block
# ===========================================================================


def hybrid_init(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = compute_dtype(cfg)
    ks = jax.random.split(key, 6)

    def mamba_layer(k):
        return {
            "ln": common.rmsnorm_init(cfg.d_model, dtype),
            "mamba": ssm.mamba2_init(k, cfg.d_model, cfg.ssm, dtype),
        }

    p = {
        "embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "layers": _stacked_init(ks[1], cfg.num_layers, mamba_layer),
        "final_norm": common.rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.shared_block_period:
        kc, kb = jax.random.split(ks[2])
        p["shared_proj"] = common.dense_init(kc, 2 * cfg.d_model, cfg.d_model, dtype)
        p["shared_block"] = _block_init(kb, cfg, use_moe=False)
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype)
    return p


def hybrid_num_shared_applications(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.shared_block_period if cfg.shared_block_period else 0


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dtype = compute_dtype(cfg)
    n_apps = hybrid_num_shared_applications(cfg)
    cache = {
        "mamba": jax.vmap(lambda _i: ssm.mamba2_state(cfg.d_model, cfg.ssm, batch, dtype))(
            jnp.arange(cfg.num_layers)
        ),
        "attn": jax.vmap(lambda _i: attention.init_cache(cfg.attention, batch, max_seq, dtype))(
            jnp.arange(max(n_apps, 1))
        ),
    }
    return cache


def _hybrid_trunk(params, cfg: ModelConfig, x, positions, cache, *, chunked: bool):
    period = cfg.shared_block_period
    n_apps = hybrid_num_shared_applications(cfg)
    x0 = x  # original embedding, concatenated into every shared-block input
    mamba_fn = ssm.mamba2_apply_chunked if chunked else ssm.mamba2_apply_scan

    def mamba_block(p, h, s):
        o, s2 = mamba_fn(p["mamba"], cfg.ssm, common.rmsnorm(p["ln"], h, cfg.norm_eps), s)
        return h + o, s2

    mamba_block = jax.checkpoint(mamba_block) if cfg.remat else mamba_block

    def shared_apply(h, attn_cache):
        inp = jnp.einsum("bsd,dp->bsp", jnp.concatenate([h, x0], axis=-1), params["shared_proj"])
        out, attn_cache, _ = _block_apply(params["shared_block"], cfg, inp, positions, attn_cache)
        return h + out, attn_cache

    # group the stacked mamba layers: (n_apps|1 groups, period, ...)
    groups = n_apps if period else 1
    per = cfg.num_layers // groups
    grouped = jax.tree.map(lambda t: t.reshape((groups, per) + t.shape[1:]), params["layers"])
    grouped_state = jax.tree.map(
        lambda t: t.reshape((groups, per) + t.shape[1:]), cache["mamba"]
    )

    def group_body(carry, layer):
        h, _ = carry
        gp, gs, attn_cache = layer

        def inner(h2, lp_ls):
            lp, ls = lp_ls
            h2, s2 = mamba_block(lp, h2, ls)
            return h2, s2

        h, new_s = jax.lax.scan(inner, h, (gp, gs))
        if period:
            h, attn_cache = shared_apply(h, attn_cache)
        return (h, jnp.zeros((), jnp.float32)), (new_s, attn_cache)

    (x, _), (new_mamba, new_attn) = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)), (grouped, grouped_state, cache["attn"])
    )
    new_mamba = jax.tree.map(lambda t: t.reshape((cfg.num_layers,) + t.shape[2:]), new_mamba)
    x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"mamba": new_mamba, "attn": new_attn}


def hybrid_loss_fn(params, cfg: ModelConfig, batch) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    dtype = compute_dtype(cfg)
    x = common.embed_lookup(params["embed"], tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    states = jax.vmap(lambda _i: ssm.mamba2_state(cfg.d_model, cfg.ssm, b, dtype))(
        jnp.arange(cfg.num_layers)
    )
    x, _ = _hybrid_trunk_nocache(params, cfg, x, positions, states)
    logits = decoder_logits(params, cfg, x)
    return common.cross_entropy_loss(logits, labels)


def _hybrid_trunk_nocache(params, cfg: ModelConfig, x, positions, mamba_states):
    """Training/prefill-without-cache variant (attention cache = None)."""
    period = cfg.shared_block_period
    n_apps = hybrid_num_shared_applications(cfg)
    x0 = x

    def mamba_block(p, h, s):
        o, s2 = ssm.mamba2_apply_chunked(
            p["mamba"], cfg.ssm, common.rmsnorm(p["ln"], h, cfg.norm_eps), s
        )
        return h + o, s2

    mamba_block = jax.checkpoint(mamba_block) if cfg.remat else mamba_block

    groups = n_apps if period else 1
    per = cfg.num_layers // groups
    grouped = jax.tree.map(lambda t: t.reshape((groups, per) + t.shape[1:]), params["layers"])
    grouped_state = jax.tree.map(
        lambda t: t.reshape((groups, per) + t.shape[1:]), mamba_states
    )

    def group_body(h, layer):
        gp, gs = layer

        def inner(h2, lp_ls):
            lp, ls = lp_ls
            h2, s2 = mamba_block(lp, h2, ls)
            return h2, s2

        h, new_s = jax.lax.scan(inner, h, (gp, gs))
        if period:
            inp = jnp.einsum(
                "bsd,dp->bsp", jnp.concatenate([h, x0], axis=-1), params["shared_proj"]
            )
            out, _, _ = _block_apply(params["shared_block"], cfg, inp, positions, None)
            h = h + out
        return h, new_s

    x, new_states = jax.lax.scan(group_body, x, (grouped, grouped_state))
    new_states = jax.tree.map(lambda t: t.reshape((cfg.num_layers,) + t.shape[2:]), new_states)
    x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_states


def hybrid_prefill(params, cfg: ModelConfig, batch, cache):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = common.embed_lookup(params["embed"], tokens, compute_dtype(cfg))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, cache = _hybrid_trunk(params, cfg, x, positions, cache, chunked=True)
    return decoder_logits(params, cfg, x[:, -1:]), cache


def hybrid_decode_step(params, cfg: ModelConfig, token, pos, cache):
    x = common.embed_lookup(params["embed"], token[:, None], compute_dtype(cfg))
    x, cache = _hybrid_trunk(params, cfg, x, pos[:, None], cache, chunked=False)
    return decoder_logits(params, cfg, x), cache


# ===========================================================================
# Encoder-decoder (seamless-m4t backbone; audio frontend stubbed)
# ===========================================================================


def encdec_init(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = compute_dtype(cfg)
    ks = jax.random.split(key, 8)

    def enc_layer(k):
        return _block_init(k, cfg, use_moe=False)

    def dec_layer(k):
        k1, k2 = jax.random.split(k)
        p = _block_init(k1, cfg, use_moe=False)
        p["ln_cross"] = common.rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attention.init(k2, cfg.d_model, cfg.attention, dtype)
        return p

    return {
        "frontend_proj": common.dense_init(ks[0], cfg.frontend_dim, cfg.d_model, dtype),
        "enc_layers": _stacked_init(ks[1], cfg.encoder_layers, enc_layer),
        "enc_norm": common.rmsnorm_init(cfg.d_model, dtype),
        "embed": common.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "dec_layers": _stacked_init(ks[3], cfg.num_layers, dec_layer),
        "final_norm": common.rmsnorm_init(cfg.d_model, dtype),
    }


def encdec_encode(params, cfg: ModelConfig, frames):
    dtype = compute_dtype(cfg)
    x = jnp.einsum("bsf,fd->bsd", frames.astype(dtype), params["frontend_proj"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def block(p, h, c):
        del c
        h2, _ = attention.apply(
            p["attn"], cfg.attention, common.rmsnorm(p["ln1"], h, cfg.norm_eps), positions,
            causal=False,
        )
        h = h + h2
        h = h + common.mlp_apply(p["mlp"], common.rmsnorm(p["ln2"], h, cfg.norm_eps), act=cfg.act)
        return h, jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.float32)

    dummy = jnp.zeros((cfg.encoder_layers, 0), jnp.int32)
    x, _, _ = _scan_blocks(block, x, params["enc_layers"], dummy, cfg.remat)
    return common.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _encdec_dec_block(p, cfg: ModelConfig, x, positions, cache, enc_kv):
    h, cache = attention.apply(
        p["attn"], cfg.attention, common.rmsnorm(p["ln1"], x, cfg.norm_eps), positions, cache=cache
    )
    x = x + h
    h = attention.cross_attention_apply(
        p["cross"], cfg.attention, common.rmsnorm(p["ln_cross"], x, cfg.norm_eps), enc_kv
    )
    x = x + h
    x = x + common.mlp_apply(p["mlp"], common.rmsnorm(p["ln2"], x, cfg.norm_eps), act=cfg.act)
    return x, cache


def encdec_cross_kv(params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross-attention K/V from encoder output."""

    def one(p):
        return attention.encoder_kv(p["cross"], cfg.attention, enc_out)

    return jax.vmap(one, in_axes=(0,))(params["dec_layers"])


def _encdec_dec_trunk(params, cfg: ModelConfig, x, positions, caches, cross_kv):
    def body(carry, layer):
        h = carry
        p, c, kv = layer
        c = _maybe_cache_to_none(c)
        h, c2 = _encdec_dec_block(p, cfg, h, positions, c, kv)
        if c2 is None:
            c2 = jnp.zeros((0,), jnp.int32)
        return h, c2

    if caches is None:
        caches = jnp.zeros((cfg.num_layers, 0), jnp.int32)
    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, new_caches = jax.lax.scan(body_fn, x, (params["dec_layers"], caches, cross_kv))
    return common.rmsnorm(params["final_norm"], x, cfg.norm_eps), new_caches


def encdec_loss_fn(params, cfg: ModelConfig, batch) -> jax.Array:
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    enc_out = encdec_encode(params, cfg, frames)
    cross_kv = encdec_cross_kv(params, cfg, enc_out)
    x = common.embed_lookup(params["embed"], tokens, compute_dtype(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _encdec_dec_trunk(params, cfg, x, positions, None, cross_kv)
    logits = decoder_logits(params, cfg, x)
    return common.cross_entropy_loss(logits, labels)


def encdec_init_cache(cfg: ModelConfig, batch: int, max_seq: int, enc_len: int) -> dict:
    dtype = compute_dtype(cfg)
    a = cfg.attention
    self_caches = jax.vmap(lambda _i: attention.init_cache(a, batch, max_seq, dtype))(
        jnp.arange(cfg.num_layers)
    )
    kv_shape = (cfg.num_layers, batch, enc_len, a.num_kv_heads, a.head_dim)
    return {
        "self": self_caches,
        "cross_k": jnp.zeros(kv_shape, dtype),
        "cross_v": jnp.zeros(kv_shape, dtype),
    }


def encdec_prefill(params, cfg: ModelConfig, batch, caches):
    """Encode audio + run the decoder prompt; fills self- and cross-caches."""
    enc_out = encdec_encode(params, cfg, batch["frames"])
    cross_k, cross_v = encdec_cross_kv(params, cfg, enc_out)
    tokens = batch["tokens"]
    x = common.embed_lookup(params["embed"], tokens, compute_dtype(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, self_c = _encdec_dec_trunk(params, cfg, x, positions, caches["self"], (cross_k, cross_v))
    logits = decoder_logits(params, cfg, x[:, -1:])
    return logits, {"self": self_c, "cross_k": cross_k, "cross_v": cross_v}


def encdec_decode_step(params, cfg: ModelConfig, token, pos, caches):
    x = common.embed_lookup(params["embed"], token[:, None], compute_dtype(cfg))
    positions = pos[:, None]
    x, self_c = _encdec_dec_trunk(
        params, cfg, x, positions, caches["self"], (caches["cross_k"], caches["cross_v"])
    )
    logits = decoder_logits(params, cfg, x)
    return logits, {"self": self_c, "cross_k": caches["cross_k"], "cross_v": caches["cross_v"]}
