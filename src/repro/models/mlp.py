"""The paper's model: the 2NN MLP from McMahan et al. [9], Sec. V.

"multilayer perceptrons (2NN) to classify MNIST images": 784 -> 200 -> 200
-> 10 with ReLU.  PyTorch-default init (uniform +- 1/sqrt(fan_in)) is
replicated so max-norm synchronization behaves as in P2PL [6].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_2nn(
    key: jax.Array, *, in_dim: int = 784, hidden: int = 200, num_classes: int = 10
) -> dict:
    def torch_linear(k, fan_in, fan_out):
        kw, kb = jax.random.split(k)
        bound = fan_in**-0.5
        return {
            "w": jax.random.uniform(kw, (fan_in, fan_out), jnp.float32, -bound, bound),
            "b": jax.random.uniform(kb, (fan_out,), jnp.float32, -bound, bound),
        }

    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "fc1": torch_linear(k1, in_dim, hidden),
        "fc2": torch_linear(k2, hidden, hidden),
        "out": torch_linear(k3, hidden, num_classes),
    }


def apply_2nn(params: dict, x: jax.Array) -> jax.Array:
    """x: (N, 784) or (N, 28, 28) -> logits (N, 10)."""
    x = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


def loss_2nn(params: dict, batch) -> jax.Array:
    """Mean cross-entropy. batch = (images, int labels)."""
    x, y = batch
    logits = apply_2nn(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def accuracy_2nn(params: dict, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(apply_2nn(params, x), -1) == y).astype(jnp.float32))
