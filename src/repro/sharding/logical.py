"""Logical-axis sharding annotations (MaxText-style, minimal).

Model code annotates tensors with *logical* axis names; a rules table maps
logical names to mesh axes (or None = replicated).  Outside a mesh context the
annotations are no-ops, so the same model code runs on a laptop CPU and on a
512-chip multi-pod mesh.

Usage:
    with logical.rules(RULES_TP), jax.sharding.use_mesh(mesh):
        lowered = jax.jit(step).lower(...)
Inside model code:
    x = logical.shard(x, "batch", "seq", "embed")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_rules() -> Mapping[str, str | Sequence[str] | None] | None:
    return getattr(_state, "rules", None)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def rules(table: Mapping[str, str | Sequence[str] | None] | None, mesh=None):
    prev, prev_mesh = current_rules(), current_mesh()
    _state.rules, _state.mesh = table, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev, prev_mesh


def spec(*logical_axes: str | None) -> P:
    """Resolve logical axis names to a PartitionSpec under the current rules."""
    table = current_rules()
    if table is None:
        return P()
    resolved = []
    for ax in logical_axes:
        if ax is None:
            resolved.append(None)
        else:
            resolved.append(table.get(ax))
    return P(*resolved)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Apply a with_sharding_constraint if rules are active; else identity."""
    table = current_rules()
    if table is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"rank mismatch: array rank {x.ndim} vs {len(logical_axes)} logical axes"
        )
    s = spec(*logical_axes)
    mesh = current_mesh()
    if mesh is not None:
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
    return jax.lax.with_sharding_constraint(x, s)


# ---------------------------------------------------------------------------
# Standard rule tables
# ---------------------------------------------------------------------------

# Tensor-parallel only: weights sharded over `model` along head/ff/vocab dims,
# activations sharded over `data` along batch.
RULES_TP = {
    "batch": "data",
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_group": "data",
    "layers": None,
    "kv_lora": None,
    "conv": None,
    "state": None,
    "peer": "pod",
}

# FSDP + TP: additionally shard the embed dim of weights over `data`.
RULES_FSDP_TP = dict(RULES_TP, embed_weight="data")
RULES_TP = dict(RULES_TP, embed_weight=None)

# Peer-stacked small-model mode: the leading peer axis of stacked parameters
# shards over `data`; model internals replicated or TP over `model`.
RULES_PEER_STACKED = dict(RULES_TP, peer="data", batch=None)


def weight_spec(*logical_axes: str | None) -> P:
    """Spec for parameters (distinguishes `embed_weight` FSDP axis)."""
    return spec(*logical_axes)
