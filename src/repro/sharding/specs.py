"""Name-based PartitionSpec rules for parameters, caches, and batches.

Rules are keyed on the leaf's path name (and rank, to disambiguate e.g. dense
``w_up (D,F)`` from MoE ``w_up (E,D,F)``).  Leaves under a stacked-layer
container ("layers", "first_layers", "enc_layers", "dec_layers", "mamba",
"main", "first", "self", "attn") get a leading ``None`` for the layer axis.
Peer-stacked (multi-pod) trees additionally get a leading ``peer_axis``.

Axis vocabulary: tp = tensor-parallel mesh axis ("model"); fsdp = the data
axis when FSDP is enabled (param_count >= fsdp_threshold), else None.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any

STACKED_CONTAINERS = (
    "layers",
    "first_layers",
    "enc_layers",
    "dec_layers",
    "mamba",
    "main",
    "first",
    "self",
    "attn",
)

FSDP_THRESHOLD = 8_000_000_000  # params; >= 8B shards the embed dim over `data`


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(f"#{p.idx}")
    return out


def param_leaf_spec(names: list[str], ndim: int, *, tp="model", fsdp=None) -> P:
    """Spec for one parameter leaf, *before* stacked/peer prefixing."""
    name = names[-1] if names else ""
    two = {  # rank-2 rules
        "embed": P(tp, fsdp),
        "lm_head": P(fsdp, tp),
        "w_o": P(tp, fsdp),
        "w_up": P(fsdp, tp),
        "w_gate": P(fsdp, tp),
        "w_down": P(tp, fsdp),
        "w_r": P(fsdp, tp),
        "w_k": P(fsdp, tp),
        "w_v": P(fsdp, tp),
        "w_g": P(fsdp, tp),
        "wk_ff": P(fsdp, tp),
        "wv_ff": P(tp, fsdp),
        "wr_gate": P(fsdp, tp),
        "in_proj": P(fsdp, tp),
        "out_proj": P(tp, fsdp),
        "router": P(None, None),
        "w_dq": P(fsdp, None),
        "w_dkv": P(fsdp, None),
        "shared_proj": P(fsdp, None),
        "frontend_proj": P(None, None),
        "projector": P(None, fsdp),
        "conv_w": P(None, tp),
        "mix_mu": P(None, None),
        # rwkv6 LoRA tables are ~170 MB/layer-stack: shard the d_model side
        # (consensus wire scales with the replicated fraction — §Perf P1 it3)
        "decay_lora_a": P(fsdp, None),
        "decay_lora_b": P(None, tp),
        "bonus_u": P(None, None),
    }
    three = {  # rank-3 rules
        "w_q": P(fsdp, tp, None),
        "w_k": P(fsdp, tp, None),
        "w_v": P(fsdp, tp, None),
        "w_uq": P(None, tp, None),
        "w_uk": P(None, tp, None),
        "w_uv": P(None, tp, None),
        "w_up": P(tp, fsdp, None),
        "w_gate": P(tp, fsdp, None),
        "w_down": P(tp, None, fsdp),
        "mix_lora_a": P(fsdp, None, None),
        "mix_lora_b": P(None, None, tp),
    }
    if ndim == 2 and name in two:
        return two[name]
    if ndim == 3 and name in three:
        return three[name]
    if ndim == 2 and name in ("b_q", "b_k", "b_v"):
        return P(tp, None)
    # scalars / vectors / norms / unknown: replicate
    return P(*([None] * ndim))


def _prefixes(names: list[str], peer_axis) -> tuple:
    pre = []
    if peer_axis is not None:
        pre.append(peer_axis)
    if any(n in STACKED_CONTAINERS for n in names[:-1]):
        pre.append(None)
    return tuple(pre)


def param_pspecs(params_shapes: PyTree, *, fsdp: bool = False, peer_axis=None) -> PyTree:
    """PartitionSpec tree for an UNSTACKED ``params_shapes`` tree.

    ``peer_axis`` (e.g. "pod") prepends the stacked-peer axis that the caller
    will add by stacking the tree afterwards — it does NOT consume a rank of
    the leaves seen here.  Stacked-layer containers (which ARE part of the
    leaf rank) get a leading None automatically.
    """
    fsdp_ax = "data" if fsdp else None

    def one(path, leaf):
        names = _path_names(path)
        stacked = 1 if any(n in STACKED_CONTAINERS for n in names[:-1]) else 0
        base = param_leaf_spec(names, leaf.ndim - stacked, fsdp=fsdp_ax)
        pre = ((peer_axis,) if peer_axis is not None else ()) + (None,) * stacked
        return P(*pre, *base)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def cache_leaf_spec(names: list[str], ndim: int, *, tp="model", layout: str = "heads") -> P:
    """layout="heads": KV sharded over kv-head dim (fails over to replication
    when head counts don't divide the model axis — e.g. qwen1.5's 40 heads).
    layout="seq": KV sharded over the cache-position dim (always divisible for
    the assigned shapes) — flash-decode style; attention over the cache
    becomes a partial-softmax combine instead of a cache all-gather."""
    name = names[-1] if names else ""
    if layout == "seq":
        table = {
            "k": P("data", tp, None, None),
            "v": P("data", tp, None, None),
            "k_scale": P("data", tp, None),
            "v_scale": P("data", tp, None),
            "pos_ids": P("data", tp),
            "c_kv": P("data", tp, None),
            "k_rope": P("data", tp, None),
        }
    else:
        table = {
            "k": P("data", None, tp, None),
            "v": P("data", None, tp, None),
            "k_scale": P("data", None, tp),
            "v_scale": P("data", None, tp),
            "pos_ids": P("data", None),
            "c_kv": P("data", None, None),
            "k_rope": P("data", None, None),
        }
    table.update({
        "cross_k": P("data", None, tp, None),
        "cross_v": P("data", None, tp, None),
        "conv": P("data", None, tp),
        "ssm": P("data", tp, None, None),
        "tm_prev": P("data", None),
        "cm_prev": P("data", None),
        "wkv": P("data", tp, None, None),
    })
    if name in table:
        spec = table[name]
        if len(spec) == ndim:
            return spec
    return P(*(["data"] + [None] * (ndim - 1)))  # batch-leading default


def cache_pspecs(
    cache_shapes: PyTree, *, family: str = "", peer_axis=None, layout: str = "heads"
) -> PyTree:
    """Specs for an UNSTACKED cache tree (see param_pspecs re: peer_axis)."""

    def one(path, leaf):
        names = _path_names(path)
        stacked = 1 if (
            family == "rwkv6" or any(n in STACKED_CONTAINERS for n in names[:-1])
        ) else 0
        base = cache_leaf_spec(names, leaf.ndim - stacked, layout=layout)
        pre = ((peer_axis,) if peer_axis is not None else ()) + (None,) * stacked
        return P(*pre, *base)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def peer_stacked_pspecs(tree: PyTree, *, peer_axis="pod") -> PyTree:
    """Specs for a peer-STACKED tree: leading K axis sharded, scalars replicated.

    This is the placement of the sharded peer-axis runtime's state
    (``repro.core.p2p.P2PState``): every array leaf carries a leading peer
    axis (params, momentum, biases, push-sum mass), the round counter is a
    replicated scalar.  Works on arrays, ShapeDtypeStructs, and tracers —
    ``make_sharded_round_fn`` builds its shard_map in/out specs with it.

    The serving runtime is the second consumer of this layout: a trained
    ``P2PState.params`` stack (``core/p2p.py:serving_params``) is served
    as-is — ``launch/serve.py`` routes request groups over the same leading
    K axis, and ``serve_fleet(peer_axis="pod")`` places parameters, request
    batches, and decode caches with ``shard_peer_tree`` exactly as the
    trainer does, so training and serving share one placement.

    One exception: a ``compression`` subtree (the CHOCO public-estimate stack
    of the compressed-gossip runtime) is REPLICATED, leading axis included —
    every device needs every sender's running estimate, and all replicas
    advance identically from the broadcast payloads, so the stack is a true
    replica, not a shard.

    The ``staleness`` subtree (bounded-staleness snapshot buffer,
    ``repro.core.p2p.StalenessState``) takes the DEFAULT rule: its
    ``published`` leaves are params-shaped (K, ...) and its ``age`` is (K,),
    both peer-sharded — each device owns its peer's published snapshot and
    age, and the async pod round gathers the snapshot stack over the lanes
    once per round, exactly like params.
    """

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        return P(peer_axis, *([None] * (leaf.ndim - 1)))

    specs = jax.tree.map(one, tree)
    comp = getattr(tree, "compression", None)
    if comp is not None and jax.tree.leaves(comp):
        replicated = jax.tree.map(lambda leaf: P(*([None] * leaf.ndim)), comp)
        specs = specs._replace(compression=replicated)
    return specs


def peer_batch_pspecs(tree: PyTree, *, peer_axis="pod") -> PyTree:
    """Specs for a step-major peer batch tree: leaves (T, K, ...) — the peer
    axis is dim 1 (dim 0 is the local-step axis scanned inside the round)."""

    def one(leaf):
        if leaf.ndim < 2:
            raise ValueError(
                f"peer batches are step-major (T, K, ...); got rank {leaf.ndim}"
            )
        return P(None, peer_axis, *([None] * (leaf.ndim - 2)))

    return jax.tree.map(one, tree)


def hierarchical_layout(
    num_peers: int, mesh, *, peer_axis: str = "pod", peers_per_device: int
) -> tuple[int, int]:
    """Validate the hierarchical (vmap-within-device x shard_map) layout.

    Returns ``(num_devices, peers_per_device)`` for a fleet of ``num_peers``
    laid out block-major over the mesh's ``peer_axis``: global peer ``g``
    lives on device ``g // peers_per_device``, local slot ``g % p`` — the
    placement under which ``all_gather(..., tiled=True)`` reconstitutes the
    stacked (K, ...) order and ``peer_stacked_pspecs`` shards the leading
    axis contiguously.
    """
    axis_sizes = dict(mesh.shape)
    num_devices = axis_sizes.get(peer_axis)
    if num_devices is None:
        raise ValueError(f"mesh has no axis {peer_axis!r}: {axis_sizes}")
    if peers_per_device < 2:
        raise ValueError(
            "peers_per_device must be >= 2 for the hierarchical runtime "
            "(peers_per_device=1 is the ordinary sharded runtime)"
        )
    if num_peers != peers_per_device * num_devices:
        raise ValueError(
            f"num_peers={num_peers} != peers_per_device={peers_per_device} "
            f"x mesh axis {peer_axis!r}={num_devices}"
        )
    return num_devices, peers_per_device


_PLACER_CACHE: dict = {}


def shard_peer_tree(tree: PyTree, mesh, *, peer_axis="pod") -> PyTree:
    """Place a peer-stacked tree onto the mesh, K axis over ``peer_axis``.

    Placement goes through a jitted ``with_sharding_constraint`` rather than a
    bare ``device_put``: the arrays then record the same *normalized*
    ``PartitionSpec`` forms that jit-computed outputs record (e.g.
    ``P('pod')`` instead of ``P('pod', None, None)``).  Specs that differ only
    in trailing ``None``s are semantically equal but hash differently in the
    jit cache key, so a ``device_put``-placed state would force every round/
    scan driver to compile TWICE per run — once for the hand-built input
    shardings, once for its own outputs fed back in.  The jitted placer is
    memoized on (mesh, axis, tree structure, leaf avals) so repeated
    placements of same-shaped trees reuse one compiled copy program.
    """
    leaves, treedef = jax.tree.flatten(tree)
    key = (
        mesh, peer_axis, treedef,
        tuple((np.shape(leaf), getattr(leaf, "dtype", None)) for leaf in leaves),
    )
    placer = _PLACER_CACHE.get(key)
    if placer is None:
        shardings = to_named(mesh, peer_stacked_pspecs(tree, peer_axis=peer_axis))
        placer = jax.jit(lambda t: jax.lax.with_sharding_constraint(t, shardings))
        _PLACER_CACHE[key] = placer
    return placer(tree)


def batch_pspecs(batch_shapes: PyTree, *, peer_axis=None) -> PyTree:
    """Specs for an UNSTACKED batch tree: batch dim over `data` (+peer prefix)."""

    def one(leaf):
        pre = (peer_axis,) if peer_axis is not None else ()
        return P(*pre, "data", *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch_shapes)


def sanitize_pspecs(pspecs: PyTree, shapes: PyTree, mesh) -> PyTree:
    """Drop spec axes whose mesh size does not divide the dimension.

    ``jit`` in_shardings require exact divisibility (unlike constraint
    propagation, which pads).  E.g. smollm's 3 KV heads cannot shard over a
    16-way model axis — that dim falls back to replication.
    """
    axsize = dict(mesh.shape)

    def _n(ax) -> int:
        axes = ax if isinstance(ax, (tuple, list)) else (ax,)
        n = 1
        for a in axes:
            n *= axsize[a]
        return n

    def one(spec: P, sds) -> P:
        dims = tuple(spec) + (None,) * (len(sds.shape) - len(tuple(spec)))
        out = [
            ax if ax is not None and d % _n(ax) == 0 else None
            for d, ax in zip(sds.shape, dims)
        ]
        return P(*out)

    return jax.tree.map(one, pspecs, shapes, is_leaf=lambda x: isinstance(x, P))


def to_named(mesh, pspecs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P)
    )


def should_fsdp(param_count: int) -> bool:
    return param_count >= FSDP_THRESHOLD


def scalar_spec(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
