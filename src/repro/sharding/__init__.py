"""Sharding: logical-axis rules and per-family partition specs."""
from repro.sharding import logical

__all__ = ["logical"]
