"""Dry-run core: lower + compile every (arch x input-shape x mesh) case.

No arrays are ever allocated: parameters/optimizer/caches/batches are
ShapeDtypeStruct stand-ins from ``jax.eval_shape``; ``jit(...).lower(...)``
then ``.compile()`` proves the sharding config is coherent and yields the
cost/memory analyses the roofline reads.

Used by launch/dryrun.py (512 placeholder devices) and by the small-mesh
sharding tests (8 devices).
"""
from __future__ import annotations

import dataclasses
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, for_shape, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roofline_lib
from repro.launch import steps as steps_lib
from repro.models import build_model
from repro.sharding import logical, specs
from repro import optim as optim_lib

PyTree = Any

ACTIVATION_RULES = {
    "batch": "data",
    "seq": None,
    # the residual stream's seq dim; "model" = Megatron-style sequence
    # parallelism (AG before QKV/up-proj, RS after out-proj — half the wire
    # bytes of the all-reduce it replaces)
    "residual_seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_group": "data",
    "kv_lora": None,
    "layers": None,
    "conv": None,
    "state": None,
}


def make_optimizer(name: str) -> optim_lib.Optimizer:
    if name == "sgdm":
        return optim_lib.sgd(0.01, momentum=0.9)  # the paper's local update rule
    if name == "adamw":
        return optim_lib.adamw(3e-4)
    raise ValueError(f"unknown optimizer {name!r}")


def _stack(tree: PyTree, k: int) -> PyTree:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct((k,) + s.shape, s.dtype), tree)


def _named(mesh, ptree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), ptree, is_leaf=lambda x: isinstance(x, P)
    )


def _shardings(mesh, pspecs: PyTree, sds: PyTree) -> PyTree:
    """sanitize (divisibility) + wrap in NamedSharding."""
    return _named(mesh, specs.sanitize_pspecs(pspecs, sds, mesh))


@dataclasses.dataclass
class CaseResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    report: Optional[roofline_lib.Roofline] = None
    consensus_report: Optional[roofline_lib.Roofline] = None
    error: str = ""


def prepare_case(arch: str, shape_name: str, *, router_groups: int = 16):
    shape_cfg = INPUT_SHAPES[shape_name]
    cfg = for_shape(get_config(arch), shape_cfg)
    if cfg.moe is not None:
        cfg = cfg.replace(
            moe=dataclasses.replace(cfg.moe, router_groups=router_groups)
        )
    return cfg, shape_cfg


def run_case(
    arch: str,
    shape_name: str,
    mesh,
    *,
    multi_pod: bool = False,
    optimizer: str = "sgdm",
    algorithm: str = "p2pl_affinity",
    mesh_name: Optional[str] = None,
    with_consensus: bool = True,
    dump_hlo: Optional[str] = None,
    cache_layout: str = "auto",
    consensus_impl: str = "einsum",
    seq_parallel: bool = False,
) -> CaseResult:
    """cache_layout="auto" picks per phase: prefill writes every position, so
    the position-sharded ("seq") cache would scatter across shards — use
    "heads" there; decode reads the whole cache once per token — "seq" turns
    per-step cache all-gathers into a local partial-softmax (measured up to
    1500x on the collective term)."""
    t0 = time.time()
    mesh_name = mesh_name or "x".join(str(v) for v in mesh.shape.values())
    try:
        data_ax = mesh.shape.get("data", 1)
        cfg, shape_cfg = prepare_case(arch, shape_name, router_groups=data_ax)
        if cache_layout == "auto":
            cache_layout = "seq" if shape_cfg.kind == "decode" else "heads"
        model = build_model(cfg)
        chips = mesh_lib.num_chips(mesh)
        peers = mesh.shape.get("pod", 1)
        fsdp = specs.should_fsdp(cfg.param_count())
        peer_axis = "pod" if multi_pod else None
        eta_d = 1.0 if algorithm == "p2pl_affinity" else 0.0
        opt = make_optimizer(optimizer)

        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params_unstacked_for_consensus = params_sds
        param_bytes_total = sum(
            s.size * s.dtype.itemsize for s in jax.tree.leaves(params_sds)
        ) * max(peers, 1)

        p_specs = specs.param_pspecs(params_sds, fsdp=fsdp, peer_axis=peer_axis)
        if multi_pod:
            params_sds = _stack(params_sds, peers)

        rules_table = dict(ACTIVATION_RULES)
        if seq_parallel and shape_cfg.kind != "decode":
            rules_table["residual_seq"] = "model"
        with logical.rules(rules_table, mesh):
            if shape_cfg.kind == "train":
                lowered = _lower_train(
                    model, cfg, shape_cfg, mesh, multi_pod, peers, opt, eta_d,
                    params_sds, p_specs, fsdp,
                )
                step_kind = "train"
            elif shape_cfg.kind == "prefill":
                lowered = _lower_prefill(
                    model, cfg, shape_cfg, mesh, multi_pod, peers, params_sds, p_specs,
                    cache_layout,
                )
                step_kind = "prefill"
            else:
                lowered = _lower_decode(
                    model, cfg, shape_cfg, mesh, multi_pod, peers, params_sds, p_specs,
                    cache_layout,
                )
                step_kind = "decode"

            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            memstats = compiled.memory_analysis()
            hlo = compiled.as_text()
            if dump_hlo:
                with open(dump_hlo, "w") as f:
                    f.write(hlo)

            report = roofline_lib.build_report(
                arch=arch,
                shape=shape_name,
                mesh_name=mesh_name,
                chips=chips,
                step_kind=step_kind,
                cost=cost,
                memstats=memstats,
                hlo_text=hlo,
                model_flops_total=roofline_lib.model_flops(cfg, shape_cfg, peers=peers),
                param_bytes_total=param_bytes_total,
                extra={"fsdp": fsdp, "algorithm": algorithm, "optimizer": optimizer,
                       "cache_layout": cache_layout},
            )

            consensus_report = None
            if multi_pod and with_consensus and shape_cfg.kind == "train":
                # consensus is pure parameter-space: shard its trees maximally
                # (FSDP over `data` regardless of the train-path threshold —
                # wire scales with the replicated fraction; §Perf P1 it2/it3)
                cons_specs = specs.param_pspecs(
                    params_unstacked_for_consensus, fsdp=True, peer_axis=peer_axis
                )
                consensus_report = _lower_consensus(
                    arch, shape_name, mesh, mesh_name, chips, peers,
                    params_sds, cons_specs, eta_d, param_bytes_total,
                    impl=consensus_impl,
                )

        return CaseResult(
            arch, shape_name, mesh_name, True, time.time() - t0,
            report=report, consensus_report=consensus_report,
        )
    except Exception:  # noqa: BLE001 — record and continue the sweep
        return CaseResult(
            arch, shape_name, mesh_name, False, time.time() - t0,
            error=traceback.format_exc(limit=20),
        )


def _lower_train(model, cfg, shape_cfg, mesh, multi_pod, peers, opt, eta_d,
                 params_sds, p_specs, fsdp):
    b_per_peer = max(shape_cfg.global_batch // max(peers, 1), 1)
    batch_sds = model.batch_specs(b_per_peer, shape_cfg.seq_len)
    d_sds = params_sds  # affinity bias tree mirrors params (incl. peer stack)

    # optimizer state mirrors the per-peer params: build specs unstacked,
    # then stack the shapes (param_pspecs' peer_axis adds the prefix only).
    peer_axis = "pod" if multi_pod else None
    params_unstacked = (
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), params_sds)
        if multi_pod
        else params_sds
    )
    opt_unstacked = jax.eval_shape(opt.init, params_unstacked)
    opt_specs = specs.param_pspecs(opt_unstacked, fsdp=fsdp, peer_axis=peer_axis)
    opt_sds = _stack(opt_unstacked, peers) if multi_pod else opt_unstacked
    b_specs = specs.batch_pspecs(batch_sds, peer_axis=peer_axis)
    if multi_pod:
        batch_sds = _stack(batch_sds, peers)

    if multi_pod:
        step_fn = steps_lib.make_multipod_train_step(model, opt, eta_d=eta_d)
    else:
        step_fn = steps_lib.make_train_step(model, opt, eta_d=eta_d)

    p_sh = _shardings(mesh, p_specs, params_sds)
    o_sh = _shardings(mesh, opt_specs, opt_sds)
    b_sh = _shardings(mesh, b_specs, batch_sds)
    in_sh = (p_sh, o_sh, p_sh, b_sh, NamedSharding(mesh, P()))
    out_sh = (p_sh, o_sh, NamedSharding(mesh, P()))
    if multi_pod:
        out_sh = (*out_sh[:2], NamedSharding(mesh, P("pod")))

    step_sds = jax.ShapeDtypeStruct((), jnp.int32)
    return jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh).lower(
        params_sds, opt_sds, d_sds, batch_sds, step_sds
    )


def _cache_for(model, cfg, b, s, multi_pod, peers, mesh, cache_layout="heads"):
    cache_sds = jax.eval_shape(lambda: model.init_cache(b, s))
    peer_axis = "pod" if multi_pod else None
    c_specs = specs.cache_pspecs(
        cache_sds, family=cfg.family, peer_axis=peer_axis, layout=cache_layout
    )
    if multi_pod:
        cache_sds = _stack(cache_sds, peers)
    return cache_sds, _shardings(mesh, c_specs, cache_sds)


def _lower_prefill(model, cfg, shape_cfg, mesh, multi_pod, peers, params_sds, p_specs,
                   cache_layout="heads"):
    b_per_peer = max(shape_cfg.global_batch // max(peers, 1), 1)
    batch_sds = model.batch_specs(b_per_peer, shape_cfg.seq_len)
    peer_axis = "pod" if multi_pod else None
    b_specs = specs.batch_pspecs(batch_sds, peer_axis=peer_axis)
    cache_sds, c_sh = _cache_for(model, cfg, b_per_peer, shape_cfg.seq_len, multi_pod,
                                 peers, mesh, cache_layout)
    if multi_pod:
        batch_sds = _stack(batch_sds, peers)
        step_fn = jax.vmap(steps_lib.make_prefill_step(model), spmd_axis_name="pod")
        tok_sh = NamedSharding(mesh, P("pod", "data"))
    else:
        step_fn = steps_lib.make_prefill_step(model)
        tok_sh = NamedSharding(mesh, P("data"))

    in_sh = (_shardings(mesh, p_specs, params_sds), _shardings(mesh, b_specs, batch_sds), c_sh)
    out_sh = (tok_sh, c_sh)
    return jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh).lower(
        params_sds, batch_sds, cache_sds
    )


def _lower_decode(model, cfg, shape_cfg, mesh, multi_pod, peers, params_sds, p_specs,
                  cache_layout="heads"):
    b_per_peer = max(shape_cfg.global_batch // max(peers, 1), 1)
    cache_sds, c_sh = _cache_for(model, cfg, b_per_peer, shape_cfg.seq_len, multi_pod,
                                 peers, mesh, cache_layout)
    tok_shape = (peers, b_per_peer) if multi_pod else (b_per_peer,)
    tok_sds = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
    tok_spec = P("pod", "data") if multi_pod else P("data")
    tok_sh = NamedSharding(mesh, specs.sanitize_pspecs(tok_spec, tok_sds, mesh))

    if multi_pod:
        step_fn = steps_lib.make_multipod_serve_step(model)
    else:
        step_fn = steps_lib.make_serve_step(model)

    in_sh = (_shardings(mesh, p_specs, params_sds), c_sh, tok_sh, tok_sh)
    out_sh = (tok_sh, tok_sh, c_sh)
    return jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh).lower(
        params_sds, cache_sds, tok_sds, tok_sds
    )


def _lower_consensus(arch, shape_name, mesh, mesh_name, chips, peers,
                     params_sds, p_specs, eta_d, param_bytes_total, impl="einsum"):
    """Lower the gossip step across the pod axis (complete graph, K=peers)."""
    from repro.core import graph as graph_lib

    g = graph_lib.build_graph("complete", peers)
    w = graph_lib.mixing_matrix(g, "data_weighted", data_sizes=np.ones(peers))
    beta = graph_lib.affinity_matrix(g)
    if impl == "psum":
        step_fn = steps_lib.make_consensus_step_psum(
            peers, self_weight=float(w[0, 0]), peer_weight=float(w[0, 1]),
            local_steps=60, use_affinity=eta_d != 0.0,
        )
    else:
        step_fn = steps_lib.make_consensus_step(
            w, beta, local_steps=60, use_affinity=eta_d != 0.0
        )
    sh = _shardings(mesh, p_specs, params_sds)
    lowered = jax.jit(step_fn, in_shardings=(sh, sh), out_shardings=(sh, sh)).lower(
        params_sds, params_sds
    )
    compiled = lowered.compile()
    return roofline_lib.build_report(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        step_kind="consensus",
        cost=compiled.cost_analysis(),
        memstats=compiled.memory_analysis(),
        hlo_text=compiled.as_text(),
        model_flops_total=0.0,
        param_bytes_total=param_bytes_total,
        extra={"note": "amortize collective term by 1/T (T=60 local steps)",
               "impl": impl},
    )
