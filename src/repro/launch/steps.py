"""jit-able step functions: local train step, consensus step, serve steps.

The paper's round structure at production scale:
    for r in rounds:
        for t in range(T):  train_step        (intra-peer only: FSDP/TP colls)
        consensus_step                        (inter-peer: the `pod` axis)

``train_step`` is the P2PL learning phase (Eq. 3): grad + optimizer update +
eta_d * d affinity bias.  ``consensus_step`` is Eq. 4 plus the affinity d/b
updates — at zero extra communication, since d is computed from the very
parameters the mixing step already gathers (verified by the dry-run byte
parity check in EXPERIMENTS.md).

Multi-pod variants wrap the single-peer step in
``jax.vmap(..., spmd_axis_name="pod")`` over peer-stacked trees.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus as consensus_lib
from repro.models.registry import Model
from repro.optim import Optimizer

PyTree = Any


def make_train_step(model: Model, opt: Optimizer, *, eta_d: float = 0.0) -> Callable:
    """(params, opt_state, d_bias, batch, step) -> (params, opt_state, loss)."""

    def train_step(params, opt_state, d_bias, batch, step):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, step)
        if eta_d:
            params = jax.tree.map(
                lambda w, d: (w.astype(jnp.float32) + eta_d * d.astype(jnp.float32)).astype(
                    w.dtype
                ),
                params,
                d_bias,
            )
        return params, opt_state, loss

    return train_step


def make_consensus_step(
    w_mat: np.ndarray,
    beta_mat: np.ndarray,
    *,
    local_steps: int,
    use_affinity: bool,
) -> Callable:
    """Stacked-peer gossip: (stacked_params, d_bias) -> (mixed_params, new_d).

    Operates on trees whose leaves carry a leading K (peer) axis, sharded over
    the `pod` mesh axis at production scale.  The mixing einsum lowers to an
    all-gather/all-reduce across `pod` only.
    """
    w = jnp.asarray(w_mat, jnp.float32)
    beta = jnp.asarray(beta_mat, jnp.float32)

    def consensus_step(stacked_params, d_bias):
        if use_affinity:
            nbr_avg = consensus_lib.mix_stacked(beta, stacked_params)
            d_bias = jax.tree.map(
                lambda avg, p: (avg.astype(jnp.float32) - p.astype(jnp.float32))
                / local_steps,
                nbr_avg,
                stacked_params,
            )
        mixed = consensus_lib.mix_stacked(w, stacked_params)
        return mixed, d_bias

    return consensus_step


def make_consensus_step_psum(
    num_peers: int,
    *,
    self_weight: float,
    peer_weight: float,
    local_steps: int,
    use_affinity: bool,
) -> Callable:
    """Optimized gossip for uniform complete graphs (the pod-level topology).

    out_k = a*x_k + b*sum_{j!=k} x_j = (a-b)*x_k + b*S,   S = sum_k x_k
    d_k   = (S - x_k)/(K-1 ) - x_k, scaled by 1/T          (uniform beta)

    Both outputs derive from ONE peer-axis reduction S: XLA lowers the
    jnp.sum over the stacked axis into a single all-reduce of the *local
    shard* across the pod axis — vs. the general einsum form, which the
    partitioner resolves by fully rematerializing (replicating) the stacked
    parameters on every chip (measured: ~113 GiB/chip for rwkv6-7b).  This
    also makes the paper's zero-extra-communication claim structural: the
    affinity d costs zero additional collective ops, not just zero bytes.
    """

    def consensus_step(stacked_params, d_bias):
        def mix_leaf(x):
            xf = x.astype(jnp.float32)
            s = jnp.sum(xf, axis=0, keepdims=True)  # one all-reduce over pod
            mixed = (self_weight - peer_weight) * xf + peer_weight * s
            return mixed.astype(x.dtype), s

        mixed_and_s = jax.tree.map(mix_leaf, stacked_params)
        mixed = jax.tree.map(lambda t: t[0], mixed_and_s,
                             is_leaf=lambda t: isinstance(t, tuple))
        if use_affinity:
            def d_leaf(pair, x):
                _, s = pair
                xf = x.astype(jnp.float32)
                nbr_avg = (s - xf) / max(num_peers - 1, 1)
                return ((nbr_avg - xf) / local_steps).astype(x.dtype)

            d_bias = jax.tree.map(
                d_leaf, mixed_and_s, stacked_params,
                is_leaf=lambda t: isinstance(t, tuple),
            )
        return mixed, d_bias

    return consensus_step


def make_multipod_train_step(model: Model, opt: Optimizer, *, eta_d: float = 0.0) -> Callable:
    """vmap the single-peer train step over the leading peer axis; inner
    sharding constraints are lifted onto the `pod` mesh axis via
    spmd_axis_name (each peer's compute stays inside its pod)."""
    step = make_train_step(model, opt, eta_d=eta_d)
    return jax.vmap(step, in_axes=(0, 0, 0, 0, None), spmd_axis_name="pod")


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One decode step: greedy-sample the next token, update the cache."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, token, pos, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, pos + 1, cache

    return serve_step


def make_multipod_serve_step(model: Model) -> Callable:
    step = make_serve_step(model)
    return jax.vmap(step, in_axes=(0, 0, 0, 0), spmd_axis_name="pod")


def prompt_dec_len(batch: PyTree) -> int:
    """Decoder-side length of a prompt batch: the position decode resumes at.

    vlm prefix embeddings (``patches``) occupy decoder cache slots ahead of
    the text tokens, so they advance the decode position; encoder inputs
    (encdec ``frames``) live in a separate cross-attention cache and do NOT.
    """
    n = batch["tokens"].shape[1]
    if "patches" in batch:
        n += batch["patches"].shape[1]
    return n


def make_decode_scan(model: Model, num_steps: int) -> Callable:
    """(params, cache, token, pos) -> (tokens (B, num_steps), cache).

    The per-token python decode loop collapsed into ONE ``lax.scan`` over
    generation steps — one dispatch and one compile for the whole generation
    instead of one per token (the same scan pattern that fused the round
    loop in ``core/p2p.py:make_scan_driver``).  ``num_steps`` is static: one
    compile per generation length.  ``num_steps == 0`` is rejected — callers
    take the empty-decode path structurally (see ``make_generate_fn``).
    """
    if num_steps < 1:
        raise ValueError(
            f"make_decode_scan needs num_steps >= 1, got {num_steps}; a "
            "zero-step decode is the explicit empty-decode case — skip the "
            "scan entirely (make_generate_fn does this structurally)"
        )
    step = make_serve_step(model)

    def decode_scan(params, cache, token, pos):
        def body(carry, _):
            tok, p, c = carry
            tok, p, c = step(params, c, tok, p)
            return (tok, p, c), tok

        (_, _, cache), toks = jax.lax.scan(
            body, (token, pos, cache), None, length=num_steps
        )
        return jnp.moveaxis(toks, 0, 1), cache  # (steps, B) -> (B, steps)

    return decode_scan


def make_generate_fn(model: Model, gen_tokens: int) -> Callable:
    """(params, batch, cache) -> (tokens (B, gen_tokens), cache).

    Prefill + scanned greedy decode as one traceable function: the prefill
    argmax is the first generated token, the remaining ``gen_tokens - 1``
    come from ``make_decode_scan``.  ``gen_tokens == 1`` skips the scan
    STRUCTURALLY (prefill only — the explicit empty decode).  Returning the
    final cache lets callers jit with ``donate_argnums`` on the cache slot:
    the input buffers are reused in place for the output cache.
    """
    if gen_tokens < 1:
        raise ValueError(f"need gen_tokens >= 1, got {gen_tokens}")
    prefill = make_prefill_step(model)
    decode = make_decode_scan(model, gen_tokens - 1) if gen_tokens > 1 else None

    def generate(params, batch, cache):
        tok, cache = prefill(params, batch, cache)
        if decode is None:
            return tok[:, None], cache
        pos = jnp.full(tok.shape, prompt_dec_len(batch), jnp.int32)
        toks, cache = decode(params, cache, tok, pos)
        return jnp.concatenate([tok[:, None], toks], axis=1), cache

    return generate
