"""HLO-text cost model with while-loop trip-count scaling.

``compiled.cost_analysis()`` counts each op ONCE — ops inside a ``while`` body
(i.e. everything under ``lax.scan``, which this framework uses for layer
stacks and SSM chunk scans) are NOT multiplied by the trip count, so scanned
models would be undercounted by ~num_layers x.  This module re-derives
FLOPs / bytes / collective-wire-bytes from ``compiled.as_text()`` directly:

1. split the module into computations,
2. walk the call graph from ENTRY, assigning every computation an execution
   multiplier (while bodies/conds: x trip count, parsed from the loop-bound
   constant in the condition computation; fusions/calls: x1),
3. per op: dot FLOPs from shapes + contracting dims; bytes = operand+result
   shape bytes; collective wire bytes as in roofline.parse_collectives.

Validated against closed-form expectations in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# header lines may contain nested tuple types in the params — just detect
# "... -> ... {" and grab the leading name
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+)?([a-z][\w\-]*)\(")
_CALL_REFS = (
    ("body=", re.compile(r"body=%?([\w\.\-]+)")),
    ("condition=", re.compile(r"condition=%?([\w\.\-]+)")),
    ("calls=", re.compile(r"calls=%?([\w\.\-]+)")),
    ("to_apply=", re.compile(r"to_apply=%?([\w\.\-]+)")),
    ("branch_computations=", re.compile(r"branch_computations=\{([^}]*)\}")),
)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dtype]
    return total


_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


@dataclasses.dataclass
class Op:
    opcode: str
    line: str
    name: str = ""
    result_bytes: int = 0
    result_shape: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_entry: bool = False


_COMMENT_RE = re.compile(r"/\*[^*]*\*/")


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HEADER_RE.match(stripped)
                if m:
                    cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        mo = _OPCODE_RE.match(rhs)
        if mo:
            opcode = mo.group(2)
        else:
            head = rhs.split("(")[0].split()
            opcode = head[-1] if head else ""
        shape_str = mo.group(1) or "" if mo else ""
        cur.ops.append(Op(opcode, line, name=name,
                          result_bytes=_shapes_bytes(shape_str),
                          result_shape=shape_str.strip()))
    return comps


def _refs(line: str) -> list[str]:
    out = []
    for _tag, rx in _CALL_REFS:
        m = rx.search(line)
        if not m:
            continue
        blob = m.group(1)
        for part in blob.split(","):
            part = part.strip().lstrip("%")
            if part:
                out.append(part)
    return out


def _const_value(op: Op):
    m = re.search(r"constant\((-?\d+)\)", op.line)
    return int(m.group(1)) if m else None


def _trip_count(while_op: Op, cond: Computation | None, enclosing: Computation) -> int:
    """Loop bound resolution chain:
    (a) integer literal in the condition computation (constant-folded bounds),
    (b) max s32 scalar constant among the while's init-tuple operands
        (jax.lax.scan carries the bound as a tuple element),
    (c) max leading dim of stacked (rank>=2) result tuple elements,
    (d) 1."""
    if cond is not None:
        best = max((_const_value(op) or 0 for op in cond.ops if op.opcode == "constant"),
                   default=0)
        if best > 1:
            return best
    table = {op.name: op for op in enclosing.ops}
    args = (
        _OPERANDS_RE.findall(while_op.line.split("(", 1)[1].split(")")[0])
        if "(" in while_op.line else []
    )
    best = 0
    for a in args:
        init = table.get(a)
        if init is None:
            continue
        operands = []
        if init.opcode == "tuple" and "(" in init.line:
            operands = _OPERANDS_RE.findall(init.line.split("(", 1)[1].split(")")[0])
        else:
            operands = [a]
        for ref in operands:
            op = table.get(ref)
            if op is not None and op.opcode == "constant" and "s32[]" in op.line:
                v = _const_value(op)
                if v:
                    best = max(best, v)
    if best > 1:
        return best
    dims = [
        _first_shape_dims(m.group(0))
        for m in _SHAPE_RE.finditer(while_op.result_shape)
    ]
    shapes = (
        _first_shape_dims(f"{t}[{dd}]")
        for t, dd in _SHAPE_RE.findall(while_op.result_shape)
    )
    lead = max((d[0] for d in shapes if d and len(d) >= 2), default=1)
    del dims
    return max(lead, 1)


def _first_shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    return [int(x) for x in m.group(2).split(",") if x]


def _dot_flops(op: Op, table: dict) -> float:
    """2 * prod(result dims) * prod(contracted dims of lhs)."""
    result_dims = _first_shape_dims(op.result_shape)
    if result_dims is None:
        return 0.0
    # operands are %-references; look their shapes up in the symbol table
    paren = op.line.split("(", 1)[1] if "(" in op.line else ""
    args = _OPERANDS_RE.findall(paren.split(")")[0])
    if not args or args[0] not in table:
        return 0.0
    lhs_dims = _first_shape_dims(table[args[0]])
    if lhs_dims is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contracted = 1
    if m:
        for idx in m.group(1).split(","):
            if idx:
                contracted *= lhs_dims[int(idx)]
    res = 1
    for d in result_dims:
        res *= d
    return 2.0 * res * contracted


def _op_bytes(op: Op, table: dict) -> int:
    """result bytes + operand bytes (via the symbol table)."""
    total = op.result_bytes
    if "(" in op.line:
        paren = op.line.split("(", 1)[1].split(")")[0]
        for ref in _OPERANDS_RE.findall(paren):
            if ref in table:
                total += _shapes_bytes(table[ref])
    return total


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    coll_wire_bytes: float
    coll_by_kind: dict
    loop_info: dict  # computation name -> multiplier


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.ops))

    # Two multipliers per computation: `mult` scales flops/collectives
    # everywhere; `mult_mem` scales bytes and is NOT propagated into fusion
    # bodies or reduce/scatter appliers — ops inside a fusion touch registers
    # /VMEM, not HBM (the fusion callsite's operands+result carry the traffic).
    mult: dict[str, float] = defaultdict(float)
    mult_mem: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    mult_mem[entry.name] = 1.0
    queue = [entry.name]
    seen_edges = set()
    _FUSED_CALLERS = ("fusion", "reduce", "scatter", "sort", "map",
                      "reduce-window", "select-and-scatter", "all-reduce",
                      "reduce-scatter")
    while queue:
        name = queue.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        mm = mult_mem[name]
        for op in comp.ops:
            refs = _refs(op.line)
            if not refs:
                continue
            if op.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = _trip_count(op, comps.get(cond), comp)
                for r in (body, cond):
                    if r and (name, r) not in seen_edges:
                        mult[r] += m * trips
                        mult_mem[r] += mm * trips
                        seen_edges.add((name, r))
                        queue.append(r)
            else:
                fused = op.opcode in _FUSED_CALLERS
                for r in refs:
                    if (name, r, op.opcode) in seen_edges:
                        continue
                    seen_edges.add((name, r, op.opcode))
                    mult[r] += m
                    if not fused:
                        mult_mem[r] += mm
                    queue.append(r)

    flops = 0.0
    bytes_acc = 0.0
    coll = {k: {"count": 0.0, "wire_bytes": 0.0} for k in COLLECTIVES}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        mm = mult_mem.get(name, 0.0)
        table = {op.name: op.result_shape for op in comp.ops}
        for op in comp.ops:
            if op.opcode in ("dot", "dot-general", "convolution"):
                flops += m * _dot_flops(op, table)
            # skip pure bookkeeping ops for bytes
            if mm > 0 and op.opcode not in ("parameter", "constant", "tuple",
                                            "get-tuple-element", "bitcast"):
                bytes_acc += mm * _op_bytes(op, table)
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base in COLLECTIVES:
                coll[base]["count"] += m
                coll[base]["wire_bytes"] += m * op.result_bytes * _WIRE_FACTOR[base]
    total_wire = sum(v["wire_bytes"] for v in coll.values())
    return HloCost(
        flops=flops,
        bytes_accessed=bytes_acc,
        coll_wire_bytes=total_wire,
        coll_by_kind={k: v for k, v in coll.items() if v["count"]},
        loop_info={k: v for k, v in mult.items() if v > 1.0},
    )
