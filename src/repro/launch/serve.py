"""Batched serving loop: prefill a prompt batch, then greedy decode.

CPU-runnable on reduced configs; the same serve_step is what the dry-run
lowers at production shapes (decode_32k / long_500k).

CLI:  python -m repro.launch.serve --arch smollm-135m --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.launch import steps as steps_lib
from repro.models import build_model


def serve_batch(
    arch: str = "smollm-135m",
    *,
    batch: int = 4,
    prompt_len: int = 16,
    gen_tokens: int = 8,
    use_reduced: bool = True,
    seed: int = 0,
    verbose: bool = False,
) -> dict:
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)

    prompt = model.make_batch(rng, batch, prompt_len)
    max_len = prompt_len + gen_tokens
    cache = model.init_cache(batch, max_len)

    prefill = jax.jit(steps_lib.make_prefill_step(model))
    serve = jax.jit(steps_lib.make_serve_step(model))

    t0 = time.time()
    tok, cache = prefill(params, prompt, cache)
    prefill_s = time.time() - t0

    # decode positions continue after the prompt's *decoder-side* length
    dec_len = prompt["tokens"].shape[1]
    if "patches" in prompt:
        dec_len += prompt["patches"].shape[1]
    pos = jnp.full((batch,), dec_len, jnp.int32)

    generated = [tok]
    t0 = time.time()
    for _ in range(gen_tokens - 1):
        tok, pos, cache = serve(params, cache, tok, pos)
        generated.append(tok)
    decode_s = time.time() - t0
    out = jnp.stack(generated, axis=1)  # (B, gen)

    result = {
        "tokens": out,
        "prefill_s": prefill_s,
        "decode_s_per_token": decode_s / max(gen_tokens - 1, 1),
    }
    if verbose:
        print(f"arch={arch} batch={batch} prompt={prompt_len} gen={gen_tokens}")
        print(f"prefill: {prefill_s*1e3:.1f} ms; decode: "
              f"{result['decode_s_per_token']*1e3:.2f} ms/token")
        print("sample tokens:", out[0].tolist())
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--full", action="store_true", help="use the full (non-reduced) config")
    args = ap.parse_args(argv)
    serve_batch(
        args.arch,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen_tokens=args.gen,
        use_reduced=not args.full,
        verbose=True,
    )


if __name__ == "__main__":
    main()
