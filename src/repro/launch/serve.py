"""Serving: single-model batched decode and the stacked K-model fleet.

``serve_batch`` serves ONE model: prefill a prompt batch, then greedy-decode
with the generation collapsed into a single ``lax.scan`` dispatch
(``launch/steps.py:make_decode_scan``; ``decode_impl="python"`` keeps the
legacy per-token loop as the parity baseline).

``serve_fleet`` is the personalized-fleet path — P2PL's product is K
*divergent* models, and the trainer already emits them stacked
(``core/p2p.py:P2PState.params``, leading K axis).  The fleet server keeps
that exact layout: ``make_fleet_generate_fn`` routes each request group to
its peer's weights via a TRACED ``peer_ids`` gather and vmaps the fused
generate over the group axis, so ONE compile serves any request routing (the
one-compile rule of docs/ARCHITECTURE.md, applied to serving).  With
``peer_axis="pod"`` the same jitted function runs with the K parameter rows
sharded over the mesh (``sharding/specs.py:shard_peer_tree`` — the identical
placement the sharded trainer uses), so serving and training share the
stacked-parameter layout.

CLI:  python -m repro.launch.serve --arch smollm-135m --batch 4 --gen 8
      python -m repro.launch.serve --peers 8          # the stacked fleet
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.launch import steps as steps_lib
from repro.models import build_model

PyTree = Any


def route_params(stacked_params: PyTree, peer_ids: jax.Array) -> PyTree:
    """Gather each request group's parameter rows: (K, ...) -> (G, ...).

    ``peer_ids`` (G,) int32 is a TRACED value — routing changes never
    recompile (``jnp.take`` with a traced index, not python indexing).
    """
    return jax.tree.map(lambda p: jnp.take(p, peer_ids, axis=0), stacked_params)


def make_fleet_generate_fn(model, gen_tokens: int) -> Callable:
    """The stacked K-model serving step.

    (stacked_params (K, ...), prompts (G, B, ...), caches (G, ...),
    peer_ids (G,)) -> (tokens (G, B, gen_tokens), caches)

    Request group g decodes under peer ``peer_ids[g]``'s weights: a traced
    gather routes the parameter rows, then the fused prefill+scan generate
    (``steps.make_generate_fn``) is vmapped over the group axis.  Jit with
    ``donate_argnums=(2,)`` to reuse the cache buffers in place.
    """
    generate = steps_lib.make_generate_fn(model, gen_tokens)

    def fleet(stacked_params, prompts, caches, peer_ids):
        routed = route_params(stacked_params, peer_ids)
        return jax.vmap(generate)(routed, prompts, caches)

    return fleet


def make_fleet_classify_fn(apply_fn: Callable) -> Callable:
    """Stacked fleet serving for classifier models (the paper's 2NN MLP).

    (stacked_params (K, ...), inputs (G, N, ...), peer_ids (G,)) ->
    logits (G, N, C) — the same traced-gather + vmap routing as the LLM
    fleet, over a single forward instead of a generate loop.
    """

    def fleet(stacked_params, inputs, peer_ids):
        routed = route_params(stacked_params, peer_ids)
        return jax.vmap(apply_fn)(routed, inputs)

    return fleet


def stack_request_caches(cache: PyTree, num_groups: int) -> PyTree:
    """Replicate one fresh decode cache into the (G, ...) group layout."""
    return jax.tree.map(
        lambda x: jnp.tile(x[None], (num_groups,) + (1,) * x.ndim), cache
    )


def serve_batch(
    arch: str = "smollm-135m",
    *,
    batch: int = 4,
    prompt_len: int = 16,
    gen_tokens: int = 8,
    use_reduced: bool = True,
    seed: int = 0,
    verbose: bool = False,
    decode_impl: str = "scan",
) -> dict:
    """Single-model serving: prefill, then greedy-decode ``gen_tokens - 1``.

    Timing follows benchmarks/timing.py's discipline: jax dispatches
    asynchronously, so inputs are blocked on before the start timestamp and
    outputs before the stop timestamp — a bare ``time.time()`` around a jit
    call measures enqueue time, not execution time (and the reported times
    here still include compile, since each jit runs once; steady-state
    numbers live in benchmarks/serving.py).

    ``gen_tokens=1`` is the EXPLICIT empty decode: zero serve steps run, the
    prefill-sampled token is the only output (``tokens`` is (B, 1)),
    ``decode_steps`` is 0 and ``decode_s_per_token`` is None — not a rate
    divided out of a region in which nothing executed.
    """
    if gen_tokens < 1:
        raise ValueError(f"need gen_tokens >= 1, got {gen_tokens}")
    if decode_impl not in ("scan", "python"):
        raise ValueError(
            f"decode_impl must be 'scan' or 'python', got {decode_impl!r}"
        )
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)

    prompt = model.make_batch(rng, batch, prompt_len)
    max_len = prompt_len + gen_tokens
    cache = model.init_cache(batch, max_len)

    prefill = jax.jit(steps_lib.make_prefill_step(model))

    jax.block_until_ready((params, prompt, cache))
    t0 = time.perf_counter()
    tok, cache = prefill(params, prompt, cache)
    jax.block_until_ready((tok, cache))
    prefill_s = time.perf_counter() - t0

    decode_steps = gen_tokens - 1
    if decode_steps == 0:
        out = tok[:, None]
        decode_s_per_token = None
    else:
        # decode positions continue after the prompt's *decoder-side* length
        pos = jnp.full((batch,), steps_lib.prompt_dec_len(prompt), jnp.int32)
        if decode_impl == "scan":
            decode = jax.jit(
                steps_lib.make_decode_scan(model, decode_steps),
                donate_argnums=(1,),
            )
            t0 = time.perf_counter()
            gen, cache = decode(params, cache, tok, pos)
            jax.block_until_ready((gen, cache))
            decode_s = time.perf_counter() - t0
        else:
            serve = jax.jit(steps_lib.make_serve_step(model))
            first, toks = tok, []
            t0 = time.perf_counter()
            for _ in range(decode_steps):
                tok, pos, cache = serve(params, cache, tok, pos)
                toks.append(tok)
            jax.block_until_ready((toks, cache))
            decode_s = time.perf_counter() - t0
            gen, tok = jnp.stack(toks, axis=1), first
        out = jnp.concatenate([tok[:, None], gen], axis=1)
        decode_s_per_token = decode_s / decode_steps

    result = {
        "tokens": out,  # (B, gen_tokens)
        "cache": cache,
        "prefill_s": prefill_s,
        "decode_steps": decode_steps,
        "decode_s_per_token": decode_s_per_token,
    }
    if verbose:
        print(f"arch={arch} batch={batch} prompt={prompt_len} gen={gen_tokens} "
              f"decode_impl={decode_impl}")
        decode_msg = (
            "decode: (empty — gen_tokens=1 samples only the prefill token)"
            if decode_s_per_token is None
            else f"decode: {decode_s_per_token*1e3:.2f} ms/token"
        )
        print(f"prefill: {prefill_s*1e3:.1f} ms; {decode_msg}")
        print("sample tokens:", out[0].tolist())
    return result


def serve_fleet(
    arch: str = "smollm-135m",
    *,
    num_peers: int = 8,
    batch: int = 4,
    prompt_len: int = 16,
    gen_tokens: int = 8,
    use_reduced: bool = True,
    seed: int = 0,
    peer_axis: str = "vmap",
    verbose: bool = False,
) -> dict:
    """Serve ``num_peers`` personalized models from ONE stacked process.

    Builds K per-peer parameter sets (independent seeds standing in for a
    trained ``P2PState.params`` stack), one request group per peer, and runs
    the whole fleet through a single jitted call with cache donation.
    ``peer_axis="pod"`` places the K rows (and the request groups) over the
    mesh — one device per peer, same layout as the sharded trainer; it
    needs ``num_peers`` visible devices (``launch/mesh.py:make_peer_mesh``
    fails fast with the CPU incantation otherwise).
    """
    if peer_axis not in ("vmap", "pod"):
        raise ValueError(f"peer_axis must be 'vmap' or 'pod', got {peer_axis!r}")
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    stacked_params = jax.vmap(model.init)(
        jax.random.split(jax.random.PRNGKey(seed), num_peers)
    )
    prompts = jax.vmap(lambda k: model.make_batch(k, batch, prompt_len))(
        jax.random.split(jax.random.PRNGKey(seed + 1), num_peers)
    )
    caches = stack_request_caches(
        model.init_cache(batch, prompt_len + gen_tokens), num_peers
    )
    peer_ids = jnp.arange(num_peers, dtype=jnp.int32)

    fleet = jax.jit(make_fleet_generate_fn(model, gen_tokens), donate_argnums=(2,))
    if peer_axis == "pod":
        from repro.launch import mesh as mesh_lib
        from repro.sharding import specs as specs_lib

        mesh = mesh_lib.make_peer_mesh(num_peers)
        stacked_params = specs_lib.shard_peer_tree(stacked_params, mesh)
        prompts = specs_lib.shard_peer_tree(prompts, mesh)
        caches = specs_lib.shard_peer_tree(caches, mesh)
        peer_ids = specs_lib.shard_peer_tree(peer_ids, mesh)

    jax.block_until_ready((stacked_params, prompts, caches, peer_ids))
    t0 = time.perf_counter()
    tokens, caches = fleet(stacked_params, prompts, caches, peer_ids)
    jax.block_until_ready(tokens)
    serve_s = time.perf_counter() - t0

    total_tokens = int(tokens.shape[0] * tokens.shape[1] * tokens.shape[2])
    result = {
        "tokens": tokens,  # (K, B, gen_tokens)
        "serve_s": serve_s,
        "tokens_per_s": total_tokens / serve_s,
    }
    if verbose:
        print(f"arch={arch} fleet: {num_peers} personalized models x "
              f"{batch} requests x {gen_tokens} tokens, peer_axis={peer_axis}")
        print(f"one stacked call: {serve_s*1e3:.1f} ms "
              f"({result['tokens_per_s']:.0f} tokens/s, includes compile; "
              "steady-state numbers: benchmarks/serving.py)")
        print("peer 0 tokens:", tokens[0, 0].tolist())
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--peers", type=int, default=0,
                    help="serve this many personalized models from one "
                         "stacked process (0 = single-model serve_batch)")
    ap.add_argument("--peer-axis", default="vmap", choices=["vmap", "pod"],
                    help="with --peers: 'vmap' stacks the fleet on one "
                         "device; 'pod' shards one model replica per device "
                         "(needs --peers visible devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=K)")
    ap.add_argument("--decode-impl", default="scan", choices=["scan", "python"],
                    help="single-model decode driver: 'scan' is one fused "
                         "lax.scan dispatch, 'python' the legacy per-token "
                         "loop (parity baseline)")
    ap.add_argument("--full", action="store_true", help="use the full (non-reduced) config")
    args = ap.parse_args(argv)
    if args.peers:
        serve_fleet(
            args.arch,
            num_peers=args.peers,
            batch=args.batch,
            prompt_len=args.prompt_len,
            gen_tokens=args.gen,
            use_reduced=not args.full,
            peer_axis=args.peer_axis,
            verbose=True,
        )
        return
    serve_batch(
        args.arch,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen_tokens=args.gen,
        use_reduced=not args.full,
        verbose=True,
        decode_impl=args.decode_impl,
    )


if __name__ == "__main__":
    main()
