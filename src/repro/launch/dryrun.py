import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
# The 512 placeholder host devices exist ONLY for this dry-run entry point;
# smoke tests and benchmarks see the 1 real CPU device.

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

from repro.configs import ARCHITECTURES, INPUT_SHAPES  # noqa: E402
from repro.launch import dryrun_lib, mesh as mesh_lib, roofline  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run: lower+compile every case")
    ap.add_argument("--arch", default="all", help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="input shape or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--optimizer", default="sgdm", choices=["sgdm", "adamw"])
    ap.add_argument("--algorithm", default="p2pl_affinity",
                    choices=["p2pl_affinity", "local_dsgd"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--markdown", default="")
    ap.add_argument("--dump-hlo", default="", help="dir to dump per-case HLO text")
    ap.add_argument("--cache-layout", default="auto", choices=["auto", "heads", "seq"],
                    help="KV-cache sharding: auto = heads for prefill, seq for decode")
    ap.add_argument("--consensus-impl", default="einsum", choices=["einsum", "psum"],
                    help="gossip lowering across the pod axis")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="shard the residual seq dim over `model` (Megatron SP)")
    args = ap.parse_args(argv)

    archs = list(ARCHITECTURES) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("16x16", False))
    if args.mesh in ("multi", "both"):
        meshes.append(("2x16x16", True))

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    if args.dump_hlo:
        os.makedirs(args.dump_hlo, exist_ok=True)

    results, reports = [], []
    n_fail = 0
    for mesh_name, multi in meshes:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                dump = (
                    os.path.join(args.dump_hlo, f"{arch}_{shape}_{mesh_name}.hlo")
                    if args.dump_hlo
                    else None
                )
                t0 = time.time()
                res = dryrun_lib.run_case(
                    arch, shape, mesh,
                    multi_pod=multi,
                    optimizer=args.optimizer,
                    algorithm=args.algorithm,
                    mesh_name=mesh_name,
                    dump_hlo=dump,
                    cache_layout=args.cache_layout,
                    consensus_impl=args.consensus_impl,
                    seq_parallel=args.seq_parallel,
                )
                dt = time.time() - t0
                if res.ok:
                    r = res.report
                    print(
                        f"[ok]   {arch:22s} {shape:12s} {mesh_name:8s} "
                        f"{r.step_kind:8s} comp={roofline.fmt_seconds(r.compute_s)} "
                        f"mem={roofline.fmt_seconds(r.memory_s)} "
                        f"coll={roofline.fmt_seconds(r.collective_s)} "
                        f"dom={r.dominant} ({dt:.1f}s)",
                        flush=True,
                    )
                    reports.append(r)
                    if res.consensus_report:
                        reports.append(res.consensus_report)
                else:
                    n_fail += 1
                    print(f"[FAIL] {arch:22s} {shape:12s} {mesh_name}\n{res.error}", flush=True)
                results.append(
                    {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "ok": res.ok, "seconds": res.seconds,
                        "report": res.report.to_dict() if res.report else None,
                        "consensus": res.consensus_report.to_dict()
                        if res.consensus_report
                        else None,
                        "error": res.error,
                    }
                )
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(roofline.markdown_table(reports))
    print(f"\n{len(results) - n_fail}/{len(results)} cases compiled", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
