"""Roofline-term derivation from compiled dry-run artifacts.

This container is CPU-only: TPU v5e is the *target*, so wall-clock MFU cannot
be measured.  Instead we derive, per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_chip / HBM_bw               [s]
    collective term = collective_bytes_per_chip / link_bw       [s]

HLO_FLOPs / HLO_bytes / collective bytes come from the trip-count-scaled HLO
cost model (launch/hlo_cost.py) over ``compiled.as_text()`` — XLA's
``cost_analysis()`` counts while-loop bodies once, which would undercount
scanned layer stacks by ~num_layers x (its raw values are kept in
``extra["xla_cost_analysis"]``).  Collective wire bytes apply an algorithmic
factor (ring all-reduce moves ~2x the payload; the others ~1x).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

import numpy as np

from repro.launch import mesh as mesh_lib

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# bytes-on-the-wire multiplier per collective algorithm (ring)
_WIRE_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# result shapes may be tuples containing /*index=N*/ comments; capture
# everything between '=' and the op name (operands are %-prefixed, so an op
# name appearing as an operand never matches "<ws>op-name(").
_OP_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-kind {count, result_bytes, wire_bytes} + totals, per device."""
    out = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0} for k in COLLECTIVE_KINDS}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += b
        out[kind]["wire_bytes"] += b * _WIRE_FACTOR[kind]
    total_wire = sum(v["wire_bytes"] for v in out.values())
    total_result = sum(v["result_bytes"] for v in out.values())
    return {"by_kind": out, "wire_bytes": total_wire, "result_bytes": total_result}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    step_kind: str  # train | prefill | decode | consensus
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_wire_bytes_per_chip: float
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_chip: float
    useful_flop_ratio: float
    param_bytes_per_chip: float
    arg_bytes: float
    temp_bytes: float
    extra: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def build_report(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    step_kind: str,
    cost: dict,
    memstats,
    hlo_text: str,
    model_flops_total: float,
    param_bytes_total: float,
    extra: Optional[dict] = None,
) -> Roofline:
    # xla's cost_analysis counts while bodies ONCE; use the trip-count-scaled
    # HLO cost model instead (see launch/hlo_cost.py), keeping the raw
    # cost_analysis values in `extra` for reference.
    from repro.launch import hlo_cost as hlo_cost_lib

    hc = hlo_cost_lib.analyze(hlo_text)
    flops = float(hc.flops)
    hbm_bytes = float(hc.bytes_accessed)
    colls = {
        "by_kind": {
            k: {"count": v["count"], "result_bytes": 0, "wire_bytes": v["wire_bytes"]}
            for k, v in hc.coll_by_kind.items()
        },
        "wire_bytes": hc.coll_wire_bytes,
    }
    wire = float(colls["wire_bytes"])
    extra = dict(extra or {})
    # jax <= 0.4.x returns cost_analysis() as a one-element list of dicts;
    # newer jax returns the dict directly
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    extra["xla_cost_analysis"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    extra["loop_multipliers"] = {
        k: v for k, v in sorted(hc.loop_info.items(), key=lambda kv: -kv[1])[:8]
    }

    compute_s = flops / mesh_lib.PEAK_FLOPS_BF16
    memory_s = hbm_bytes / mesh_lib.HBM_BW
    collective_s = wire / mesh_lib.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    model_flops_per_chip = model_flops_total / chips
    useful = model_flops_per_chip / flops if flops else 0.0

    arg_bytes = float(getattr(memstats, "argument_size_in_bytes", 0) or 0)
    temp_bytes = float(getattr(memstats, "temp_size_in_bytes", 0) or 0)

    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        step_kind=step_kind,
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm_bytes,
        coll_wire_bytes_per_chip=wire,
        coll_breakdown={
            k: v for k, v in colls["by_kind"].items() if v["count"]
        },
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_per_chip=model_flops_per_chip,
        useful_flop_ratio=useful,
        param_bytes_per_chip=param_bytes_total / chips,
        arg_bytes=arg_bytes,
        temp_bytes=temp_bytes,
        extra=extra or {},
    )


def model_flops(cfg, shape_cfg, *, peers: int = 1) -> float:
    """MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D decode/prefill (fwd only);
    N = active params (MoE), D = tokens processed this step (all peers)."""
    n_active = cfg.active_param_count()
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence (global_batch tokens), at least `peers`
    tokens = max(shape_cfg.global_batch, peers)
    return 2.0 * n_active * tokens


def fmt_seconds(s: float) -> str:
    if s == 0:
        return "0"
    if s < 1e-6:
        return f"{s*1e9:.1f}ns"
    if s < 1e-3:
        return f"{s*1e6:.1f}us"
    if s < 1:
        return f"{s*1e3:.2f}ms"
    return f"{s:.2f}s"


def markdown_table(reports: list[Roofline]) -> str:
    hdr = (
        "| arch | shape | mesh | step | compute | memory | collective | dominant "
        "| useful FLOP ratio | params/chip | coll GiB/chip |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in reports:
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.step_kind} "
            f"| {fmt_seconds(r.compute_s)} | {fmt_seconds(r.memory_s)} "
            f"| {fmt_seconds(r.collective_s)} | **{r.dominant}** "
            f"| {r.useful_flop_ratio:.2f} | {r.param_bytes_per_chip/2**30:.2f} GiB "
            f"| {r.coll_wire_bytes_per_chip/2**30:.3f} |"
        )
    return hdr + "\n".join(rows) + "\n"


def save_reports(path: str, reports: list[Roofline]) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in reports], f, indent=1)


def load_reports(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)
