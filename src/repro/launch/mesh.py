"""Production mesh construction (TPU v5e pods; host-device placeholders here).

``make_production_mesh`` is a FUNCTION (never a module constant) so importing
this module never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (smoke tests, benches) sees the 1 real CPU device.

Axis semantics:
  pod   — the P2P *peer* axis at production scale: each pod is one paper
          "device"; consensus collectives run only across this axis.
  data  — intra-peer batch/FSDP axis.
  model — intra-peer tensor/expert-parallel axis.
"""
from __future__ import annotations

import jax

# TPU v5e roofline constants (per chip), per the assignment.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def _mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults to Auto
    # semantics anyway, so only pass axis_types where it exists.
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (requires >= prod(shape) devices)."""
    return _mesh(shape, axes)


def num_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
