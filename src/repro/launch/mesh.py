"""Production mesh construction (TPU v5e pods; host-device placeholders here).

``make_production_mesh`` is a FUNCTION (never a module constant) so importing
this module never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (smoke tests, benches) sees the 1 real CPU device.

Axis semantics:
  pod   — the P2P *peer* axis at production scale: each pod is one paper
          "device"; consensus collectives run only across this axis.
  data  — intra-peer batch/FSDP axis.
  model — intra-peer tensor/expert-parallel axis.

Running sharded locally
-----------------------
The sharded peer-axis runtime (``--peer-axis pod``,
``repro.core.p2p.make_sharded_round_fn``) needs one device per peer.  On a
CPU-only machine, force XLA to expose K host devices BEFORE the first jax
import (an env var, not a runtime switch)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.train --experiment sharded_k8 --peer-axis pod

The same incantation drives the ``mesh``-marked test suite
(``python -m pytest -m mesh``) and CI's multi-device job; results are
bit-identical to the vmap runtime, so the forced-host mesh is a faithful
stand-in for real hardware.  ``make_peer_mesh`` fails fast with this hint
when too few devices are visible.
"""
from __future__ import annotations

import jax
import numpy as np

# TPU v5e roofline constants (per chip), per the assignment.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def _mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults to Auto
    # semantics anyway, so only pass axis_types where it exists.
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (requires >= prod(shape) devices)."""
    return _mesh(shape, axes)


def make_peer_mesh(num_peers: int, *, axis_name: str = "pod"):
    """1-D mesh for the sharded peer-axis runtime: one device per peer.

    Fails fast (with the CPU incantation) when fewer than ``num_peers``
    devices are visible — the alternative is an opaque XLA sharding error
    deep inside the first jitted round.
    """
    if num_peers < 1:
        raise ValueError("need at least one peer")
    devices = jax.devices()
    if len(devices) < num_peers:
        raise RuntimeError(
            f"peer_axis={axis_name!r} needs one device per peer: "
            f"num_peers={num_peers} but only {len(devices)} jax device(s) "
            "visible. On CPU, relaunch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_peers} set before "
            "the first jax import (see repro/launch/mesh.py)."
        )
    # jax.sharding.Mesh (not jax.make_mesh): stable across supported jax
    # versions and accepts an explicit device subset.
    return jax.sharding.Mesh(np.asarray(devices[:num_peers]), (axis_name,))


def num_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
