"""Assemble EXPERIMENTS.md roofline/dry-run tables from sweep JSONs.

    PYTHONPATH=src python -m repro.launch.report \
        --baseline results/dryrun_single_baseline.json \
        --opt results/dryrun_single_opt.json \
        --multi results/dryrun_multi_opt.json --out results/tables.md
"""
from __future__ import annotations

import argparse
import json

from repro.launch.roofline import fmt_seconds


def _load(path):
    with open(path) as f:
        return json.load(f)


def _fmt_gib(b):
    return f"{b/2**30:.2f}"


def roofline_table(results, *, title):
    out = [f"### {title}\n"]
    out.append(
        "| arch | shape | step | compute | memory | collective | dominant | "
        "useful FLOPs | params/chip GiB | coll wire GiB/chip |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in results:
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
            continue
        p = r["report"]
        out.append(
            f"| {p['arch']} | {p['shape']} | {p['step_kind']} "
            f"| {fmt_seconds(p['compute_s'])} | {fmt_seconds(p['memory_s'])} "
            f"| {fmt_seconds(p['collective_s'])} | **{p['dominant']}** "
            f"| {p['useful_flop_ratio']:.2f} | {_fmt_gib(p['param_bytes_per_chip'])} "
            f"| {_fmt_gib(p['coll_wire_bytes_per_chip'])} |"
        )
    return "\n".join(out) + "\n"


def comparison_table(baseline, opt):
    """Baseline vs optimized deltas for cases where they differ."""
    base = {(r["arch"], r["shape"]): r for r in baseline if r["ok"]}
    out = [
        "| arch | shape | term | baseline | optimized | x |",
        "|---|---|---|---|---|---|",
    ]
    for r in opt:
        if not r["ok"]:
            continue
        key = (r["arch"], r["shape"])
        if key not in base:
            continue
        b, o = base[key]["report"], r["report"]
        for term in ("collective_s", "memory_s"):
            bv, ov = b[term], o[term]
            if bv > 0 and (bv / max(ov, 1e-12) >= 1.25 or ov / max(bv, 1e-12) >= 1.25):
                out.append(
                    f"| {r['arch']} | {r['shape']} | {term[:-2]} "
                    f"| {fmt_seconds(bv)} | {fmt_seconds(ov)} "
                    f"| {bv/max(ov,1e-12):.1f}x |"
                )
    return "\n".join(out) + "\n"


def consensus_table(multi):
    out = [
        "| arch | impl | collective | wire GiB/chip | amortized by T=60 |",
        "|---|---|---|---|---|",
    ]
    for r in multi:
        c = r.get("consensus")
        if not c:
            continue
        out.append(
            f"| {c['arch']} | {c['extra'].get('impl','?')} "
            f"| {fmt_seconds(c['collective_s'])} "
            f"| {_fmt_gib(c['coll_wire_bytes_per_chip'])} "
            f"| {fmt_seconds(c['collective_s']/60)}/step |"
        )
    return "\n".join(out) + "\n"


def summarize(results):
    ok = [r for r in results if r["ok"]]
    doms = {}
    for r in ok:
        doms[r["report"]["dominant"]] = doms.get(r["report"]["dominant"], 0) + 1
    return f"{len(ok)}/{len(results)} compiled; dominant terms: {doms}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--opt", required=True)
    ap.add_argument("--multi", required=True)
    ap.add_argument("--out", required=True)
    args = ap.parse_args(argv)

    baseline, opt, multi = _load(args.baseline), _load(args.opt), _load(args.multi)
    parts = [
        "## Dry-run / roofline summaries\n",
        f"- single-pod baseline: {summarize(baseline)}",
        f"- single-pod optimized: {summarize(opt)}",
        f"- multi-pod (2x16x16) optimized: {summarize(multi)}\n",
        roofline_table(
            baseline,
            title="Single-pod 16x16 — paper-faithful baseline (cache_layout=heads)",
        ),
        roofline_table(opt, title="Single-pod 16x16 — optimized (cache_layout=seq)"),
        roofline_table(multi, title="Multi-pod 2x16x16 — optimized (P2P peers = pods)"),
        "### Baseline vs optimized (>=1.25x deltas)\n",
        comparison_table(baseline, opt),
        "### Consensus step across the pod axis\n",
        consensus_table(multi),
    ]
    with open(args.out, "w") as f:
        f.write("\n".join(parts))
    print("wrote", args.out)


if __name__ == "__main__":
    main()
