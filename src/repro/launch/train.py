"""Training drivers.

``run_paper_experiment`` — K peers training the experiment's ``TrainTask``
(``core/task.py``: the paper's 2NN MLP by default, ``--model rwkv6_seqmnist``
for RWKV6 on sequential MNIST) on (synthetic-)MNIST shards under the
P2PL-with-Affinity family, measuring test accuracy after BOTH phases of every
round (the paper's instrument).  Runs the stacked/vmap runtime on CPU; this
is the end-to-end driver deliverable.

``run_p2p_lm`` — the same algorithm family applied to the LLM substrate:
K peers train a (reduced) assigned architecture on disjoint token shards,
interleaving T LM steps with gossip consensus.  Demonstrates the paper's
technique as a first-class feature of the large-model stack.

CLI:  python -m repro.launch.train --experiment noniid_affinity --rounds 40
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.p2pl_mnist import (
    PaperExperiment,
    directed_k8,
    iid_k100,
    noniid_k2,
    seqmnist_k8,
    sharded_k8,
    straggler_k8,
    timevarying_k2,
    timevarying_k8,
)
from repro import compression as compression_lib
from repro.core import consensus as consensus_lib
from repro.core import features as features_lib
from repro.core import graph as graph_lib
from repro.core import metrics as metrics_lib
from repro.core import p2p
from repro.core import protocols as protocols_lib
from repro.core import task as task_lib
from repro.data import partition, synthetic
from repro.models import build_model


def _mnist_parts(exp: PaperExperiment, x, y):
    if exp.peer_classes:
        return partition.pathological_partition(
            x, y, list(exp.peer_classes), samples_per_class=exp.samples_per_class
        )
    return partition.iid_partition(x, y, exp.p2p.num_peers)


def run_paper_experiment(
    exp: PaperExperiment,
    *,
    rounds: Optional[int] = None,
    data=None,
    eval_every: int = 1,
    seed: int = 0,
    verbose: bool = False,
    peer_axis: str = "vmap",
    driver: str = "scan",
    peers_per_device: int = 1,
    mix_mode: str = "auto",
    return_state: bool = False,
) -> metrics_lib.RoundLog:
    """``peer_axis``: "vmap" (stacked runtime, any device count) or "pod" (the
    sharded runtime: one device per peer, bit-identical results — see
    "Running sharded locally" in repro/launch/mesh.py).

    ``driver``: "scan" (default) runs each eval period as ONE jitted
    ``lax.scan`` chunk with the input state donated — one dispatch and at most
    one host transfer per eval period; "python" dispatches the jitted round
    fn once per round (the pre-scan driver, kept for debugging and as the
    parity baseline — the two are fp32 bit-identical).  Both drivers evaluate
    at the same cadence: after rounds ``eval_every, 2*eval_every, ...`` (the
    end of each eval period).

    ``peers_per_device`` > 1 (with ``peer_axis="pod"``) selects the
    HIERARCHICAL runtime: K / peers_per_device mesh slices, each vmapping a
    block of peers, consensus over the degree-bounded sparse schedule
    (``core.graph.SparseSchedule``).  ``mix_mode`` picks its consensus form:
    "bridge" (fp32 bit-identical, K <= 64), "segment" (O(K * degree / devices)
    memory, allclose), "auto" (bridge iff it is the parity regime).

    ``return_state=True`` returns ``(log, state)`` — the final post-consensus
    ``P2PState``, the training->serving bridge: ``p2p.serving_params(state)``
    is the stacked (K, ...) fleet the serving runtime
    (``repro.launch.serve``) consumes directly.  Under the pod runtime the
    state stays peer-sharded; pull it with ``jax.device_get`` before serving
    on the default device.
    """
    rounds = rounds or exp.rounds
    if peer_axis not in ("vmap", "pod"):
        raise ValueError(f"peer_axis must be 'vmap' or 'pod', got {peer_axis!r}")
    if driver not in ("scan", "python"):
        raise ValueError(f"driver must be 'scan' or 'python', got {driver!r}")
    if peers_per_device < 1:
        raise ValueError(f"peers_per_device must be >= 1, got {peers_per_device}")
    if peers_per_device > 1 and peer_axis != "pod":
        raise ValueError(
            "peers_per_device > 1 is the hierarchical sharded runtime — "
            "it needs peer_axis='pod' (the vmap runtime already holds every "
            "peer on one device)"
        )
    # fail fast — before data generation and tracing — on the compositions the
    # declarative feature table rejects (core/features.py), with the
    # documented workaround; the hierarchical pairs fire here because this is
    # where peers_per_device is first known
    features_lib.check_config(exp.p2p, peers_per_device=peers_per_device)
    task = task_lib.get_task(exp.p2p.model)
    if data is None:
        data = synthetic.mnist_like()
    x_tr, y_tr, x_te, y_te = data
    parts = _mnist_parts(exp, x_tr, y_tr)
    sizes = partition.data_sizes(parts)
    cfg = exp.p2p

    batcher = task.make_peer_batches(parts, exp.batch_size, seed=seed)
    # data_sizes seed both the mixing weights and the protocol state (for
    # push_sum: initial mass proportional to n_k -> data-weighted consensus).
    state = p2p.init_state(jax.random.PRNGKey(seed), task, cfg, data_sizes=sizes)
    mesh = None
    if peer_axis == "pod":
        from repro.launch import mesh as mesh_lib
        from repro.sharding import specs as specs_lib

        if cfg.num_peers % peers_per_device:
            raise ValueError(
                f"peers_per_device={peers_per_device} does not divide "
                f"num_peers={cfg.num_peers}"
            )
        # fails fast if short on devices; with peers_per_device > 1 the mesh
        # has K / p slices, each holding a contiguous block of p peers
        mesh = mesh_lib.make_peer_mesh(cfg.num_peers // peers_per_device)
        state = specs_lib.shard_peer_tree(state, mesh)
    hier = dict(peers_per_device=peers_per_device, mix_mode=mix_mode)
    if driver == "scan":
        drive_fn = p2p.make_scan_driver(
            task, cfg, data_sizes=sizes, mesh=mesh, **hier
        )
    elif peer_axis == "pod":
        round_fn = p2p.make_sharded_round_fn(
            task, cfg, mesh, data_sizes=sizes, **hier
        )
    else:
        round_fn = p2p.make_round_fn(task, cfg, data_sizes=sizes)

    # stratified eval groups: seen/unseen per the union of peer classes
    if exp.peer_classes:
        all_classes = sorted({c for cls in exp.peer_classes for c in cls})
        groups = {
            f"peer{k}_seen": np.asarray(cls) for k, cls in enumerate(exp.peer_classes)
        }
        groups["all"] = np.asarray(all_classes)
        sel = np.isin(y_te, all_classes)
        x_eval, y_eval = x_te[sel], y_te[sel]
    else:
        groups = {"all": np.arange(10)}
        x_eval, y_eval = x_te, y_te
    if task.eval_set_size is not None and len(x_eval) > task.eval_set_size:
        # seeded subsample: recurrent evals over the full test set are
        # minutes of CPU; the cap trades accuracy resolution for wall clock
        idx = np.random.default_rng(seed).permutation(len(x_eval))
        idx = np.sort(idx[: task.eval_set_size])
        x_eval, y_eval = x_eval[idx], y_eval[idx]
    # the task maps raw eval images to its input format ONCE, on the host
    # (identity for the MLP; pixel-stream tokenization for sequence models)
    x_eval_np = np.asarray(task.prepare_eval(x_eval))
    x_eval_j = jnp.asarray(x_eval_np)
    y_eval_j = jnp.asarray(y_eval)

    if task.eval_batch_size is None:
        eval_fn = jax.jit(
            lambda params: p2p.stratified_accuracy(
                task.apply_fn, params, x_eval_j, y_eval_j, groups
            )
        )
    else:
        # chunked eval: per-chunk predictions, group accuracies from the
        # concatenated (K, N) buffer — identical counts, bounded memory
        all_classes = np.sort(np.concatenate(list(groups.values())))

        @jax.jit
        def _preds(params, xb):
            def one(p):
                logits = task.apply_fn(p, xb)
                m = jnp.full((logits.shape[-1],), -1e9, jnp.float32)
                m = m.at[jnp.asarray(all_classes)].set(0.0)
                return jnp.argmax(logits + m, axis=-1)

            return jax.vmap(one)(params)

        def eval_fn(params):
            b = task.eval_batch_size
            pred = np.concatenate(
                [
                    np.asarray(_preds(params, jnp.asarray(x_eval_np[i : i + b])))
                    for i in range(0, len(x_eval_np), b)
                ],
                axis=1,
            )  # (K, N)
            out = {}
            for name, classes in groups.items():
                sel = np.isin(y_eval, classes)
                denom = max(int(sel.sum()), 1)
                out[name] = ((pred == y_eval[None, :]) & sel[None, :]).sum(axis=1) / denom
            return out

    log = metrics_lib.RoundLog()

    def record_eval(r, after_local, after_cons, round_losses):
        """One eval: a SINGLE batched host transfer for both phase params."""
        params_l, params_c = after_local.params, after_cons.params
        if peer_axis == "pod":
            # evaluation runs on the default device: pull BOTH phases'
            # peer-sharded params in one batched transfer per eval period
            params_l, params_c = jax.device_get((params_l, params_c))
        acc_l = {k: np.asarray(v) for k, v in eval_fn(params_l).items()}
        acc_c = {k: np.asarray(v) for k, v in eval_fn(params_c).items()}
        loss = float(np.mean(round_losses))
        log.record(
            local_acc=acc_l,
            consensus_acc=acc_c,
            drift=float(consensus_lib.pairwise_drift(params_l)),
            consensus_error=float(consensus_lib.consensus_error(params_c)),
            train_loss=loss,
        )
        if verbose:
            print(
                f"round {r:3d} loss={loss:.4f} "
                f"acc(after local)={acc_l['all'].mean():.3f} "
                f"acc(after consensus)={acc_c['all'].mean():.3f}",
                flush=True,
            )

    if driver == "scan":
        r = 0
        while r < rounds:
            n = min(eval_every, rounds - r)
            bx, by = batcher.round_batches(cfg.local_steps * n)
            # (n*T, K, ...) -> (n, T, K, ...): rounds-major chunk layout
            bx = bx.reshape((n, cfg.local_steps) + bx.shape[1:])
            by = by.reshape((n, cfg.local_steps) + by.shape[1:])
            # the input state is DONATED to the scan: use only the returns
            after_local, state, losses = drive_fn(
                state, (jnp.asarray(bx), jnp.asarray(by))
            )
            r += n
            # one eval (and at most one host transfer) per chunk, on the last
            # round's phase-boundary states; losses[-1] is that round's (T,)
            record_eval(r - 1, after_local, state, losses[-1])
    else:
        for r in range(rounds):
            bx, by = batcher.round_batches(cfg.local_steps)
            after_local, after_cons, losses = round_fn(
                state, (jnp.asarray(bx), jnp.asarray(by))
            )
            state = after_cons
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                # eval at period ends only: non-eval rounds transfer NOTHING
                record_eval(r, after_local, after_cons, losses)
    if return_state:
        return log, state
    return log


# ---------------------------------------------------------------------------
# P2P training of the LLM substrate (reduced configs on CPU)
# ---------------------------------------------------------------------------


def run_p2p_lm(
    arch: str = "smollm-135m",
    *,
    num_peers: int = 2,
    local_steps: int = 4,
    rounds: int = 8,
    batch: int = 4,
    seq: int = 32,
    algorithm: str = "p2pl_affinity",
    lr: float = 1e-2,
    momentum: float = 0.5,
    eta_d: float = 0.25,
    seed: int = 0,
    verbose: bool = False,
) -> dict:
    """K peers, disjoint token shards, local-DSGD/P2PL rounds on a reduced arch.

    Note eta_d default 0.25, not the paper's 1.0: with K=2 and a
    fully-averaging consensus, eta_d=1 re-injects the entire pre-consensus
    drift each round (d*T = w_j - w_k), a marginally-stable feedback loop that
    momentum turns divergent on transformer losses — see EXPERIMENTS.md
    §Paper-repro (beyond-paper observation O1)."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    p2p_cfg = p2p.P2PConfig(
        algorithm=algorithm,
        num_peers=num_peers,
        local_steps=local_steps,
        consensus_steps=1,
        lr=lr,
        momentum=momentum,
        eta_d=eta_d,
        topology="complete",
    )
    state = p2p.init_state(jax.random.PRNGKey(seed), model.init, p2p_cfg)
    round_fn = p2p.make_round_fn(model.loss_fn, p2p_cfg)

    rng = np.random.default_rng(seed)

    def round_batch():
        # per-peer disjoint vocab slices = "non-IID token distributions"
        tokens = np.empty((local_steps, num_peers, batch, seq), np.int32)
        labels = np.empty_like(tokens)
        span = cfg.vocab_size // num_peers
        for t in range(local_steps):
            for k in range(num_peers):
                toks = rng.integers(k * span, (k + 1) * span, size=(batch, seq + 1))
                tokens[t, k] = toks[:, :-1]
                labels[t, k] = toks[:, 1:]
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}

    losses = []
    for r in range(rounds):
        _, state, step_losses = round_fn(state, round_batch())
        losses.append(float(jnp.mean(step_losses)))
        if verbose:
            print(f"round {r}: loss {losses[-1]:.4f}", flush=True)
    drift = float(consensus_lib.pairwise_drift(state.params))
    return {"losses": losses, "final_drift": drift}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--experiment", default="noniid_affinity",
                    choices=["iid_k100", "noniid_local_dsgd", "noniid_affinity",
                             "noniid_dsgd", "p2p_lm",
                             "timevarying_k2", "timevarying_k8", "directed_k8",
                             "sharded_k8", "straggler_k8", "seqmnist_k8"])
    ap.add_argument("--model", default=None,
                    choices=sorted(task_lib.task_names()),
                    help="the TrainTask the peers train (core/task.py): "
                         "'mnist_mlp' — the paper's 2NN on flat images (the "
                         "fp32 bit-identical legacy path); 'rwkv6_seqmnist' — "
                         "RWKV6 run as an RNN over the 196-token pixel stream "
                         "of sequential MNIST.  Default: the experiment's own "
                         "(mnist_mlp everywhere except seqmnist_k8)")
    ap.add_argument("--peer-axis", default="vmap", choices=["vmap", "pod"],
                    help="how the K peer axis executes: 'vmap' (stacked "
                         "runtime, any device count) or 'pod' (shard_map over "
                         "a real mesh, one device per peer — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=K "
                         "before launch; results are bit-identical)")
    ap.add_argument("--peers-per-device", type=int, default=1,
                    help="with --peer-axis pod: peers vmapped per mesh slice "
                         "(default 1 = the classic one-device-per-peer "
                         "runtime).  > 1 selects the HIERARCHICAL runtime — "
                         "K/p mesh slices, consensus over the degree-bounded "
                         "sparse schedule — decoupling the fleet size from "
                         "the device count (K=4096 on 8 devices at p=512)")
    ap.add_argument("--mix-mode", default="auto",
                    choices=sorted(p2p.MIX_MODES),
                    help="hierarchical consensus form (only with "
                         "--peers-per-device > 1): 'bridge' replays the "
                         "dense einsum rows (fp32 bit-identical, K <= 64), "
                         "'segment' ring-streams degree-bounded slots "
                         "(O(K*degree/devices) memory, allclose), 'auto' "
                         "picks bridge iff K <= 64")
    ap.add_argument("--driver", default="scan", choices=["scan", "python"],
                    help="round driver: 'scan' fuses each eval period into one "
                         "jitted lax.scan chunk (donated state, one host "
                         "transfer per period); 'python' dispatches one jitted "
                         "round per loop iteration (debug/parity baseline)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="evaluate every N rounds (the end of each period); "
                         "with --driver scan this is also the fused chunk "
                         "size — N rounds per dispatch, so N > 1 is where "
                         "the scan driver's amortization engages")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--topology", default="complete")
    ap.add_argument("--local-steps", type=int, default=None,
                    help="T local SGD steps per round (default: the "
                         "experiment's own — 10 everywhere except "
                         "seqmnist_k8's 4)")
    ap.add_argument("--schedule", default=None,
                    choices=["static", "link_dropout", "random_matching",
                             "peer_churn", "round_robin", "one_way_matching",
                             "adaptive"],
                    help="communication-graph schedule for timevarying_* / "
                         "directed_* / sharded_* experiments (default: "
                         "link_dropout for timevarying_*, static for "
                         "directed_k8).  'adaptive' selects gossip partners "
                         "ON DEVICE each round from the peers' own training "
                         "losses (see --partner-rule); composes with every "
                         "--driver / --peer-axis / --protocol")
    ap.add_argument("--partner-rule", default="loss_proximity",
                    choices=sorted(graph_lib.ADAPTIVE_RULES),
                    help="how --schedule adaptive scores candidate partners: "
                         "loss_proximity pairs peers with the closest recent "
                         "training loss (Onoszko et al.), random is the "
                         "matched-communication baseline, eps_greedy explores "
                         "a random matching with probability --adaptive-eps")
    ap.add_argument("--adaptive-eps", type=float, default=0.1,
                    help="exploration probability for --partner-rule "
                         "eps_greedy (in [0, 1])")
    ap.add_argument("--adaptive-seed", type=int, default=0,
                    help="seeds the PRNG key threaded through the adaptive "
                         "selection state (the --schedule-seed of "
                         "state-dependent schedules)")
    ap.add_argument("--schedule-rounds", type=int, default=16,
                    help="period of the stochastic schedule (cycled)")
    ap.add_argument("--link-survival-prob", type=float, default=0.7)
    ap.add_argument("--peer-online-prob", type=float, default=0.8)
    ap.add_argument("--round-robin-topologies", default="ring,star",
                    help="comma-separated topology names cycled by "
                         "--schedule round_robin")
    ap.add_argument("--protocol", default=None,
                    choices=sorted(protocols_lib.protocol_names()),
                    help="consensus protocol (default: the experiment's own — "
                         "gossip everywhere except directed_k8's push_sum)")
    ap.add_argument("--compressor", default=None,
                    choices=sorted(compression_lib.compressor_names()),
                    help="consensus-payload compression (repro/compression): "
                         "'none' ships raw fp32 (bit-identical legacy path), "
                         "'topk' keeps the --topk-frac largest-|h| entries "
                         "per leaf, 'qint8' ships symmetric int8 + one fp32 "
                         "scale per leaf; both carry an error-feedback "
                         "residual so the dropped signal re-enters next round")
    ap.add_argument("--topk-frac", type=float, default=0.01,
                    help="fraction of entries the 'topk' compressor keeps per "
                         "leaf (in (0, 1]; ~50x bytes reduction at 0.01 on "
                         "the paper's 2NN)")
    ap.add_argument("--steps-profile", default=None,
                    choices=sorted(p2p.STEPS_PROFILES),
                    help="per-peer compute profile (core/p2p.py "
                         "compute_profile): 'uniform' — every peer runs all T "
                         "local steps (the synchronous legacy path, "
                         "bit-identical); 'straggler' — the last "
                         "straggler_frac of peers run T/straggler_period "
                         "steps and publish every straggler_period-th round; "
                         "'linear' — per-peer speeds ramp from 1 down to "
                         "1/straggler_period")
    ap.add_argument("--staleness-bound", type=int, default=None,
                    help="bounded-staleness gossip: peers mix each sender's "
                         "last PUBLISHED snapshot, at most this many rounds "
                         "old (forced delivery at the bound).  0 (default) = "
                         "synchronous mixing, bit-identical to the legacy "
                         "round.  > 0 enables the async consensus path with "
                         "age-decayed, renormalized mixing weights")
    ap.add_argument("--staleness-decay", type=float, default=None,
                    help="per-round decay applied to a stale snapshot's "
                         "mixing weight (weight *= decay^age, diagonal "
                         "renormalized per the protocol's stochasticity); "
                         "in (0, 1], default 0.5")
    ap.add_argument("--algorithm", default="p2pl_affinity",
                    help="algorithm for timevarying_* experiments")
    ap.add_argument("--out", default="")
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args(argv)
    if not 0.0 <= args.adaptive_eps <= 1.0:
        ap.error(f"--adaptive-eps must be in [0, 1], got {args.adaptive_eps}")
    if not 0.0 < args.topk_frac <= 1.0:
        ap.error(f"--topk-frac must be in (0, 1], got {args.topk_frac}")

    t0 = time.time()
    if args.experiment == "p2p_lm":
        if args.peer_axis != "vmap":
            ap.error("p2p_lm runs the vmap runtime only (--peer-axis vmap)")
        out = run_p2p_lm(args.arch, rounds=args.rounds or 8, verbose=True)
        print(json.dumps(out))
        return
    if args.experiment in ("timevarying_k2", "timevarying_k8"):
        builder = timevarying_k2 if args.experiment == "timevarying_k2" else timevarying_k8
        exp = builder(
            schedule=args.schedule or "link_dropout",
            algorithm=args.algorithm,
            local_steps=args.local_steps or 10,
            schedule_rounds=args.schedule_rounds,
            link_survival_prob=args.link_survival_prob,
            peer_online_prob=args.peer_online_prob,
            round_robin_topologies=tuple(
                t for t in args.round_robin_topologies.split(",") if t
            ),
            partner_rule=args.partner_rule,
            adaptive_eps=args.adaptive_eps,
            adaptive_seed=args.adaptive_seed,
        )
    elif args.experiment == "directed_k8":
        schedule = args.schedule or "static"
        if schedule not in ("static", "link_dropout", "one_way_matching",
                            "adaptive"):
            ap.error(f"directed_k8 supports --schedule static|link_dropout|"
                     f"one_way_matching|adaptive, got {schedule!r}")
        exp = directed_k8(
            schedule=schedule,
            protocol=args.protocol or "push_sum",
            algorithm=args.algorithm,
            local_steps=args.local_steps or 10,
            schedule_rounds=args.schedule_rounds,
            link_survival_prob=args.link_survival_prob,
            partner_rule=args.partner_rule,
            adaptive_eps=args.adaptive_eps,
            adaptive_seed=args.adaptive_seed,
        )
    elif args.experiment == "sharded_k8":
        exp = sharded_k8(
            schedule=args.schedule or "static",
            protocol=args.protocol or "gossip",
            algorithm=args.algorithm,
            local_steps=args.local_steps or 10,
            schedule_rounds=args.schedule_rounds,
            link_survival_prob=args.link_survival_prob,
            round_robin_topologies=tuple(
                t for t in args.round_robin_topologies.split(",") if t
            ),
            partner_rule=args.partner_rule,
            adaptive_eps=args.adaptive_eps,
            adaptive_seed=args.adaptive_seed,
        )
    elif args.experiment == "straggler_k8":
        schedule = args.schedule or "static"
        if schedule not in ("static", "round_robin"):
            ap.error(f"straggler_k8 supports --schedule static|round_robin, "
                     f"got {schedule!r}")
        exp = straggler_k8(
            schedule=schedule,
            protocol=args.protocol or "gossip",
            algorithm=args.algorithm,
            local_steps=args.local_steps or 8,
            steps_profile=args.steps_profile or "straggler",
            staleness_bound=(3 if args.staleness_bound is None
                             else args.staleness_bound),
            staleness_decay=(0.5 if args.staleness_decay is None
                             else args.staleness_decay),
            schedule_rounds=args.schedule_rounds,
            round_robin_topologies=tuple(
                t for t in args.round_robin_topologies.split(",") if t
            ),
        )
    elif args.experiment == "seqmnist_k8":
        exp = seqmnist_k8(
            schedule=args.schedule or "static",
            protocol=args.protocol or "gossip",
            local_steps=args.local_steps or 4,
            schedule_rounds=args.schedule_rounds,
            round_robin_topologies=tuple(
                t for t in args.round_robin_topologies.split(",") if t
            ),
        )
    elif args.experiment == "iid_k100":
        exp = iid_k100(topology=args.topology)
    elif args.experiment == "noniid_local_dsgd":
        exp = noniid_k2(algorithm="local_dsgd", local_steps=args.local_steps or 10)
    elif args.experiment == "noniid_dsgd":
        exp = noniid_k2(algorithm="dsgd", local_steps=1)
    else:
        exp = noniid_k2(algorithm="p2pl_affinity", local_steps=args.local_steps or 10)
    if args.model and args.model != exp.model:
        try:
            exp = dataclasses.replace(
                exp, model=args.model,
                p2p=dataclasses.replace(exp.p2p, model=args.model),
            )
        except ValueError as e:
            ap.error(str(e))
    if args.protocol and exp.p2p.protocol != args.protocol:
        exp = dataclasses.replace(
            exp, p2p=dataclasses.replace(exp.p2p, protocol=args.protocol)
        )
    if args.compressor and (exp.p2p.compressor != args.compressor
                            or exp.p2p.topk_frac != args.topk_frac):
        try:
            exp = dataclasses.replace(
                exp, p2p=dataclasses.replace(
                    exp.p2p, compressor=args.compressor, topk_frac=args.topk_frac
                )
            )
        except ValueError as e:
            # e.g. straggler_k8's staleness_bound=3 x --compressor topk
            ap.error(str(e))
    async_overrides = {
        k: v for k, v in (
            ("steps_profile", args.steps_profile),
            ("staleness_bound", args.staleness_bound),
            ("staleness_decay", args.staleness_decay),
        ) if v is not None and getattr(exp.p2p, k) != v
    }
    if async_overrides:
        try:
            exp = dataclasses.replace(
                exp, p2p=dataclasses.replace(exp.p2p, **async_overrides)
            )
        except ValueError as e:
            # P2PConfig.__post_init__ rejects staleness x adaptive/compressed
            # with the actionable message — surface it as a CLI error
            ap.error(str(e))
    if args.peers_per_device < 1:
        ap.error(f"--peers-per-device must be >= 1, got {args.peers_per_device}")
    if args.peers_per_device > 1 and args.peer_axis != "pod":
        ap.error("--peers-per-device > 1 needs --peer-axis pod "
                 "(the hierarchical sharded runtime)")
    # every pairwise feature rejection (async/adaptive/compressor/real-model x
    # hierarchical, ...) fires from the ONE declarative table — the same
    # messages run_paper_experiment would raise, surfaced as CLI errors
    try:
        features_lib.check_config(exp.p2p, peers_per_device=args.peers_per_device)
    except ValueError as e:
        ap.error(str(e))
    if args.peer_axis == "pod":
        if exp.p2p.num_peers % args.peers_per_device:
            ap.error(
                f"--peers-per-device {args.peers_per_device} does not divide "
                f"num_peers={exp.p2p.num_peers} of experiment {exp.name!r}"
            )
        need = exp.p2p.num_peers // args.peers_per_device
        if jax.device_count() < need:
            # fail fast, before data generation and tracing, instead of
            # letting the first jitted round die with an opaque XLA
            # sharding/shape error
            ap.error(
                f"--peer-axis pod needs {need} device(s) (num_peers="
                f"{exp.p2p.num_peers} / peers_per_device="
                f"{args.peers_per_device}) but only {jax.device_count()} jax "
                "device(s) are visible. On CPU, relaunch with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need} set before "
                "the first jax import."
            )
    log = run_paper_experiment(
        exp, rounds=args.rounds, verbose=True, peer_axis=args.peer_axis,
        driver=args.driver, eval_every=args.eval_every,
        peers_per_device=args.peers_per_device, mix_mode=args.mix_mode,
    )
    print(f"done in {time.time()-t0:.1f}s")
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(log.to_json())
        print("wrote", args.out)


if __name__ == "__main__":
    main()
