"""Pure-jnp oracle for blockwise (flash) attention.

Plain materialized-scores attention with causal and sliding-window masking,
f32 softmax.  Shapes: q/k/v (B, H, S, D) — GQA expansion happens in ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    s = q.shape[-2]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= (qi - ki) < window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
