"""Public attention op: GQA handling, dtype plumbing, ref/pallas dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def gqa_flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Kh, D)
    v: jax.Array,  # (B, S, Kh, D)
    *,
    causal: bool = True,
    window: int | None = None,
    impl: str = "pallas",
    interpret: bool | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Returns (B, S, H, D).  KV heads are expanded to Q heads (GQA).

    ``interpret=None`` lowers per platform (see repro.kernels.lowering):
    interpret mode on CPU, compiled Pallas elsewhere — resolved once, inside
    the kernel entry point it forwards to.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    assert h % kh == 0
    rep = h // kh
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    if impl == "pallas":
        out = flash_attention(
            qt, kt, vt, causal=causal, window=window,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    else:
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)
