"""Pallas TPU flash-attention forward (causal / sliding-window).

Canonical TPU tiling: grid (batch*heads, n_q_blocks, n_kv_blocks) with the KV
block dimension innermost (sequential on TPU), online-softmax statistics in
VMEM scratch that persist across KV steps:

    m   (BQ, 1)  running row max
    l   (BQ, 1)  running denominator
    acc (BQ, D)  unnormalized context accumulator

Q/K/V tiles stream HBM->VMEM per BlockSpec; the (BQ, BK) score tile lives
only in VMEM/VREGs — the S x S matrix is never materialized, so prefill_32k
attention is O(S) memory.  Fully-masked KV blocks (beyond the causal frontier
or behind the sliding window) are skipped with pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, block_q, block_k, causal, window, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level mask culling: run the block only if any (q, k) pair is live.
    run = True
    if causal:
        run = jnp.asarray(k_start <= q_start + block_q - 1)
    if window is not None:
        # newest visible k for the oldest q row in this tile:
        run = jnp.logical_and(run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0].astype(jnp.float32)  # (BK, D)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        mask = jnp.ones_like(scores, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_ref[...]  # (BQ, 1)
        m_cur = jnp.max(scores, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new)  # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)  # (BQ, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    from repro.kernels import lowering

    interpret = lowering.resolve_interpret(interpret)
    b, h, s, d = q.shape
    scale = scale if scale is not None else d**-0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q, n_kv = s // block_q, s // block_k

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        window=window,
        n_kv=n_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # m: running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # l: running denom
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
