"""Sequential oracle for the RWKV6 (Finch) WKV recurrence.

Per head, state S in R^{dk x dv}:
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(exp(logdecay_t)) S_{t-1} + k_t v_t^T
with data-dependent per-channel log-decays (<= 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logdecay, u, initial_state=None):
    """r/k/v/logdecay: (B, T, H, dk); u: (H, dk). Returns (o (B,T,H,dk), S)."""
    b, t, h, dk = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    ld = logdecay.astype(jnp.float32)
    s0 = (
        jnp.zeros((b, h, dk, dk), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, inp):
        rt, kt, vt, ldt = inp  # (B, H, dk)
        ot = jnp.einsum("bhi,bhij->bhj", rt, s) + jnp.einsum(
            "bhi,bhi,bhj->bhj", rt, u[None] * kt, vt
        )
        s = jnp.exp(ldt)[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, ot

    inps = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, ld))
    s_fin, os = jax.lax.scan(step, s0, inps)
    return os.transpose(1, 0, 2, 3), s_fin
