"""Pallas TPU kernel: chunked RWKV6 WKV with data-dependent per-channel decay.

Grid (B*H, n_chunks); the chunk dimension is innermost (sequential on TPU),
so the (dk, dv) state lives in VMEM scratch across chunks.  Per chunk Q:

  intra-chunk:  att3[t,s,i] = r[t,i] k[s,i] exp(cum[t,i] - cum[s,i]), s < t
                y_t  = sum_s (sum_i att3) v_s  + (r_t . (u*k_t)) v_t
  inter-chunk:  y_t += (r_t * exp(cum_t)) @ S
  state:        S    = exp(cum_last) * S + (k * exp(cum_last - cum))^T @ v

All decay exponents are differences cum_t - cum_s with t >= s of non-positive
log-decays => every factor <= 1: no overflow for any decay magnitude.  The
(Q, Q, dk) pairwise tensor is the VMEM working set — Q=16, dk=64 -> 64 KiB —
exactly the tiling the chunk-scan jnp fallback uses (repro/models/ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, ld_ref, u_ref, o_ref, s_ref, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)  # (Q, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (Q, dv)
    ld = ld_ref[0].astype(jnp.float32)  # (Q, dk) log-decay <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, dk)

    cum = jnp.cumsum(ld, axis=0)  # (Q, dk), inclusive
    cum_ex = cum - ld  # exclusive: RWKV applies decay AFTER the read (S_{t-1})
    q = r.shape[0]

    # intra-chunk pairwise (strictly lower-triangular in (t, s)):
    # contribution s -> t decays through steps s+1..t-1 = exp(cum_ex_t - cum_s)
    pair = cum_ex[:, None, :] - cum[None, :, :]  # (Q, Q, dk)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = (s_idx < t_idx)[..., None]
    att = jnp.sum(jnp.where(tri, r[:, None, :] * k[None, :, :] * jnp.exp(pair), 0.0), axis=-1)
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # current-step bonus
    diag = jnp.sum(r * (u * k), axis=-1, keepdims=True)  # (Q, 1)
    y = y + diag * v
    # inter-chunk contribution from carried state (decays steps c0..t-1)
    y = y + jax.lax.dot_general(r * jnp.exp(cum_ex), s_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)

    # state update
    rem = jnp.exp(cum[-1:] - cum)  # (Q, dk), <= 1
    s_ref[...] = s_ref[...] * jnp.exp(cum[-1])[:, None] + jax.lax.dot_general(
        (k * rem), v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked(
    r: jax.Array,  # (B, T, H, dk)
    k: jax.Array,
    v: jax.Array,
    logdecay: jax.Array,  # (B, T, H, dk), <= 0
    u: jax.Array,  # (H, dk)
    *,
    chunk: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    from repro.kernels import lowering

    interpret = lowering.resolve_interpret(interpret)
    b, t, h, dk = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    def flat(x):  # (B*H, T, dk)
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, dk)

    rf, kf, vf, ldf = map(flat, (r, k, v, logdecay))
    u_bh = jnp.tile(u, (b, 1)).reshape(b * h, 1, dk)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, dk), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dk), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dk), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, ldf, u_bh)
    return out.reshape(b, h, t, dk).transpose(0, 2, 1, 3)
