"""Public WKV6 op with ref/pallas dispatch."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6.ref import wkv6_ref
from repro.kernels.rwkv6.rwkv6 import wkv6_chunked


def wkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logdecay: jax.Array,
    u: jax.Array,
    *,
    impl: str = "pallas",
    chunk: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, T, H, dk) x4 + u (H, dk) -> (B, T, H, dk).

    ``interpret=None`` lowers per platform (repro.kernels.lowering),
    resolved inside ``wkv6_chunked``."""
    if impl == "pallas":
        return wkv6_chunked(r, k, v, logdecay, u, chunk=chunk, interpret=interpret)
    out, _ = wkv6_ref(r, k, v, logdecay, u)
    return out
