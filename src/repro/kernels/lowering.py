"""Platform-aware Pallas lowering policy, shared by every kernel family.

Historically each kernel entry point hardcoded ``interpret: bool = True`` —
correct on the CPU test environment (Pallas has no CPU backend, interpret mode
is the only way to run there) but silently wrong on real accelerators, where
interpret mode emulates the kernel at Python speed.  The single source of
truth is now ``default_interpret()``:

* backend ``cpu``   -> interpret=True  (the only mode that runs at all)
* anything else     -> interpret=False (compile the kernel for the device)
* ``REPRO_PALLAS_INTERPRET=1|0`` (also true/false/yes/no/on/off) overrides
  both directions — e.g. force interpret mode on a TPU to debug a kernel, or
  force compiled mode in a CPU-backed unit test that asserts lowering works.

Kernel entry points take ``interpret: bool | None = None`` and resolve the
``None`` through ``resolve_interpret`` — an explicit bool always wins.  Note
that several entry points are jitted with ``interpret`` as a static argument:
the environment variable is read when the ``None`` call signature first
*traces*, so flipping it mid-process does not retrace already-compiled calls
(pass ``interpret=`` explicitly for per-call control).
"""
from __future__ import annotations

import os

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def default_interpret(backend: str | None = None) -> bool:
    """Whether Pallas kernels should lower in interpret mode on ``backend``.

    ``backend`` defaults to ``jax.default_backend()``; the ``ENV_VAR``
    environment variable overrides the platform rule in either direction.
    """
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    if env:
        raise ValueError(
            f"{ENV_VAR}={os.environ[ENV_VAR]!r} is not a boolean; use one of "
            f"{_TRUTHY + _FALSY} (or unset it for the platform default)"
        )
    if backend is None:
        backend = jax.default_backend()
    return backend == "cpu"


def resolve_interpret(interpret: bool | None, backend: str | None = None) -> bool:
    """Resolve a kernel entry point's ``interpret`` argument.

    ``None`` (the default everywhere) means "platform decides" via
    ``default_interpret``; an explicit bool is passed through untouched.
    """
    if interpret is None:
        return default_interpret(backend)
    return bool(interpret)
