"""Pure-jnp oracle for the fused gossip + affinity update.

For one peer k with D neighbors:
    mixed = w_self * x + sum_d w_nbr[d] * nbrs[d]           (Eq. 4, one row)
    d     = (sum_d beta[d] * nbrs[d] - x) / T               (Sec. IV-A)
All accumulation in f32; outputs cast back to the input dtype.
"""
from __future__ import annotations

import jax.numpy as jnp


def consensus_mix_ref(x, nbrs, w_self, w_nbr, beta, local_steps: int):
    """x: (N,); nbrs: (D, N); w_self: scalar; w_nbr, beta: (D,)."""
    xf = x.astype(jnp.float32)
    nf = nbrs.astype(jnp.float32)
    mixed = w_self.astype(jnp.float32) * xf + jnp.einsum(
        "d,dn->n", w_nbr.astype(jnp.float32), nf
    )
    nbr_avg = jnp.einsum("d,dn->n", beta.astype(jnp.float32), nf)
    # all-zero beta (no neighbors this round) => d stays 0, matching the
    # dense path's isolated-peer semantics
    d_bias = jnp.where(
        jnp.sum(beta.astype(jnp.float32)) > 0.0,
        (nbr_avg - xf) / local_steps,
        jnp.zeros_like(xf),
    )
    return mixed.astype(x.dtype), d_bias.astype(x.dtype)
