"""Pure-jnp oracle for the fused gossip + affinity update.

For one peer k with D neighbors:
    mixed = w_self * x + sum_d w_nbr[d] * nbrs[d]           (Eq. 4, one row)
    d     = (sum_d beta[d] * nbrs[d] - x) / T               (Sec. IV-A)
All accumulation in f32; outputs cast back to the input dtype.
"""
from __future__ import annotations

import jax.numpy as jnp


def consensus_mix_ref(x, nbrs, w_self, w_nbr, beta, local_steps: int):
    """x: (N,); nbrs: (D, N); w_self: scalar; w_nbr, beta: (D,)."""
    xf = x.astype(jnp.float32)
    nf = nbrs.astype(jnp.float32)
    mixed = w_self.astype(jnp.float32) * xf + jnp.einsum(
        "d,dn->n", w_nbr.astype(jnp.float32), nf
    )
    nbr_avg = jnp.einsum("d,dn->n", beta.astype(jnp.float32), nf)
    # all-zero beta (no neighbors this round) => d stays 0, matching the
    # dense path's isolated-peer semantics
    d_bias = jnp.where(
        jnp.sum(beta.astype(jnp.float32)) > 0.0,
        (nbr_avg - xf) / local_steps,
        jnp.zeros_like(xf),
    )
    return mixed.astype(x.dtype), d_bias.astype(x.dtype)


def dequant_mix_ref(
    x, self_est, nbrs_est, nbrs_q, nbr_scale, w_self, w_nbr, beta,
    local_steps: int,
):
    """Dense oracle for the fused dequantize-and-mix kernel.

    x: (N,) f32 TRUE own parameters; self_est: (N,) f32 own public estimate;
    nbrs_est: (D, N) f32 neighbor estimates; nbrs_q: (D, N) int8 difference
    payloads; nbr_scale: (D,) fp32 scales.  Does exactly what the kernel
    exists to avoid — materializes every ADVANCED fp32 neighbor copy
    ``est + q * scale`` — then runs the unfused mix; the affinity d runs on
    estimate differences (``nbr_avg - self_est``), mirroring the compressed
    runtime.  The kernel (scale folded into the weights, accumulation
    straight from int8) must be allclose to this in every cell.
    """
    xf = x.astype(jnp.float32)
    nf = nbrs_est.astype(jnp.float32) + (
        nbrs_q.astype(jnp.float32) * nbr_scale.astype(jnp.float32)[:, None]
    )
    mixed = w_self.astype(jnp.float32) * xf + jnp.einsum(
        "d,dn->n", w_nbr.astype(jnp.float32), nf
    )
    nbr_avg = jnp.einsum("d,dn->n", beta.astype(jnp.float32), nf)
    d_bias = jnp.where(
        jnp.sum(beta.astype(jnp.float32)) > 0.0,
        (nbr_avg - self_est.astype(jnp.float32)) / local_steps,
        jnp.zeros_like(xf),
    )
    return mixed.astype(x.dtype), d_bias.astype(x.dtype)


def segment_mix_ref(flat, w_mat, beta_mat, local_steps: int):
    """Dense oracle for the segment (edge-list) kernel, gossip form.

    flat: (K, N) every peer's flattened parameters; w_mat, beta_mat: dense
    (K, K).  The (K, K) einsum the kernel exists to avoid — the ground truth
    it must be allclose to (slot-ordered sums are not bit-identical).
    """
    xf = flat.astype(jnp.float32)
    w = w_mat.astype(jnp.float32)
    b = beta_mat.astype(jnp.float32)
    mixed = jnp.einsum("kj,jn->kn", w, xf)
    nbr_avg = jnp.einsum("kj,jn->kn", b, xf)
    has_nbrs = jnp.sum(b, axis=1) > 0.0
    d_bias = jnp.where(
        has_nbrs[:, None], (nbr_avg - xf) / local_steps, jnp.zeros_like(xf)
    )
    return mixed.astype(flat.dtype), d_bias.astype(flat.dtype)


def segment_mix_push_sum_ref(flat, mass, a_mat, beta_mat, local_steps: int):
    """Dense oracle for the segment kernel, push-sum form.

    flat: (K, N) DE-BIASED parameters; mass: (K,); a_mat: dense
    column-stochastic (K, K).  Mirrors ``protocols.PushSumProtocol.mix``
    plus the affinity-d update of the raw (pre-bias) neighbor estimates.
    Returns (debiased, d_bias, new_mass).
    """
    xf = flat.astype(jnp.float32)
    a = a_mat.astype(jnp.float32)
    b = beta_mat.astype(jnp.float32)
    y = mass.astype(jnp.float32)
    y_new = jnp.einsum("kj,j->k", a, y)
    num = jnp.einsum("kj,jn->kn", a, xf * y[:, None])
    debiased = num / y_new[:, None]
    nbr_avg = jnp.einsum("kj,jn->kn", b, xf)
    has_nbrs = jnp.sum(b, axis=1) > 0.0
    d_bias = jnp.where(
        has_nbrs[:, None], (nbr_avg - xf) / local_steps, jnp.zeros_like(xf)
    )
    return debiased.astype(flat.dtype), d_bias.astype(flat.dtype), y_new
