"""Pallas TPU kernel: fused gossip mixing + affinity-bias update.

The paper's hot op is memory-bound: each consensus step reads the peer's own
parameters plus D neighbor parameter sets and must produce both the mixed
parameters (Eq. 4) and the affinity bias d (Sec. IV-A).  Unfused, that is two
passes over the D+1 tensors (mix, then d) = 2(D+1) reads + 2 writes; fused it
is one pass = (D+1) reads + 2 writes, per tile, straight through VMEM.

Layout: parameters are flattened and reshaped to (R, 128) lanes; the grid
tiles R.  Neighbor tensors arrive as one (D, R, 128) array so a single
BlockSpec streams all neighbors for the tile.  Mixing weights are tiny and
live in VMEM whole.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256  # 256 x 128 f32 = 128 KiB per operand tile


def _kernel(x_ref, nbrs_ref, w_self_ref, w_nbr_ref, beta_ref, inv_t_ref,
            mixed_ref, d_ref):
    x = x_ref[...].astype(jnp.float32)  # (BR, 128)
    nbrs = nbrs_ref[...].astype(jnp.float32)  # (D, BR, 128)
    w_self = w_self_ref[0]
    w_nbr = w_nbr_ref[...]  # (D,)
    beta = beta_ref[...]  # (D,)
    inv_t = inv_t_ref[0]

    # One pass over the neighbor tensors computes both outputs.
    mixed = w_self * x + jnp.einsum("d,drl->rl", w_nbr, nbrs)
    nbr_avg = jnp.einsum("d,drl->rl", beta, nbrs)
    mixed_ref[...] = mixed.astype(mixed_ref.dtype)
    # All-zero beta row = no neighbors this round (e.g. churned-out peer in a
    # time-varying schedule): the affinity bias stays 0 instead of pulling
    # the peer toward the origin.
    d = jnp.where(jnp.sum(beta) > 0.0, (nbr_avg - x) * inv_t, jnp.zeros_like(x))
    d_ref[...] = d.astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def consensus_mix_2d(
    x: jax.Array,  # (R, 128)
    nbrs: jax.Array,  # (D, R, 128)
    w_self: jax.Array,  # scalar
    w_nbr: jax.Array,  # (D,)
    beta: jax.Array,  # (D,)
    inv_t: jax.Array,  # scalar: 1 / local_steps
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    from repro.kernels import lowering

    interpret = lowering.resolve_interpret(interpret)
    r, lane = x.shape
    d = nbrs.shape[0]
    assert lane == LANE and nbrs.shape[1:] == (r, LANE)
    br = min(block_rows, r)
    assert r % br == 0, f"rows {r} not divisible by block {br}"

    grid = (r // br,)
    out_shape = (
        jax.ShapeDtypeStruct((r, LANE), x.dtype),
        jax.ShapeDtypeStruct((r, LANE), x.dtype),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((d, br, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(x, nbrs, w_self.reshape(1), w_nbr, beta, inv_t.reshape(1))
