"""jit'd public API for the fused consensus kernel.

``consensus_mix_flat``    — operates on flattened (N,) parameter vectors.
``consensus_mix_stacked`` — drop-in accelerated form of one gossip step over a
stacked (K, ...) parameter pytree with a sparse (padded-neighbor) mixing
matrix; used by the P2P runtime when ``use_kernel=True``.
``consensus_mix_schedule``— time-varying form: selects round ``r % R`` of a
stacked (R, ...) sparse schedule (built by ``sparse_from_schedule``, padded to
the schedule-wide max degree) inside the traced program, so every round of a
churning topology reuses one compiled kernel.

``consensus_mix_push_sum_stacked`` / ``..._push_sum_schedule`` — the directed
push-sum protocol through the SAME kernel: the (K,) push-sum mass rides as one
appended all-ones lane of the flattened parameters while the sparse weights
are pre-scaled by the sender's mass, so a single fused pass yields the mixed
numerators, the new mass, AND the affinity d of the de-biased parameters.

``consensus_mix_dense`` / ``consensus_mix_push_sum_dense`` — the
*dense-dynamic* path for state-dependent (adaptive) topologies: the (K, K)
W/Beta are TRACED values computed inside the program each round
(``graph.adaptive_round_matrices``), so no host-built sparse structure
exists.  The candidate neighbor set is the static complete graph (every
``j != k``, a trace-time constant) and the per-candidate weights are gathered
dynamically from the dense matrices — unselected candidates carry weight 0
and contribute exactly +-0.0, so one kernel shape serves every matching the
selection can produce, preserving the one-compile property.

Every entry point takes ``interpret: bool | None = None`` and resolves the
default through ``repro.kernels.lowering`` — interpret mode on CPU (the only
mode Pallas can run there), compiled lowering on real accelerators, with the
``REPRO_PALLAS_INTERPRET`` environment variable overriding either direction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus as consensus_lib
from repro.kernels.consensus_mix.consensus_mix import LANE, consensus_mix_2d

PyTree = object


def _pad_to_lanes(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.shape[-1]
    rows = -(-n // LANE)
    pad = rows * LANE - n
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(x.shape[:-1] + (rows, LANE)), n


def consensus_mix_flat(
    x: jax.Array,  # (N,)
    nbrs: jax.Array,  # (D, N)
    w_self: jax.Array,
    w_nbr: jax.Array,  # (D,)
    beta: jax.Array,  # (D,)
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    # interpret=None resolves inside consensus_mix_2d (repro.kernels.lowering)
    x2, n = _pad_to_lanes(x)
    nb2, _ = _pad_to_lanes(nbrs)
    rows = x2.shape[0]
    # pick a block that divides rows
    br = rows
    for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % cand == 0:
            br = cand
            break
    mixed, d = consensus_mix_2d(
        x2,
        nb2,
        jnp.asarray(w_self, jnp.float32),
        jnp.asarray(w_nbr, jnp.float32),
        jnp.asarray(beta, jnp.float32),
        jnp.asarray(1.0 / local_steps, jnp.float32),
        block_rows=br,
        interpret=interpret,
    )
    return mixed.reshape(-1)[:n], d.reshape(-1)[:n]


def flatten_pytree(tree: PyTree) -> tuple[jax.Array, list]:
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(l.shape[0], -1) for l in leaves], axis=1)
    meta = [(l.shape, l.dtype) for l in leaves]
    return flat, meta


def unflatten_pytree(tree_like: PyTree, flat: jax.Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(tree_like)
    out, off = [], 0
    for l in leaves:
        sz = int(np.prod(l.shape[1:])) if l.ndim > 1 else 1
        out.append(flat[:, off : off + sz].reshape(l.shape).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(treedef, out)


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def consensus_mix_stacked(
    stacked: PyTree,  # leaves (K, ...)
    self_w: jax.Array,  # (K,)
    nbr_idx: jax.Array,  # (K, D) padded neighbor indices
    nbr_w: jax.Array,  # (K, D)
    beta: jax.Array,  # (K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree]:
    """One gossip step + affinity d for all peers, via the fused kernel.

    Equivalent to consensus_lib.mix_sparse + the d update, but each neighbor
    tensor is read once.  Returns (mixed_params, d_bias).
    """
    flat, _ = flatten_pytree(stacked)  # (K, N)
    k = flat.shape[0]

    def per_peer(xk, sw, idx, wn, bt):
        nbrs = flat[idx]  # (D, N) gather — stays in HBM, tiles stream to VMEM
        return consensus_mix_flat(xk, nbrs, sw, wn, bt, local_steps, interpret=interpret)

    mixed, d = jax.vmap(per_peer)(flat, self_w, nbr_idx, nbr_w, beta)
    return unflatten_pytree(stacked, mixed), unflatten_pytree(stacked, d)


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def consensus_mix_push_sum_stacked(
    stacked: PyTree,  # leaves (K, ...) — the DE-BIASED parameters
    mass: jax.Array,  # (K,) push-sum mass y
    self_w: jax.Array,  # (K,) diagonal of the column-stochastic A
    nbr_idx: jax.Array,  # (K, D) padded in-neighbor indices
    nbr_w: jax.Array,  # (K, D) off-diagonal A weights
    beta: jax.Array,  # (K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree, jax.Array]:
    """One push-sum step + affinity d for all peers, via the fused kernel.

    The mass scalar is carried as an appended all-ones lane and the weights
    are scaled by the *sender's* mass, so the kernel's single pass computes

        [num_k | y_k'] = sum_j A[k, j] y_j [x_j | 1],   d from the raw x_j

    and the de-biased parameters are ``num / y'``.  Equivalent to
    ``protocols.PushSumProtocol.mix`` plus the d update.
    Returns (mixed_params, d_bias, new_mass).
    """
    flat, _ = flatten_pytree(stacked)  # (K, N)
    k = flat.shape[0]
    aug = jnp.concatenate(
        [flat.astype(jnp.float32), jnp.ones((k, 1), jnp.float32)], axis=1
    )
    massf = mass.astype(jnp.float32)
    self_w_y = self_w * massf
    nbr_w_y = nbr_w * massf[nbr_idx]

    def per_peer(xk, sw, idx, wn, bt):
        nbrs = aug[idx]  # (D, N+1) gather — stays in HBM, tiles stream to VMEM
        return consensus_mix_flat(xk, nbrs, sw, wn, bt, local_steps, interpret=interpret)

    mixed, d = jax.vmap(per_peer)(aug, self_w_y, nbr_idx, nbr_w_y, beta)
    new_mass = mixed[:, -1]
    debiased = mixed[:, :-1] / new_mass[:, None]
    return (
        unflatten_pytree(stacked, debiased),
        unflatten_pytree(stacked, d[:, :-1]),
        new_mass,
    )


def _complete_candidates(k: int) -> jax.Array:
    """Static (K, K-1) candidate indices: every peer j != k, row-major.

    The dense-dynamic path's neighbor structure — a trace-time constant that
    admits EVERY possible edge; the traced weights decide which contribute.
    """
    if k < 2:
        raise ValueError("dense-dynamic consensus needs at least two peers")
    idx = np.arange(k)
    cand = np.stack([np.concatenate([idx[:i], idx[i + 1 :]]) for i in range(k)])
    return jnp.asarray(cand.astype(np.int32))


def _dense_operands(
    w_mat: jax.Array, beta_mat: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(self_w, nbr_idx, nbr_w, beta) from TRACED dense (K, K) matrices.

    The traced analogue of ``sparse_from_matrices``: the candidate structure
    is the static complete graph, the weights are dynamic gathers from the
    dense matrices, so the stacked kernel entry points consume them unchanged.
    """
    k = w_mat.shape[0]
    nbr_idx = _complete_candidates(k)  # (K, K-1)
    rows = jnp.arange(k, dtype=jnp.int32)[:, None]
    self_w = jnp.diagonal(w_mat).astype(jnp.float32)
    nbr_w = w_mat[rows, nbr_idx].astype(jnp.float32)
    beta_p = beta_mat[rows, nbr_idx].astype(jnp.float32)
    return self_w, nbr_idx, nbr_w, beta_p


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def consensus_mix_dense(
    stacked: PyTree,  # leaves (K, ...)
    w_mat: jax.Array,  # (K, K) TRACED row-stochastic mixing matrix
    beta_mat: jax.Array,  # (K, K) TRACED affinity matrix
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree]:
    """One gossip step + affinity d from DYNAMIC dense matrices, via the kernel.

    Unlike ``consensus_mix_stacked``/``_schedule`` (host-built sparse
    structure), ``w_mat``/``beta_mat`` may be values computed inside the
    traced program — e.g. an adaptive round's on-device
    ``graph.adaptive_round_matrices`` output.  The candidate set is the static
    complete graph; weights of unselected edges are zero.  Equivalent to
    ``consensus_lib.mix_stacked`` + the affinity-d update.
    Returns (mixed_params, d_bias).
    """
    self_w, nbr_idx, nbr_w, beta_p = _dense_operands(w_mat, beta_mat)
    return consensus_mix_stacked(
        stacked, self_w, nbr_idx, nbr_w, beta_p, local_steps, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def consensus_mix_push_sum_dense(
    stacked: PyTree,  # leaves (K, ...) — the DE-BIASED parameters
    mass: jax.Array,  # (K,) push-sum mass y
    w_mat: jax.Array,  # (K, K) TRACED column-stochastic push matrix
    beta_mat: jax.Array,  # (K, K) TRACED affinity matrix
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree, jax.Array]:
    """Dense-dynamic form of ``consensus_mix_push_sum_stacked``: one push-sum
    step + affinity d from TRACED dense matrices (adaptive directed rounds).
    Returns (mixed_params, d_bias, new_mass)."""
    self_w, nbr_idx, nbr_w, beta_p = _dense_operands(w_mat, beta_mat)
    return consensus_mix_push_sum_stacked(
        stacked, mass, self_w, nbr_idx, nbr_w, beta_p, local_steps,
        interpret=interpret,
    )


def sparse_from_matrices(w_mat: np.ndarray, beta_mat: np.ndarray, *, dmax: int | None = None):
    """Static (self_w, nbr_idx, nbr_w, beta_padded) from dense W and Beta.

    ``dmax`` pads the neighbor axis to a fixed width (weight-0 self-index
    padding) so rounds of differing degree share one kernel shape.  Padded
    slots read beta[i, i] = 0, so they contribute nothing to either output.
    """
    self_w, nbr_idx, nbr_w = consensus_lib.sparse_mixing(w_mat, dmax=dmax)
    k = nbr_idx.shape[0]
    beta_p = beta_mat[np.arange(k)[:, None], nbr_idx].astype(np.float32)
    return (
        jnp.asarray(self_w),
        jnp.asarray(nbr_idx),
        jnp.asarray(nbr_w),
        jnp.asarray(beta_p),
    )


def sparse_from_schedule(w_stack: np.ndarray, beta_stack: np.ndarray):
    """Stacked sparse form of a (R, K, K) W/Beta schedule.

    Returns (self_w (R, K), nbr_idx (R, K, D), nbr_w (R, K, D), beta (R, K, D))
    with D = the max degree across *all* rounds, so one kernel shape serves
    the whole schedule; callers select a round with ``arr[round_idx % R]``.
    """
    w_stack = np.asarray(w_stack)
    beta_stack = np.asarray(beta_stack)
    rounds = w_stack.shape[0]
    dmax = max(
        1, max(int(consensus_lib.mixing_degrees(w_stack[t]).max()) for t in range(rounds))
    )
    parts = [
        sparse_from_matrices(w_stack[t], beta_stack[t], dmax=dmax) for t in range(rounds)
    ]
    return tuple(jnp.stack([p[i] for p in parts]) for i in range(4))


def consensus_mix_schedule(
    stacked: PyTree,  # leaves (K, ...)
    round_idx: jax.Array,  # scalar int
    self_w_s: jax.Array,  # (R, K)
    nbr_idx_s: jax.Array,  # (R, K, D)
    nbr_w_s: jax.Array,  # (R, K, D)
    beta_s: jax.Array,  # (R, K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree]:
    """Schedule-aware gossip step: round ``round_idx`` of a time-varying graph.

    The round's sparse operands are dynamic slices of the stacked schedule,
    selected inside the traced program — no recompile, no host round-trip.
    """
    idx = jax.lax.rem(jnp.asarray(round_idx, jnp.int32), jnp.int32(self_w_s.shape[0]))
    return consensus_mix_stacked(
        stacked, self_w_s[idx], nbr_idx_s[idx], nbr_w_s[idx], beta_s[idx],
        local_steps, interpret=interpret,
    )


def consensus_mix_push_sum_schedule(
    stacked: PyTree,  # leaves (K, ...)
    mass: jax.Array,  # (K,)
    round_idx: jax.Array,  # scalar int
    self_w_s: jax.Array,  # (R, K)
    nbr_idx_s: jax.Array,  # (R, K, D)
    nbr_w_s: jax.Array,  # (R, K, D)
    beta_s: jax.Array,  # (R, K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree, jax.Array]:
    """Schedule-aware push-sum step: round ``round_idx`` of a (possibly
    directed) time-varying graph, selected inside the traced program."""
    idx = jax.lax.rem(jnp.asarray(round_idx, jnp.int32), jnp.int32(self_w_s.shape[0]))
    return consensus_mix_push_sum_stacked(
        stacked, mass, self_w_s[idx], nbr_idx_s[idx], nbr_w_s[idx], beta_s[idx],
        local_steps, interpret=interpret,
    )
