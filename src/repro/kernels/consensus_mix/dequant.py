"""Pallas TPU kernel: fused dequantize-and-mix for int8-compressed gossip.

The compressed-gossip runtime (``repro.compression``, ``compressor="qint8"``)
moves consensus traffic as int8 difference payloads plus one fp32 scale per
sender; each receiver keeps a dense fp32 public estimate per neighbor and the
mix consumes ``est + q * scale`` (the advanced estimate).  The obvious
consumption order — materialize each advanced fp32 neighbor copy, then run
the fused mix — doubles the HBM traffic: write D fp32 tensors, read them
back.  This kernel fuses the advance INTO the mix: the int8 tiles and the
fp32 estimate tiles stream straight to VMEM and the per-sender scale is
folded into the mixing weights on the host side of the call,

    mixed = w_self * x + sum_d w_nbr[d] * est[d]
                       + sum_d (w_nbr[d] * scale[d]) * q[d]
    d     = (sum_d beta[d] * est[d]
             + sum_d (beta[d] * scale[d]) * q[d] - x_hat_self) / T

so no advanced neighbor copy ever exists — the weighted accumulation runs
directly on the compressed representation (the in-register int8 -> f32 cast
is free next to the memory saved).  ``x_hat_self`` is the peer's OWN public
estimate: the affinity d of the compressed runtime operates on estimate
differences (see ``p2p._consensus_phase_compressed``), while the mix's self
term stays exact on the true ``x``.  The no-neighbor guard cannot read the
folded beta (scale = 0 would corrupt it), so the RAW beta sum rides in as a
separate flag.

Layout matches ``consensus_mix.py``: (rows, 128) lanes, the grid tiles rows,
one (D, BR, 128) int8 BlockSpec streams all payloads per tile.  Note the
TPU int8 tile floor is (32, 128) vs fp32's (8, 128); the block-rows picker in
``dequant_mix_flat`` prefers multiples of 32 accordingly.  The dense oracle
is ``ref.dequant_mix_ref`` (advance-then-mix, f32): the kernel must stay
allclose to it in every cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.consensus_mix.consensus_mix import LANE, DEFAULT_BLOCK_ROWS
from repro.kernels.consensus_mix.ops import (
    _pad_to_lanes,
    flatten_pytree,
    unflatten_pytree,
)

PyTree = object


def _kernel(x_ref, self_est_ref, est_ref, q_ref, w_self_ref, w_nbr_ref,
            w_eff_ref, beta_ref, beta_eff_ref, has_nbrs_ref, inv_t_ref,
            mixed_ref, d_ref):
    x = x_ref[...].astype(jnp.float32)  # (BR, 128)
    self_est = self_est_ref[...].astype(jnp.float32)  # (BR, 128)
    est = est_ref[...].astype(jnp.float32)  # (D, BR, 128)
    q = q_ref[...].astype(jnp.float32)  # (D, BR, 128) int8, cast in-register
    w_self = w_self_ref[0]
    w_nbr = w_nbr_ref[...]  # (D,)
    w_eff = w_eff_ref[...]  # (D,) = w_nbr * scale — the advance folded in
    beta = beta_ref[...]  # (D,)
    beta_eff = beta_eff_ref[...]  # (D,) = beta * scale
    inv_t = inv_t_ref[0]

    mixed = (
        w_self * x
        + jnp.einsum("d,drl->rl", w_nbr, est)
        + jnp.einsum("d,drl->rl", w_eff, q)
    )
    nbr_avg = (
        jnp.einsum("d,drl->rl", beta, est)
        + jnp.einsum("d,drl->rl", beta_eff, q)
    )
    mixed_ref[...] = mixed.astype(mixed_ref.dtype)
    # the guard flag is the RAW beta sum (beta_eff would read 0 whenever a
    # sender's payload scale is 0, e.g. an all-zero difference)
    d = jnp.where(
        has_nbrs_ref[0] > 0.0, (nbr_avg - self_est) * inv_t, jnp.zeros_like(x)
    )
    d_ref[...] = d.astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dequant_mix_2d(
    x: jax.Array,  # (R, 128) f32 — this peer's own TRUE lanes
    self_est: jax.Array,  # (R, 128) f32 — this peer's own public estimate
    nbrs_est: jax.Array,  # (D, R, 128) f32 — neighbor public estimates
    nbrs_q: jax.Array,  # (D, R, 128) int8 — neighbor difference payloads
    w_self: jax.Array,  # scalar
    w_nbr: jax.Array,  # (D,)
    w_eff: jax.Array,  # (D,) w_nbr * scale
    beta: jax.Array,  # (D,)
    beta_eff: jax.Array,  # (D,) beta * scale
    has_nbrs: jax.Array,  # scalar: raw sum(beta), the no-neighbor guard
    inv_t: jax.Array,  # scalar: 1 / local_steps
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    from repro.kernels import lowering

    interpret = lowering.resolve_interpret(interpret)
    r, lane = x.shape
    d = nbrs_q.shape[0]
    assert lane == LANE and nbrs_q.shape[1:] == (r, LANE)
    assert nbrs_est.shape == (d, r, LANE) and self_est.shape == (r, LANE)
    assert nbrs_q.dtype == jnp.int8
    br = min(block_rows, r)
    assert r % br == 0, f"rows {r} not divisible by block {br}"

    grid = (r // br,)
    out_shape = (
        jax.ShapeDtypeStruct((r, LANE), x.dtype),
        jax.ShapeDtypeStruct((r, LANE), x.dtype),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((d, br, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((d, br, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(
        x, self_est, nbrs_est, nbrs_q, w_self.reshape(1), w_nbr, w_eff,
        beta, beta_eff, has_nbrs.reshape(1), inv_t.reshape(1),
    )


def dequant_mix_flat(
    x: jax.Array,  # (N,) f32 — own TRUE parameters
    self_est: jax.Array,  # (N,) f32 — own public estimate
    nbrs_est: jax.Array,  # (D, N) f32 — neighbor public estimates
    nbrs_q: jax.Array,  # (D, N) int8 — difference payloads
    nbr_scale: jax.Array,  # (D,) fp32 payload scales
    w_self: jax.Array,
    w_nbr: jax.Array,  # (D,)
    beta: jax.Array,  # (D,)
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused dequantize-and-mix on flattened vectors; one peer's row.

    Must stay allclose to ``ref.dequant_mix_ref`` (which materializes the
    advanced fp32 neighbors ``est + q * scale``); the kernel instead folds
    ``nbr_scale`` into the weights and accumulates straight from int8.
    """
    x2, n = _pad_to_lanes(x)
    se2, _ = _pad_to_lanes(self_est)
    ne2, _ = _pad_to_lanes(nbrs_est)
    nb2, _ = _pad_to_lanes(nbrs_q)
    rows = x2.shape[0]
    # pick a block that divides rows; multiples of 32 first (int8 tile floor)
    br = rows
    for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % cand == 0:
            br = cand
            break
    w_nbr = jnp.asarray(w_nbr, jnp.float32)
    beta = jnp.asarray(beta, jnp.float32)
    scale = jnp.asarray(nbr_scale, jnp.float32)
    mixed, d = dequant_mix_2d(
        x2,
        se2,
        ne2,
        nb2,
        jnp.asarray(w_self, jnp.float32),
        w_nbr,
        w_nbr * scale,
        beta,
        beta * scale,
        jnp.sum(beta),
        jnp.asarray(1.0 / local_steps, jnp.float32),
        block_rows=br,
        interpret=interpret,
    )
    return mixed.reshape(-1)[:n], d.reshape(-1)[:n]


def quantize_int8(flat: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 payload of a (K, N) f32 stack: (q int8, scale (K,)).

    The kernel path's whole-tree quantization (one scale per peer over the
    concatenated leaves) — the sender-side half of the fused consumer below.
    In the estimate-tracking protocol the input stack is the DIFFERENCE
    ``x - est``; the payload advances every copy of the sender's estimate.
    """
    f = flat.astype(jnp.float32)
    amax = jnp.max(jnp.abs(f), axis=1)  # (K,)
    scale = amax / 127.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(f / safe[:, None]), -127.0, 127.0).astype(jnp.int8)
    return q, scale


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def dequant_consensus_mix_stacked(
    stacked: PyTree,  # leaves (K, ...) — each peer's own TRUE parameters
    est: jax.Array,  # (K, N) f32 — flattened public-estimate stack
    q: jax.Array,  # (K, N) int8 — the senders' payloads (quantize_int8)
    scale: jax.Array,  # (K,) fp32 payload scales
    self_w: jax.Array,  # (K,)
    nbr_idx: jax.Array,  # (K, D) padded neighbor indices
    nbr_w: jax.Array,  # (K, D)
    beta: jax.Array,  # (K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree]:
    """One gossip step + affinity d where every NEIGHBOR view is its public
    estimate advanced by the int8 payload: the self term stays exact on the
    peer's own fp32 row, neighbor accumulation runs fused from the compressed
    representation.

    Returns (mixed_params, d_bias), like ``ops.consensus_mix_stacked``.
    ``est`` is the flattened (K, N) estimate stack BEFORE this step's
    advance; the caller advances its carried copy with ``est + q * scale``.
    """
    flat, _ = flatten_pytree(stacked)  # (K, N) f32
    k = flat.shape[0]

    def per_peer(xk, my, sw, idx, wn, bt):
        nbrs_q = q[idx]  # (D, N) int8 gather — stays compressed in HBM
        nbrs_e = est[idx]  # (D, N) f32 estimates
        sc = scale[idx]  # (D,)
        return dequant_mix_flat(
            xk, est[my], nbrs_e, nbrs_q, sc, sw, wn, bt, local_steps,
            interpret=interpret,
        )

    mixed, d = jax.vmap(per_peer)(
        flat, jnp.arange(k), self_w, nbr_idx, nbr_w, beta
    )
    return unflatten_pytree(stacked, mixed), unflatten_pytree(stacked, d)


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def dequant_consensus_mix_schedule(
    stacked: PyTree,
    est: jax.Array,  # (K, N) f32
    q: jax.Array,  # (K, N) int8
    scale: jax.Array,  # (K,)
    self_w_s: jax.Array,  # (R, K)
    nbr_idx_s: jax.Array,  # (R, K, D)
    nbr_w_s: jax.Array,  # (R, K, D)
    beta_s: jax.Array,  # (R, K, D)
    round_idx: jax.Array,  # traced scalar
    local_steps: int,
    *,
    interpret: bool | None = None,
) -> tuple[PyTree, PyTree]:
    """Time-varying form: round ``round_idx % R`` of a stacked sparse schedule
    (``ops.sparse_from_schedule``) selected INSIDE the traced program — one
    compile serves every round, like ``ops.consensus_mix_schedule``."""
    idx = jax.lax.rem(round_idx, self_w_s.shape[0])
    return dequant_consensus_mix_stacked(
        stacked, est, q, scale,
        self_w_s[idx], nbr_idx_s[idx], nbr_w_s[idx], beta_s[idx],
        local_steps, interpret=interpret,
    )
