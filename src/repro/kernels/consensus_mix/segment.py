"""Pallas TPU kernel: segment-sum consensus mix over an edge list.

The stacked kernel path (``ops.consensus_mix_stacked``) gathers each peer's
neighbor parameters OUTSIDE the kernel — ``flat[nbr_idx]`` materializes a
(K, D, N) array in HBM before a single tile is mixed.  At K = 4096 that
gather is the memory wall, and the dense alternative (a (K, K) einsum) is
the very array the sparse schedule exists to avoid.

This kernel moves the gather inside the pallas machinery: the padded
neighbor indices are scalar-prefetch operands, and the neighbor BlockSpec's
``index_map`` reads them — ``(idx_ref[k, d], r, 0)`` — so each grid step
DMAs exactly one neighbor's (block_rows, 128) tile straight to VMEM.  No
(K, K) matrix and no (K, D, N) gather ever exists; HBM traffic is the
edge list itself: sum_k (D+1) tiles read, 2 tiles written.

Grid: (K, row_blocks, D), neighbor slot innermost so the two outputs
accumulate in VMEM across the D steps of each (peer, row-block) pair:

    mixed[k] = self_w[k] * x[k] + sum_d nbr_w[k, d] * x[nbr_idx[k, d]]
    d[k]     = (sum_d beta[k, d] * x[nbr_idx[k, d]] - x[k]) / T

Padding slots follow the repo-wide convention (``graph.SparseSchedule``):
index = own row, weight = beta = 0.0 — a self-tile DMA whose contribution
is exactly +-0.0.  Like every degree-bounded path, the slot-ordered sum is
allclose to the dense einsum, not bit-identical (see core/p2p.py's
hierarchical "segment" mode for the same contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.consensus_mix.consensus_mix import LANE, DEFAULT_BLOCK_ROWS


def _segment_kernel(
    num_slots: int,
    self_w_ref,  # SMEM (K,)
    idx_ref,  # SMEM (K, D)
    nbr_w_ref,  # SMEM (K, D)
    beta_ref,  # SMEM (K, D)
    inv_t_ref,  # SMEM (1,)
    x_self_ref,  # VMEM (1, BR, LANE) — peer k's own tile
    x_nbr_ref,  # VMEM (1, BR, LANE) — neighbor idx_ref[k, d]'s tile
    mixed_ref,  # VMEM (1, BR, LANE) accumulator
    d_ref,  # VMEM (1, BR, LANE) accumulator
):
    k = pl.program_id(0)
    d = pl.program_id(2)
    x = x_self_ref[0].astype(jnp.float32)
    xn = x_nbr_ref[0].astype(jnp.float32)

    @pl.when(d == 0)
    def _init():
        mixed_ref[0] = (self_w_ref[k] * x).astype(mixed_ref.dtype)
        d_ref[0] = jnp.zeros_like(x).astype(d_ref.dtype)

    mixed_ref[0] = (
        mixed_ref[0].astype(jnp.float32) + nbr_w_ref[k, d] * xn
    ).astype(mixed_ref.dtype)
    d_ref[0] = (d_ref[0].astype(jnp.float32) + beta_ref[k, d] * xn).astype(
        d_ref.dtype
    )

    @pl.when(d == num_slots - 1)
    def _finish():
        # all-zero beta row = isolated peer this round: d stays 0 instead of
        # decaying the peer toward the origin (dense-path semantics)
        acc = d_ref[0].astype(jnp.float32)
        has_nbrs = jnp.sum(beta_ref[k, :]) > 0.0
        out = jnp.where(
            has_nbrs, (acc - x) * inv_t_ref[0], jnp.zeros_like(x)
        )
        d_ref[0] = out.astype(d_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def segment_mix_2d(
    x: jax.Array,  # (K, R, LANE) — every peer's lane-tiled parameters
    self_w: jax.Array,  # (K,)
    nbr_idx: jax.Array,  # (K, D) padded neighbor indices, int32
    nbr_w: jax.Array,  # (K, D)
    beta: jax.Array,  # (K, D)
    inv_t: jax.Array,  # scalar: 1 / local_steps
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """All peers' fused segment mix in one pallas_call.

    Returns (mixed, d), both (K, R, LANE).  The neighbor gather happens via
    the scalar-prefetch ``index_map`` — ``x`` is read tile-by-tile, never
    gathered into a (K, D, ...) array.
    """
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels import lowering

    interpret = lowering.resolve_interpret(interpret)
    k, r, lane = x.shape
    d = nbr_idx.shape[1]
    assert lane == LANE and nbr_idx.shape == (k, d)
    br = min(block_rows, r)
    assert r % br == 0, f"rows {r} not divisible by block {br}"

    grid = (k, r // br, d)
    spec_self = pl.BlockSpec(
        (1, br, LANE), lambda pk, pr, pd, sw, idx, nw, bt, it: (pk, pr, 0)
    )
    spec_nbr = pl.BlockSpec(
        (1, br, LANE),
        lambda pk, pr, pd, sw, idx, nw, bt, it: (idx[pk, pd], pr, 0),
    )
    spec_out = pl.BlockSpec(
        (1, br, LANE), lambda pk, pr, pd, sw, idx, nw, bt, it: (pk, pr, 0)
    )
    out_shape = (
        jax.ShapeDtypeStruct((k, r, LANE), x.dtype),
        jax.ShapeDtypeStruct((k, r, LANE), x.dtype),
    )
    return pl.pallas_call(
        functools.partial(_segment_kernel, d),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[spec_self, spec_nbr],
            out_specs=[spec_out, spec_out],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(
        self_w.astype(jnp.float32),
        nbr_idx.astype(jnp.int32),
        nbr_w.astype(jnp.float32),
        beta.astype(jnp.float32),
        jnp.asarray(inv_t, jnp.float32).reshape(1),
        x,
        x,
    )


def _pad_rows(flat: jax.Array) -> tuple[jax.Array, int]:
    """(K, N) -> (K, R, LANE) lane tiling, padded with zeros; returns N."""
    k, n = flat.shape
    rows = -(-n // LANE)
    pad = rows * LANE - n
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(k, rows, LANE), n


def _pick_block(rows: int) -> int:
    for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % cand == 0:
            return cand
    return rows


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def segment_mix_stacked(
    stacked,  # pytree, leaves (K, ...)
    self_w: jax.Array,  # (K,)
    nbr_idx: jax.Array,  # (K, D)
    nbr_w: jax.Array,  # (K, D)
    beta: jax.Array,  # (K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
):
    """One gossip step + affinity d for all peers via the segment kernel.

    The degree-bounded analogue of ``ops.consensus_mix_stacked`` without its
    (K, D, N) pre-gather.  Returns (mixed_params, d_bias).
    """
    from repro.kernels.consensus_mix import ops

    flat, _ = ops.flatten_pytree(stacked)  # (K, N)
    x3, n = _pad_rows(flat)
    mixed, d = segment_mix_2d(
        x3, self_w, nbr_idx, nbr_w, beta,
        jnp.asarray(1.0 / local_steps, jnp.float32),
        block_rows=_pick_block(x3.shape[1]), interpret=interpret,
    )
    k = flat.shape[0]
    mixed = mixed.reshape(k, -1)[:, :n]
    d = d.reshape(k, -1)[:, :n]
    return ops.unflatten_pytree(stacked, mixed), ops.unflatten_pytree(stacked, d)


@functools.partial(jax.jit, static_argnames=("local_steps", "interpret"))
def segment_mix_push_sum_stacked(
    stacked,  # pytree, leaves (K, ...) — the DE-BIASED parameters
    mass: jax.Array,  # (K,) push-sum mass y
    self_w: jax.Array,  # (K,) diagonal of the column-stochastic A
    nbr_idx: jax.Array,  # (K, D) padded in-neighbor indices
    nbr_w: jax.Array,  # (K, D) off-diagonal A weights
    beta: jax.Array,  # (K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
):
    """Push-sum through the SAME segment kernel via the mass-lane trick
    (``ops.consensus_mix_push_sum_stacked``, degree-bounded edition): the
    (K,) mass rides as one appended all-ones lane while the weights are
    pre-scaled by the sender's mass, so one fused pass yields the mixed
    numerators, the new mass, and the affinity d of the de-biased
    parameters.  Returns (mixed_params, d_bias, new_mass)."""
    from repro.kernels.consensus_mix import ops

    flat, _ = ops.flatten_pytree(stacked)  # (K, N)
    k = flat.shape[0]
    aug = jnp.concatenate(
        [flat.astype(jnp.float32), jnp.ones((k, 1), jnp.float32)], axis=1
    )
    massf = mass.astype(jnp.float32)
    self_w_y = self_w * massf
    nbr_w_y = nbr_w * massf[nbr_idx]  # (K, D) — edge-list sized, not (K, K)

    x3, n_aug = _pad_rows(aug)
    mixed, d = segment_mix_2d(
        x3, self_w_y, nbr_idx, nbr_w_y, beta,
        jnp.asarray(1.0 / local_steps, jnp.float32),
        block_rows=_pick_block(x3.shape[1]), interpret=interpret,
    )
    mixed = mixed.reshape(k, -1)[:, :n_aug]
    d = d.reshape(k, -1)[:, :n_aug]
    new_mass = mixed[:, -1]
    debiased = mixed[:, :-1] / new_mass[:, None]
    return (
        ops.unflatten_pytree(stacked, debiased),
        ops.unflatten_pytree(stacked, d[:, :-1]),
        new_mass,
    )


def segment_mix_schedule(
    stacked,
    round_idx: jax.Array,
    self_w_s: jax.Array,  # (R, K)
    nbr_idx_s: jax.Array,  # (R, K, D)
    nbr_w_s: jax.Array,  # (R, K, D)
    beta_s: jax.Array,  # (R, K, D)
    local_steps: int,
    *,
    interpret: bool | None = None,
):
    """Round ``round_idx % R`` of a stacked sparse schedule through the
    segment kernel (one compiled shape for the whole schedule)."""
    idx = jax.lax.rem(
        jnp.asarray(round_idx, jnp.int32), jnp.int32(self_w_s.shape[0])
    )
    return segment_mix_stacked(
        stacked, self_w_s[idx], nbr_idx_s[idx], nbr_w_s[idx], beta_s[idx],
        local_steps, interpret=interpret,
    )
