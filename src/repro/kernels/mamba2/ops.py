"""Public SSD op with ref/pallas dispatch."""
from __future__ import annotations

import jax

from repro.kernels.mamba2.mamba2 import ssd_chunked
from repro.kernels.mamba2.ref import ssd_ref


def ssd(x, b, c, dt, a, *, impl: str = "pallas", chunk: int = 64, interpret: bool | None = None):
    """x (B,T,H,P), b/c (B,T,H,N), dt (B,T,H), a (H,) -> y (B,T,H,P).

    ``interpret=None`` lowers per platform (repro.kernels.lowering),
    resolved inside ``ssd_chunked``."""
    if impl == "pallas":
        return ssd_chunked(x, b, c, dt, a, chunk=chunk, interpret=interpret)
    y, _ = ssd_ref(x, b, c, dt, a)
    return y
