"""Sequential oracle for the Mamba2 SSD recurrence (post-projection core).

Per head, state S in R^{P x N}, scalar decay per head/step:
    S_t = exp(dt_t * A) * S_{t-1} + (dt_t * x_t) B_t^T
    y_t = S_t C_t                                  (current state, decay-then-add)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, b, c, dt, a, initial_state=None):
    """x: (B,T,H,P); b,c: (B,T,H,N); dt: (B,T,H); a: (H,) negative.

    Returns (y (B,T,H,P), final state (B,H,P,N))."""
    bs = x.shape[0]
    h, p = x.shape[2], x.shape[3]
    n = b.shape[3]
    xf, bf, cf = (t.astype(jnp.float32) for t in (x, b, c))
    dtf = dt.astype(jnp.float32)
    s0 = (
        jnp.zeros((bs, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, inp):
        xt, bt, ct, dtt = inp
        decay = jnp.exp(dtt * a)[..., None, None]
        s = s * decay + (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        yt = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, yt

    inps = (
        xf.transpose(1, 0, 2, 3),
        bf.transpose(1, 0, 2, 3),
        cf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
    )
    s_fin, ys = jax.lax.scan(step, s0, inps)
    return ys.transpose(1, 0, 2, 3), s_fin
