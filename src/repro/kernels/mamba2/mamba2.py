"""Pallas TPU kernel: chunked Mamba2 SSD scan.

Grid (B*H, n_chunks), chunk innermost (sequential); (P, N) state in VMEM
scratch.  Per chunk Q, with scalar per-head log-decays ld = dt * A (<= 0):

  intra:  att[t,s] = exp(cum_t - cum_s) * (C_t . B_s) * dt_s,  s <= t
          y = att @ x
  inter:  y_t += (C_t * exp(cum_t)) @ S^T
  state:  S = exp(cum_last) * S + x^T @ (B * dt * exp(cum_last - cum))

Unlike RWKV, Mamba2 is decay-THEN-add: y_t reads the state including x_t, so
the inclusive cumsum is correct on both sides.  All exponents are differences
with t >= s of non-positive values => factors <= 1 (no overflow).  Work per
chunk is three (Q x Q)/(Q x P x N) matmuls — MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, ld_ref, dt_ref, o_ref, s_ref, *, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)  # (Q, P)
    b = b_ref[0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0].astype(jnp.float32)  # (Q, N)
    ld = ld_ref[0].astype(jnp.float32)  # (Q, 1)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, 1)

    q = x.shape[0]
    cum = jnp.cumsum(ld, axis=0)  # (Q, 1) inclusive

    pair = cum - cum.T  # (Q, Q): cum_t - cum_s
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = s_idx <= t_idx
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    att = jnp.where(tri, jnp.exp(pair) * cb * dt.T, 0.0)
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)
    # inter-chunk: (C_t exp(cum_t)) @ S^T ; S is (P, N)
    y = y + jax.lax.dot_general(c * jnp.exp(cum), s_ref[...],
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)

    # state update: S = exp(cum_last) S + x^T @ (B * dt * exp(cum_last - cum))
    rem = jnp.exp(cum[-1:] - cum)  # (Q, 1)
    s_ref[...] = s_ref[...] * jnp.exp(cum[-1, 0]) + jax.lax.dot_general(
        x, b * (dt * rem), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(
    x: jax.Array,  # (B, T, H, P)
    b: jax.Array,  # (B, T, H, N)
    c: jax.Array,  # (B, T, H, N)
    dt: jax.Array,  # (B, T, H) softplus'd step sizes
    a: jax.Array,  # (H,) negative per-head decay rate
    *,
    chunk: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    from repro.kernels import lowering

    interpret = lowering.resolve_interpret(interpret)
    bs, t, h, p = x.shape
    n = b.shape[3]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    ld = (dt * a[None, None, :])[..., None]  # (B, T, H, 1) log-decay
    dt4 = dt[..., None]

    def flat(z, width):
        return z.transpose(0, 2, 1, 3).reshape(bs * h, t, width)

    xf = flat(x, p)
    bf = flat(b, n)
    cf = flat(c, n)
    ldf = flat(ld, 1)
    dtf = flat(dt4, 1)

    kernel = functools.partial(_kernel, n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=(bs * h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bs * h, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, bf, cf, ldf, dtf)
    return out.reshape(bs, h, t, p).transpose(0, 2, 1, 3)
