"""The paper's technique driving the LLM substrate: two peers train a
(reduced) assigned architecture on disjoint token distributions, interleaving
T local steps with gossip consensus — the same schedule the multi-pod dry-run
lowers at 512-chip scale.

    PYTHONPATH=src python examples/train_p2p_llm.py --arch smollm-135m
"""
import argparse

from repro.launch.train import run_p2p_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--algorithm", default="p2pl_affinity",
                    choices=["p2pl_affinity", "local_dsgd", "dsgd"])
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()
    out = run_p2p_lm(args.arch, algorithm=args.algorithm, rounds=args.rounds,
                     local_steps=4, batch=4, seq=32, verbose=True)
    print(f"\nloss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}; "
          f"final inter-peer drift {out['final_drift']:.4f}")


if __name__ == "__main__":
    main()
