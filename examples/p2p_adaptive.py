"""Beyond the paper: loss-driven adaptive partner selection (state-dependent
topologies).

Every schedule the repo shipped so far is chosen before the first round: a
pretraced stack of graphs the jitted round merely indexes.  Onoszko et al.
(2107.08517) show that letting each peer pick WHO to gossip with — by training
-loss proximity — materially improves non-IID convergence: loss-proximal peers
tend to hold similar data, so averaging with them costs less local progress
and shrinks the paper's post-consensus accuracy sawtooth.

This example trains the K=8 non-IID workload (2 classes per peer) under three
partner rules of ``--schedule adaptive`` plus the static random-matching
baseline, and prints the numbers that separate them: post-consensus
oscillation amplitude and final consensus error.  The adaptive selection runs
ON DEVICE inside the one jitted round function — each round's (K, K) mixing
matrix is computed from the previous round's per-peer losses and a PRNG key
threaded through ``P2PState`` (no host callback, one compile per run).

    PYTHONPATH=src python examples/p2p_adaptive.py [--rounds 30]
"""
import argparse

import numpy as np

from repro.configs.p2pl_mnist import timevarying_k8
from repro.data import synthetic
from repro.launch.train import run_paper_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--algorithm", default="p2pl_affinity")
    ap.add_argument("--protocol", default="gossip",
                    choices=["gossip", "push_sum"])
    ap.add_argument("--adaptive-eps", type=float, default=0.2)
    args = ap.parse_args()

    data = synthetic.mnist_like(20000, 5000)
    variants = [
        ("adaptive / loss_proximity", "adaptive", "loss_proximity"),
        ("adaptive / eps_greedy", "adaptive", "eps_greedy"),
        ("adaptive / random", "adaptive", "random"),
        ("static random_matching", "random_matching", "loss_proximity"),
    ]
    for label, schedule, rule in variants:
        exp = timevarying_k8(
            schedule=schedule, algorithm=args.algorithm, local_steps=10,
            protocol=args.protocol,
            partner_rule=rule, adaptive_eps=args.adaptive_eps,
        )
        log = run_paper_experiment(exp, rounds=args.rounds, data=data)
        acc = np.stack(log.after_consensus["all"])
        print(f"== {label} ==")
        print(f"  final accuracy (all classes) : {log.final_accuracy('all'):.4f}")
        print(f"  per-peer final accuracy      : {np.round(acc[-1], 3)}")
        print(f"  mean accuracy oscillation    : {log.mean_oscillation('all'):.4f}")
        print(f"  final consensus error        : {log.consensus_error[-1]:.4f}")
        print()


if __name__ == "__main__":
    main()
