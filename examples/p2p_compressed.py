"""Compressed gossip: the bytes x accuracy Pareto on one non-IID workload.

The consensus phase is where a P2P fleet's bandwidth goes — every round,
every peer ships its full fp32 parameter stack to every partner.  This
example reruns the K=8 time-varying non-IID workload with each registered
compressor (`repro.compression`): `none` ships raw fp32 (the bit-identical
baseline), `topk` ships only the largest-|.| fraction of each difference,
`qint8` ships symmetric int8.  Both compressed wires track a public
per-peer estimate with error feedback, so the dropped signal re-enters the
next payload instead of being lost.

Alongside accuracy, the analytic wire model (`benchmarks.wire`) prices
each variant's fleet traffic: the raw baseline pays the round's active
edges; compressed payloads ride every union lane of the schedule (estimate
tracking keeps sender and receiver copies in lockstep), and still land an
order of magnitude under the fp32 wire.

    PYTHONPATH=src python examples/p2p_compressed.py [--rounds 48]
"""
import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

# the wire-bytes model lives in the repo-root benchmarks package
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import wire  # noqa: E402
from repro import compression as compression_lib
from repro.configs.p2pl_mnist import timevarying_k8
from repro.core import p2p
from repro.core import protocols as protocols_lib
from repro.data import synthetic
from repro.launch.train import run_paper_experiment
from repro.models import mlp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--topk-frac", type=float, default=0.025)
    args = ap.parse_args()

    data = synthetic.mnist_like(20000, 5000)
    rows = []
    for name in ("none", "topk", "qint8"):
        exp = timevarying_k8(
            schedule="round_robin", algorithm="p2pl_affinity", local_steps=10,
            compressor=name, topk_frac=args.topk_frac,
        )
        cfg = exp.p2p

        # analytic traffic for this variant's wire
        sched = p2p.build_schedule(cfg)
        consts = protocols_lib.get_protocol(cfg.protocol).constants(
            sched, cfg.mixing, data_sizes=np.full(cfg.num_peers, 100)
        )
        params = jax.eval_shape(
            jax.vmap(mlp.init_2nn),
            jax.ShapeDtypeStruct((cfg.num_peers, 2), jnp.uint32),
        )
        comp = compression_lib.from_config(cfg)
        msg = wire.message_nbytes(comp, params)
        if comp.identity:
            fleet = wire.gossip_bytes_per_round(consts.w, msg, cfg.consensus_steps)
        else:
            fleet = wire.estimate_gossip_bytes_per_round(
                consts.w, msg, cfg.consensus_steps
            )

        log = run_paper_experiment(exp, rounds=args.rounds, data=data)
        rows.append((name, msg, fleet, log.final_accuracy("all")))
        print(f"== {name}: {msg:,.0f} B/edge, {fleet:,.0f} B fleet/round, "
              f"final accuracy {rows[-1][3]:.4f} ==")

    base_fleet, base_acc = rows[0][2], rows[0][3]
    print()
    print(f"{'compressor':<12}{'B/edge':>12}{'fleet B/round':>16}"
          f"{'reduction':>11}{'accuracy':>10}{'delta':>8}")
    for name, msg, fleet, acc in rows:
        # reduction is the FLEET ratio — the same number the CI gate checks
        print(f"{name:<12}{msg:>12,.0f}{fleet:>16,.0f}"
              f"{base_fleet / fleet:>10.1f}x{acc:>10.4f}{acc - base_acc:>+8.4f}")


if __name__ == "__main__":
    main()
