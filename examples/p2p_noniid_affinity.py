"""The paper's core experiment (Figs. 3 & 6): two devices with disjoint
classes; local DSGD oscillates and forgets, P2PL-with-Affinity damps the
oscillations at zero extra communication.

    PYTHONPATH=src python examples/p2p_noniid_affinity.py [--rounds 40]
"""
import argparse

import numpy as np

from repro.configs.p2pl_mnist import noniid_k2
from repro.data import synthetic
from repro.launch.train import run_paper_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    args = ap.parse_args()

    import dataclasses

    data = synthetic.mnist_like(20000, 5000)
    print("== local DSGD (T=10) ==")
    log_plain = run_paper_experiment(
        noniid_k2(algorithm="local_dsgd", local_steps=10),
        rounds=args.rounds, data=data)
    print("== P2PL with Affinity (T=10, eta_d=0.5) ==")
    aff = noniid_k2(algorithm="p2pl_affinity", local_steps=10)
    # eta_d=0.5 (not the paper's 1.0): stable for K=2 full averaging — see
    # EXPERIMENTS.md observation O1
    aff = dataclasses.replace(aff, p2p=dataclasses.replace(aff.p2p, eta_d=0.5))
    log_aff = run_paper_experiment(aff, rounds=args.rounds, data=data)

    for name, log in (("local_dsgd", log_plain), ("p2pl_affinity", log_aff)):
        un_l = np.stack(log.after_local["peer1_seen"])[:, 0]
        un_c = np.stack(log.after_consensus["peer1_seen"])[:, 0]
        print(f"\n{name}: device A accuracy on UNSEEN classes 7,8")
        print("  after local    :", np.round(un_l[-8:], 3))
        print("  after consensus:", np.round(un_c[-8:], 3))
        print(f"  mean oscillation: {log.mean_oscillation('peer1_seen'):.4f}")
        print(f"  final (consensus): {log.final_accuracy('peer1_seen'):.4f}")

    damp = log_plain.mean_oscillation("peer1_seen") - log_aff.mean_oscillation("peer1_seen")
    print(f"\nAffinity damped unseen-class oscillations by {damp:.4f} "
          f"({damp / max(log_plain.mean_oscillation('peer1_seen'), 1e-9):.0%}) "
          "with ZERO additional communication.")


if __name__ == "__main__":
    main()
