"""Quickstart: 8 peers on a ring graph collaboratively learn (synthetic-)MNIST
with P2PL — no server, no raw-data exchange.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import numpy as np

from repro.configs.p2pl_mnist import PaperExperiment
from repro.core.p2p import P2PConfig
from repro.data import synthetic
from repro.launch.train import run_paper_experiment


def main():
    exp = PaperExperiment(
        name="quickstart_ring8",
        p2p=P2PConfig(
            algorithm="p2pl",
            num_peers=8,
            local_steps=20,
            consensus_steps=1,
            lr=0.01,
            momentum=0.5,
            topology="ring",
        ),
        batch_size=10,
        rounds=15,
    )
    data = synthetic.mnist_like(16000, 4000)
    log = run_paper_experiment(exp, data=data, verbose=True)
    acc = np.stack(log.after_consensus["all"])[-1]
    print(f"\nfinal per-peer test accuracy: {np.round(acc, 3)}")
    print(f"mean oscillation |after_consensus - after_local|: "
          f"{log.mean_oscillation('all'):.4f}")


if __name__ == "__main__":
    main()
