"""Train a non-IID fleet, then SERVE it: K personalized models, one call.

P2PL's product is not one consensus model — it is K *divergent* models, each
specialized to its peer's data distribution (the paper's non-IID setting
makes them diverge by design).  This example closes the loop from training to
serving:

1. train the K=8 straggler fleet (2 classes per peer, ring gossip) and keep
   the final ``P2PState`` (``run_paper_experiment(..., return_state=True)``),
2. lift its peer-stacked parameters straight into the serving runtime
   (``p2p.serving_params`` -> ``serve.make_fleet_classify_fn``): the trainer
   and the server share the SAME leading-K layout, so "deployment" is zero
   reshaping — one jitted call classifies all K peers' held-out shards under
   their own weights, routed by a traced ``peer_ids`` gather,
3. run the consensus-averaged single model through the IDENTICAL stacked
   path (``p2p.consensus_averaged_params``) and print the per-peer A/B:
   what personalization buys on each peer's own test distribution.

Expected shape of the result: personalized accuracy beats the averaged model
by a wide margin on each peer's own classes (the averaged model splits its
capacity over all 10 classes and every peer's bias pulls it a different
way).  The CI-gated version of this claim lives in ``benchmarks/serving.py``
(``personalized_beats_consensus_acc``); the LLM fleet variant of the same
serving path is ``python -m repro.launch.serve --peers 8``.

    PYTHONPATH=src python examples/p2p_serve.py [--rounds 12]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.p2pl_mnist import straggler_k8
from repro.core import p2p
from repro.data import partition, synthetic
from repro.launch import serve as serve_lib
from repro.launch.train import run_paper_experiment
from repro.models import mlp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--train-samples", type=int, default=6000)
    ap.add_argument("--test-samples", type=int, default=1500)
    args = ap.parse_args()

    exp = straggler_k8()
    k = exp.p2p.num_peers
    data = synthetic.mnist_like(args.train_samples, args.test_samples)
    x_tr, y_tr, x_te, y_te = data

    print(f"training {exp.name}: K={k}, {args.rounds} rounds, "
          f"classes per peer {list(exp.peer_classes)[:2]}...")
    _, state = run_paper_experiment(
        exp, rounds=args.rounds, data=data, return_state=True
    )

    # each peer's held-out shard: the TEST split partitioned by ITS classes,
    # truncated to the smallest shard so the fleet evaluates in one call
    shards = partition.pathological_partition(x_te, y_te, list(exp.peer_classes))
    n = min(len(sx) for sx, _ in shards)
    images = jnp.stack([sx[:n] for sx, _ in shards])
    labels = np.stack([sy[:n] for _, sy in shards])

    personalized = p2p.serving_params(state)
    sizes = partition.data_sizes(
        partition.pathological_partition(
            x_tr, y_tr, list(exp.peer_classes),
            samples_per_class=exp.samples_per_class,
        )
    )
    averaged = p2p.consensus_averaged_params(personalized, data_sizes=sizes)

    classify = jax.jit(serve_lib.make_fleet_classify_fn(mlp.apply_2nn))
    peer_ids = jnp.arange(k, dtype=jnp.int32)

    def per_peer_acc(params):
        pred = np.asarray(jnp.argmax(classify(params, images, peer_ids), -1))
        return (pred == labels).mean(axis=1)

    acc_p = per_peer_acc(personalized)
    acc_a = per_peer_acc(averaged)

    print(f"\nper-peer accuracy on OWN held-out shard ({n} samples each):")
    print("  peer  classes   personalized   averaged")
    for i in range(k):
        print(f"    {i}   {str(exp.peer_classes[i]):8s}    "
              f"{acc_p[i]:.3f}          {acc_a[i]:.3f}")
    print(f"  mean             {acc_p.mean():.3f}          {acc_a.mean():.3f}")
    print("\npersonalized fleet "
          + ("BEATS" if acc_p.mean() > acc_a.mean() else "does NOT beat")
          + " the consensus-averaged model — the K divergent models are the "
            "product; serve them stacked (repro/launch/serve.py).")


if __name__ == "__main__":
    main()
