"""Batched serving demo: prefill a prompt batch and greedy-decode
continuations from a (reduced) assigned architecture.

    PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-7b
"""
import argparse

from repro.launch.serve import serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    serve_batch(args.arch, batch=args.batch, prompt_len=16, gen_tokens=8, verbose=True)


if __name__ == "__main__":
    main()
