"""Running the peer axis on a REAL mesh: shard_map vs vmap, bit for bit.

The stacked runtime vmaps the K peer replicas on one device — fine for paper
experiments, useless for deployment.  The sharded runtime places one peer per
mesh slice (``peer_axis="pod"``): local phases run embarrassingly parallel
and the consensus mix lowers to ppermute sends along the round's edges
instead of a dense (K, K) einsum, while staying fp32 bit-identical to the
vmap runtime (that is CI-enforced — see tests/test_mesh_runtime.py).

One device per peer is required.  On a CPU-only machine, force XLA to expose
8 host devices BEFORE jax starts — it is an env var, not a runtime switch:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/p2p_sharded.py [--rounds 10]

This example trains the sharded_k8 workload (8 non-IID peers, ring with link
dropout) under BOTH runtimes and prints the per-round wall-clock next to the
max |accuracy difference| — which is exactly 0.0.
"""
import argparse
import os
import sys
import time

# must precede the first jax import: the flag only takes effect at backend init
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.p2pl_mnist import sharded_k8  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.launch.train import run_paper_experiment  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--schedule", default="link_dropout",
                    choices=["static", "link_dropout", "round_robin",
                             "one_way_matching"])
    ap.add_argument("--protocol", default="gossip", choices=["gossip", "push_sum"])
    args = ap.parse_args()

    exp = sharded_k8(schedule=args.schedule, protocol=args.protocol,
                     local_steps=5)
    if len(jax.devices()) < exp.p2p.num_peers:
        sys.exit(
            f"need {exp.p2p.num_peers} devices, found {len(jax.devices())} — "
            "was jax imported before XLA_FLAGS was set?"
        )

    data = synthetic.mnist_like(20000, 5000)
    logs = {}
    for peer_axis in ("vmap", "pod"):
        t0 = time.time()
        logs[peer_axis] = run_paper_experiment(
            exp, rounds=args.rounds, data=data, peer_axis=peer_axis
        )
        per_round = (time.time() - t0) / args.rounds * 1e3
        print(f"{peer_axis:4s} runtime: {per_round:8.1f} ms/round "
              f"(final acc {logs[peer_axis].final_accuracy('all'):.4f})")

    diff = max(
        np.abs(np.stack(logs["vmap"].after_consensus[g])
               - np.stack(logs["pod"].after_consensus[g])).max()
        for g in logs["vmap"].after_consensus
    )
    print(f"max |vmap - pod| over every accuracy trajectory: {diff}")
    assert diff == 0.0, "the runtimes are contractually bit-identical"


if __name__ == "__main__":
    main()
