"""Beyond the paper: a REAL recurrent model on the peer axis.

The paper trains a 2NN MLP; edge fleets train real architectures.  The
`TrainTask` registry (`core/task.py`) makes the model an axis of the config:
`--model rwkv6_seqmnist` swaps the 2NN for a reduced RWKV6 running in RNN
mode over 196-token pixel-stream MNIST (2x2 mean-pool, 16 fixed luminance
bins — `data/pipeline.py:images_to_tokens`), and NOTHING else changes: the
same jitted round, the same gossip / push-sum consensus, the same non-IID
label shards, now mixing a deep parameter tree (embeddings, layernorms,
time/channel mixes, LoRA decay projections) instead of four matrices.

This example trains a K=2 disjoint-shard fleet under both protocols and
prints the loss trajectory and per-peer accuracies — each peer only ever
sees 2 of the 4 classes, so the "all" accuracy is earned by consensus, not
by local data.

    PYTHONPATH=src python examples/p2p_realmodel.py [--rounds 6]
"""
import argparse

import numpy as np

from repro.configs.p2pl_mnist import PaperExperiment
from repro.core import p2p
from repro.data import synthetic
from repro.launch.train import run_paper_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    data = synthetic.mnist_like(4000, 600)
    for protocol in ("gossip", "push_sum"):
        exp = PaperExperiment(
            name=f"realmodel_{protocol}",
            p2p=p2p.P2PConfig(
                algorithm="p2pl",
                num_peers=2,
                local_steps=args.local_steps,
                consensus_steps=1,
                lr=args.lr,
                topology="complete",
                mixing="data_weighted",
                protocol=protocol,
                model="rwkv6_seqmnist",
            ),
            batch_size=8,
            samples_per_class=30,
            peer_classes=((0, 1), (2, 3)),
        )
        print(f"== rwkv6_seqmnist under {protocol}: K=2, disjoint 2-class "
              f"shards, T={args.local_steps} ==")
        log = run_paper_experiment(exp, rounds=args.rounds, data=data)
        losses = np.asarray(log.train_loss, np.float64)
        acc = np.stack(log.after_consensus["all"])
        print(f"  train loss               : {np.round(losses, 4)}")
        print(f"  loss decreased           : {bool(losses[-1] < losses[0])}")
        print(f"  final accuracy (all)     : {log.final_accuracy('all'):.4f}")
        print(f"  per-peer final accuracy  : {np.round(acc[-1], 3)}")
        print()


if __name__ == "__main__":
    main()
