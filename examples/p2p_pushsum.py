"""Beyond the paper: non-IID training over a DIRECTED ring with push-sum.

The paper's gossip assumes every link is bidirectional.  Edge deployments
often get one-way links (asymmetric radio reach, NAT, energy budgets): peer k
can push to k+1 but never hears back.  Row-stochastic gossip still contracts
to *a* consensus on such a graph — just not the right one (the limit is the
left-Perron-weighted average, not the data-weighted average the paper's
mixing is designed to produce).  The push-sum protocol fixes this with a
per-peer mass scalar: column-stochastic weights conserve total mass, and the
de-biased estimate w_k / y_k converges to the data-weighted average on any
strongly-connected directed schedule.

This example trains the K=8 non-IID workload (2 classes per peer) on a
directed ring under both protocols and prints the number that separates
them: the distance of the consensus point from the data-weighted parameter
average.  Every run uses ONE jitted round function — the protocol constants
are stacked and indexed by round inside the compiled program.

    PYTHONPATH=src python examples/p2p_pushsum.py [--rounds 30]
"""
import argparse

import numpy as np

from repro.configs.p2pl_mnist import directed_k8
from repro.core import p2p
from repro.data import synthetic
from repro.launch.train import run_paper_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--algorithm", default="p2pl_affinity")
    ap.add_argument("--schedule", default="static",
                    choices=["static", "link_dropout", "one_way_matching"])
    args = ap.parse_args()

    data = synthetic.mnist_like(20000, 5000)
    for protocol in ("gossip", "push_sum"):
        exp = directed_k8(schedule=args.schedule, protocol=protocol,
                          algorithm=args.algorithm, local_steps=10)
        sched = p2p.build_schedule(exp.p2p)
        print(f"== {protocol} on directed {args.schedule}: period {sched.period}, "
              f"union strongly connected: {sched.union_is_strongly_connected()} ==")
        log = run_paper_experiment(exp, rounds=args.rounds, data=data)
        acc = np.stack(log.after_consensus["all"])
        print(f"  final accuracy (all classes) : {log.final_accuracy('all'):.4f}")
        print(f"  per-peer final accuracy      : {np.round(acc[-1], 3)}")
        print(f"  final consensus error        : {log.consensus_error[-1]:.4f}")
        print(f"  mean accuracy oscillation    : {log.mean_oscillation('all'):.4f}")
        print()


if __name__ == "__main__":
    main()
