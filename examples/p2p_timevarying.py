"""Beyond the paper: the K=2 non-IID experiment over a *churning* link.

The paper fixes one gossip topology per run; real edge deployments drop
links and re-sample gossip partners every round.  This example reruns the
Fig. 3 workload under three communication schedules — static, link dropout
(the A-B edge is up only ~70% of rounds), and random matching — and shows
how the consensus sawtooth and final accuracy respond.  The whole run uses
ONE jitted round function per schedule: the (R, K, K) mixing stack is
indexed by round inside the compiled program.

    PYTHONPATH=src python examples/p2p_timevarying.py [--rounds 30]
"""
import argparse

import numpy as np

from repro.configs.p2pl_mnist import timevarying_k2
from repro.core import p2p
from repro.core import graph as graph_lib
from repro.data import synthetic
from repro.launch.train import run_paper_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--algorithm", default="local_dsgd")
    args = ap.parse_args()

    data = synthetic.mnist_like(20000, 5000)
    for schedule in ("static", "link_dropout", "random_matching"):
        exp = timevarying_k2(schedule=schedule, algorithm=args.algorithm,
                             local_steps=10, link_survival_prob=0.7)
        sched = p2p.build_schedule(exp.p2p)
        w, _ = graph_lib.schedule_matrices(sched, exp.p2p.mixing)
        up = [g.degree().sum() > 0 for g in sched.graphs]
        print(f"== {schedule}: period {sched.period}, link up "
              f"{np.mean(up):.0%} of rounds, union connected: "
              f"{sched.union_is_connected()} ==")
        log = run_paper_experiment(exp, rounds=args.rounds, data=data)
        un_c = np.stack(log.after_consensus["peer1_seen"])[:, 0]
        print("  device A on UNSEEN classes (after consensus):",
              np.round(un_c[-6:], 3))
        print(f"  mean unseen oscillation : {log.mean_oscillation('peer1_seen'):.4f}")
        print(f"  final accuracy (all)    : {log.final_accuracy('all'):.4f}")
        print(f"  mean spectral gap of W_t: "
              f"{np.mean([graph_lib.spectral_gap(w[t]) for t in range(sched.period)]):.3f}")
        print()


if __name__ == "__main__":
    main()
