"""Bounded-staleness async rounds: beating the slowest peer to the target.

A synchronous P2P round cannot close before its slowest member, so a fleet
with stragglers pays `T * max_k(period_k)` wall-clock units per round even
though most peers finished long before.  This example reruns the K=8
straggler fleet (ring topology, the last quarter of the peers 4x slower)
two ways under the SAME total wall-clock budget:

- **sync**: `steps_profile="uniform"`, `staleness_bound=0` — every round
  waits for the stragglers; fewer, slowest-peer-bound rounds.
- **async**: `steps_profile="straggler"`, `staleness_bound=3` — fast peers
  mix each straggler's last *published* snapshot (age-decayed, renormalized
  per the protocol's stochasticity) instead of waiting, so rounds cost
  `T * max(1, max_p / (bound+1))` units and `max_p`x more of them fit in
  the budget.

The model (and the CI-gated claim) lives in `benchmarks/straggler.py`; this
is the narrated single-file version.

    PYTHONPATH=src python examples/p2p_async.py [--sync-rounds 16]
"""
import argparse

from repro.configs.p2pl_mnist import straggler_k8
from repro.core.p2p import compute_profile
from repro.data import synthetic
from repro.launch.train import run_paper_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sync-rounds", type=int, default=16,
                    help="synchronous budget; async gets the same wall-clock")
    args = ap.parse_args()

    data = synthetic.mnist_like(6000, 1500)
    _, period = compute_profile(straggler_k8().p2p)
    max_p = int(period.max())

    results = {}
    for name, profile, bound in (("sync", "uniform", 0), ("async", "straggler", 3)):
        exp = straggler_k8(steps_profile=profile, staleness_bound=bound)
        t = exp.p2p.local_steps
        units = float(t * max_p) if profile == "uniform" else t * max(1.0, max_p / (bound + 1))
        rounds = args.sync_rounds if profile == "uniform" else int(
            round(args.sync_rounds * t * max_p / units)
        )
        print(f"== {name}: {rounds} rounds x {units:.0f} units "
              f"(budget {rounds * units:.0f}) ==")
        log = run_paper_experiment(exp, rounds=rounds, data=data, verbose=False)
        results[name] = (log, units, rounds)
        print(f"   final accuracy {log.final_accuracy('all'):.4f}")

    target = 0.9 * results["sync"][0].final_accuracy("all")
    print(f"\ntarget accuracy (0.9 x sync final): {target:.4f}")
    for name, (log, units, rounds) in results.items():
        r = log.rounds_to_accuracy("all", target)
        wall = ((r if r >= 0 else rounds - 1) + 1) * units
        reached = f"round {r}" if r >= 0 else "never (charged full budget)"
        print(f"{name:>6}: reached at {reached} -> {wall:.0f} wall-clock units")


if __name__ == "__main__":
    main()
